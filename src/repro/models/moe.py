"""Mixture-of-Experts MLP — GShard-style grouped one-hot dispatch.

Tokens are split into groups of ``group_size``; each group dispatches into a
per-expert capacity buffer via one-hot einsums.  Expert weights shard over the
``model`` mesh axis (EP); the all-to-all emerges from GSPMD resharding the
dispatched ``(E, B, G, C, d)`` tensor from data- to model-major.  Grouping
bounds both the dispatch-tensor memory and the dispatch FLOPs (C scales with
group size, total dispatch work scales with S*C ∝ S²/n_groups).

This is the *baseline* (paper-era, GShard-faithful) routing.  Its dispatch
einsum FLOPs are visible in the roofline useful-compute ratio and are a
hillclimb target (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


def capacity(group_size: int, n_experts: int, k: int, factor: float) -> int:
    c = int(math.ceil(k * group_size * factor / n_experts))
    return max(4, ((c + 3) // 4) * 4)


def _expert_ffn(xe, w_gate, w_up, w_down):
    """xe: (E, ..., d) batched gated FFN per expert."""
    h = jax.nn.silu(jnp.einsum("e...d,edf->e...f", xe, w_gate).astype(F32)
                    ).astype(xe.dtype)
    u = jnp.einsum("e...d,edf->e...f", xe, w_up)
    return jnp.einsum("e...f,efd->e...d", h * u, w_down)


def moe_mlp_scatter(x: jax.Array, router: jax.Array,
                    w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array, *,
                    n_experts: int, k: int, capacity_factor: float = 1.25,
                    group_size: int = 1024,
                    constrain=lambda t, axes: t):
    """Scatter/gather (sort-free dropless-with-capacity) routing.

    Replaces the GShard one-hot dispatch/combine einsums — whose FLOPs are
    2*T*E*C*d *each* and whose (T,E,C) one-hot tensors dominate MoE HBM
    traffic — with capacity-binned scatter + gather (zero matmul FLOPs, O(T*d)
    traffic).  §Perf iteration 4; the einsum path remains the paper-era
    baseline (``moe_mlp``).
    """
    B, S, d = x.shape
    E = n_experts
    gs = min(group_size, S)
    assert S % gs == 0, (S, gs)
    ng = S // gs
    C = capacity(gs, E, k, capacity_factor)

    xg = x.reshape(B, ng, gs, d)
    logits = jnp.einsum("bnsd,de->bnse", xg, router,
                        preferred_element_type=F32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = lax.top_k(probs, k)                        # (B,ng,gs,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # position of each (token, choice) within its expert's capacity bin
    counts = jnp.zeros((B, ng, E), F32)
    dests, keeps = [], []
    for ki in range(k):
        oh = jax.nn.one_hot(top_i[..., ki], E, dtype=F32)     # (B,ng,gs,E)
        pos = jnp.cumsum(oh, axis=2) - oh + counts[:, :, None]
        pos_tok = jnp.sum(pos * oh, axis=-1)                  # (B,ng,gs)
        keep = pos_tok < C
        dests.append(top_i[..., ki] * C + pos_tok.astype(jnp.int32))
        keeps.append(keep)
        counts = counts + jnp.sum(oh, axis=2)
    dest = jnp.stack(dests, axis=-1)                          # (B,ng,gs,k)
    keep = jnp.stack(keeps, axis=-1)
    dest = jnp.where(keep, dest, E * C)                       # overflow slot

    # scatter tokens into capacity bins: (B,ng,E*C+1,d)
    buf = jnp.zeros((B, ng, E * C + 1, d), x.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(gs)[None, None, :, None],
                               (B, ng, gs, k))
    buf = buf.at[
        jnp.arange(B)[:, None, None, None],
        jnp.arange(ng)[None, :, None, None],
        dest, :].set(xg[jnp.arange(B)[:, None, None, None],
                        jnp.arange(ng)[None, :, None, None], tok_idx])
    xe = buf[:, :, : E * C].reshape(B, ng, E, C, d)
    xe = jnp.moveaxis(xe, 2, 0)                               # (E,B,ng,C,d)
    xe = constrain(xe, ("experts", "batch", None, None, None))
    ye = _expert_ffn(xe, w_gate, w_up, w_down)
    ye = constrain(ye, ("experts", "batch", None, None, None))
    yb = jnp.moveaxis(ye, 0, 2).reshape(B, ng, E * C, d)
    yb = jnp.concatenate([yb, jnp.zeros((B, ng, 1, d), yb.dtype)], axis=2)

    # gather each choice's output back to its token, weighted by router prob
    out = jnp.zeros((B, ng, gs, d), F32)
    for ki in range(k):
        got = jnp.take_along_axis(yb, dest[..., ki][..., None], axis=2)
        out = out + top_p[..., ki][..., None] * got.astype(F32)

    top1 = jax.nn.one_hot(top_i[..., 0], E, dtype=F32)
    f_e = jnp.mean(top1, axis=(0, 1, 2))
    p_e = jnp.mean(probs, axis=(0, 1, 2))
    aux = E * jnp.sum(f_e * p_e)
    return out.astype(x.dtype).reshape(B, S, d), aux


def moe_mlp(x: jax.Array, router: jax.Array,
            w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array, *,
            n_experts: int, k: int, capacity_factor: float = 1.25,
            group_size: int = 1024,
            constrain=lambda t, axes: t):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    router: (d, E); w_gate/w_up: (E, d, f); w_down: (E, f, d).
    ``constrain(tensor, logical_axes)`` applies sharding constraints.
    """
    B, S, d = x.shape
    E = n_experts
    gs = min(group_size, S)
    assert S % gs == 0, (S, gs)
    ng = S // gs
    C = capacity(gs, E, k, capacity_factor)

    xg = x.reshape(B, ng, gs, d)
    logits = jnp.einsum("bnsd,de->bnse", xg, router,
                        preferred_element_type=F32)          # (B,ng,gs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = lax.top_k(probs, k)                        # (B,ng,gs,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # --- capacity assignment (per group), GShard order: k-th choice after all
    # (k-1)-th choices.  The (gs,E,C) combine tensor is built in the model
    # dtype: probabilities and one-hots are bf16-exact enough, and this
    # tensor dominates MoE HBM traffic (§Perf it-5).
    combine = jnp.zeros((B, ng, gs, E, C), x.dtype)
    counts = jnp.zeros((B, ng, E), F32)                       # expert fill
    for ki in range(k):
        oh = jax.nn.one_hot(top_i[..., ki], E, dtype=F32)     # (B,ng,gs,E)
        pos = jnp.cumsum(oh, axis=2) - oh + counts[:, :, None]
        keep = oh * (pos < C)
        slot = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=x.dtype)
        combine = combine + (top_p[..., ki, None, None]
                             * keep[..., None]).astype(x.dtype) * slot
        counts = counts + jnp.sum(oh, axis=2)

    dispatch = (combine > 0).astype(x.dtype)                  # (B,ng,gs,E,C)
    xe = jnp.einsum("bnsec,bnsd->ebncd", dispatch, xg)        # (E,B,ng,C,d)
    xe = constrain(xe, ("experts", "batch", None, None, None))
    ye = _expert_ffn(xe, w_gate, w_up, w_down)                # (E,B,ng,C,d)
    ye = constrain(ye, ("experts", "batch", None, None, None))
    y = jnp.einsum("bnsec,ebncd->bnsd", combine.astype(x.dtype), ye)

    # load-balance aux loss (Switch/GShard): E * sum_e f_e * p_e
    top1 = jax.nn.one_hot(top_i[..., 0], E, dtype=F32)
    f_e = jnp.mean(top1, axis=(0, 1, 2))                      # fraction routed
    p_e = jnp.mean(probs, axis=(0, 1, 2))                     # mean router prob
    aux = E * jnp.sum(f_e * p_e)
    return y.reshape(B, S, d), aux
