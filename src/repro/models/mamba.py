"""Mamba2 (SSD — state-space duality) layer, chunked for the MXU.

Training/prefill uses the chunked SSD algorithm: within a chunk the recurrence
is expanded into a masked "attention-like" matmul (MXU-friendly); across
chunks a ``lax.scan`` carries the (heads, headdim, state) SSM state.  Decode
is the O(1) recurrent update.

Sharding: d_inner (and thus SSD heads) shard over `model`; B/C projections
(single group, shared across heads) are replicated; out_proj contracts the
sharded inner dim → one all-reduce per layer (Megatron-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv via shifts.  x: (B,S,Ch), w: (W,Ch).

    If ``state`` (B, W-1, Ch) is given (decode), it prefixes x.
    Returns (y, new_state).
    """
    W = w.shape[0]
    if state is not None:
        xs = jnp.concatenate([state, x], axis=1)             # (B, S+W-1, Ch)
    else:
        xs = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    S = x.shape[1]
    y = sum(xs[:, i:i + S, :] * w[i][None, None, :] for i in range(W))
    new_state = xs[:, -(W - 1):, :] if W > 1 else None
    return y, new_state


def ssd_chunked(xh, dt, A, Bm, Cm, *, chunk: int):
    """Chunked SSD scan.

    xh: (B,S,H,P) inputs per head; dt: (B,S,H) step sizes (post-softplus, f32);
    A: (H,) negative decay rates; Bm, Cm: (B,S,N) input/output projections
    (single group).  Returns y: (B,S,H,P).
    """
    Bb, S, H, P = xh.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    S0 = S
    if S % L:  # pad tail: dt=0 => unit decay, zero update => state-exact
        pad = L - S % L
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // L

    xc = xh.reshape(Bb, nc, L, H, P)
    dtc = dt.reshape(Bb, nc, L, H).astype(F32)
    Bc = Bm.reshape(Bb, nc, L, N).astype(F32)
    Cc = Cm.reshape(Bb, nc, L, N).astype(F32)

    a = A[None, None, None, :] * dtc                          # (B,nc,L,H) <= 0
    cum = jnp.cumsum(a, axis=2)                               # inclusive
    xdt = (xc.astype(F32) * dtc[..., None])                   # (B,nc,L,H,P)

    # ---- intra-chunk: masked decay "attention" (Pallas-fusable region:
    # the (L,L,H) decay/score tensors stay in VMEM on TPU)
    with jax.named_scope("kernel_ssd_intra"):
        CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                        preferred_element_type=F32)           # (B,nc,L,L)
        decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
        mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
        M = CB[..., None] * decay * mask[None, None, :, :, None]
        y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xdt)

        # chunk states: S_c = sum_j exp(cum_L - cum_j) B_j (dt_j x_j)
        seg = jnp.exp(cum[:, :, -1:, :] - cum)                # (B,nc,L,H)
        states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, seg, xdt)

    # ---- inter-chunk scan
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (B,nc,H)

    def step(s, inp):
        st_c, dec = inp                                       # (B,H,P,N),(B,H)
        s_new = s * dec[..., None, None] + st_c
        return s_new, s                                       # emit state at chunk START

    s0 = jnp.zeros((Bb, H, P, N), F32)
    s_final, s_prev = lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_prev = jnp.moveaxis(s_prev, 0, 1)                       # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", Cc, jnp.exp(cum), s_prev)
    y = (y_intra + y_inter).reshape(Bb, S, H, P)[:, :S0]
    return y.astype(xh.dtype), s_final


def mamba_forward(x, p, cfg, *, mode: str = "train", cache=None,
                  constrain=lambda t, axes: t):
    """Full Mamba2 block (pre-norm residual handled by caller).

    x: (B,S,d).  mode: train | prefill | decode.  For decode, ``cache`` holds
    ``conv_x`` (B,W-1,di), ``conv_bc`` (B,W-1,2N), ``ssm`` (B,H,P,N); prefill
    emits the same structure.
    Returns (y (B,S,d), new_cache_or_None, aux_state_norm scalar).
    """
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    H, P = cfg.mamba_heads, cfg.mamba_headdim
    W = cfg.mamba_conv

    zx = jnp.einsum("bsd,dz->bsz", x, p["w_xz"])              # (B,S,2di)
    z, xin = jnp.split(zx, 2, axis=-1)
    bc = jnp.einsum("bsd,dn->bsn", x, p["w_bc"])              # (B,S,2N)
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])              # (B,S,H)

    conv_x_state = cache["conv_x"] if mode == "decode" else None
    conv_bc_state = cache["conv_bc"] if mode == "decode" else None
    xin, new_conv_x = _causal_conv(xin, p["conv_x"], conv_x_state)
    bc, new_conv_bc = _causal_conv(bc, p["conv_bc"], conv_bc_state)
    xin = jax.nn.silu(xin.astype(F32)).astype(x.dtype)
    bc = jax.nn.silu(bc.astype(F32)).astype(x.dtype)
    Bm, Cm = jnp.split(bc, 2, axis=-1)

    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(F32))                      # (H,)
    xh = xin.reshape(B, S, H, P)
    xh = constrain(xh, ("batch", None, "mamba_heads", None))

    new_cache = None
    if mode == "decode":  # S == 1, O(1) recurrence
        s = cache["ssm"].astype(F32)                          # (B,H,P,N)
        a1 = jnp.exp(A[None, :] * dt[:, 0])                   # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0].astype(F32), dt[:, 0],
                         xh[:, 0].astype(F32))
        s_new = s * a1[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(F32), s_new)
        y = y[:, None]                                        # (B,1,H,P)
        new_cache = {"conv_x": new_conv_x, "conv_bc": new_conv_bc,
                     "ssm": s_new.astype(cache["ssm"].dtype)}
        state_norm = jnp.mean(s_new * s_new)
    else:
        y, s_final = ssd_chunked(xh, dt, A, Bm, Cm, chunk=cfg.mamba_chunk)
        y = y.astype(F32)
        if mode == "prefill":
            new_cache = {"conv_x": new_conv_x, "conv_bc": new_conv_bc,
                         "ssm": s_final.astype(x.dtype)}
        state_norm = jnp.mean(y * y)

    y = y + p["D"].astype(F32)[None, None, :, None] * xh.astype(F32)
    y = y.reshape(B, S, di).astype(x.dtype)
    from repro.models.layers import gated_rms_norm
    y = gated_rms_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return out, new_cache, state_norm
