"""Unified decoder stack covering all 10 assigned architectures.

Layers are grouped into the arch's repeating ``pattern`` of slots; per-slot
parameters are stacked across ``n_repeat`` repeats and the stack runs under
``lax.scan`` (optionally ``jax.checkpoint``-ed), keeping HLO size O(pattern).

Three modes share one trunk:
  * ``train``   — full-seq forward, loss, no cache
  * ``prefill`` — full-seq forward, emits KV/SSM caches + last-position logits
  * ``decode``  — single token, reads+updates caches

Broker taps (the paper's technique): every repeat emits a per-sample residual
norm and a strided ``snapshot`` vector.  Batch stays sharded over ``data``, so
each data-slice of the mesh is a "process region" in ElasticBroker terms — the
host-side broker (repro.core) fetches its addressable shards and streams them
to Cloud endpoints.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL, ATTN_LOCAL, MAMBA
from repro.models import layers as L
from repro.models.modules import ParamSpec, SpecTree
from repro.models.moe import moe_mlp, moe_mlp_scatter
from repro.models.mamba import mamba_forward

F32 = jnp.float32
Constrain = Callable[[jax.Array, tuple], jax.Array]
_ID: Constrain = lambda t, axes: t


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def build_specs(cfg: ArchConfig) -> SpecTree:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    Hp, K = cfg.padded_heads, cfg.n_kv_heads
    R = cfg.n_repeat
    Vp = cfg.padded_vocab

    def P(*shape, axes, **kw):
        return ParamSpec((R, *shape), ("layers", *axes), **kw)

    def attn_specs(prefix=""):
        return {
            prefix + "wq": P(d, Hp, hd, axes=("embed", "heads", "head_dim")),
            prefix + "wk": P(d, K, hd, axes=("embed", "kv_heads", "head_dim")),
            prefix + "wv": P(d, K, hd, axes=("embed", "kv_heads", "head_dim")),
            prefix + "wo": P(Hp, hd, d, axes=("heads", "head_dim", "embed")),
        }

    def mlp_specs():
        return {
            "w_gate": P(d, cfg.d_ff, axes=("embed", "ffn")),
            "w_up": P(d, cfg.d_ff, axes=("embed", "ffn")),
            "w_down": P(cfg.d_ff, d, axes=("ffn", "embed")),
        }

    def moe_specs():
        f = cfg.moe_d_ff or cfg.d_ff
        E = cfg.n_experts
        sp = {
            "router": P(d, E, axes=("embed", "experts"), init="small_normal"),
            "e_gate": P(E, d, f, axes=("experts", "embed", "ffn_e")),
            "e_up": P(E, d, f, axes=("experts", "embed", "ffn_e")),
            "e_down": P(E, f, d, axes=("experts", "ffn_e", "embed")),
        }
        if cfg.moe_dense_residual:
            sp.update({k + "_res": v for k, v in mlp_specs().items()})
        return sp

    def mamba_specs():
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.mamba_heads
        return {
            "w_xz": P(d, 2 * di, axes=("embed", "inner")),
            "w_bc": P(d, 2 * N, axes=("embed", None)),
            "w_dt": P(d, H, axes=("embed", "mamba_heads"), init="small_normal"),
            "conv_x": P(cfg.mamba_conv, di, axes=(None, "inner"), init="small_normal"),
            "conv_bc": P(cfg.mamba_conv, 2 * N, axes=(None, None), init="small_normal"),
            "A_log": P(H, axes=("mamba_heads",), init="zeros"),
            "D": P(H, axes=("mamba_heads",), init="zeros"),
            "dt_bias": P(H, axes=("mamba_heads",), init="zeros"),
            "norm": P(di, axes=("inner",), init="zeros"),
            "w_out": P(di, d, axes=("inner", "embed")),
        }

    slots = []
    for slot in cfg.pattern:
        sp: dict[str, Any] = {"norm1": P(d, axes=("embed",), init="zeros")}
        if slot.kind in (ATTN_GLOBAL, ATTN_LOCAL):
            sp.update(attn_specs())
        elif slot.kind == MAMBA:
            sp.update(mamba_specs())
        if slot.cross_attn:
            sp["xnorm"] = P(d, axes=("embed",), init="zeros")
            sp.update(attn_specs("x"))
        if cfg.d_ff or (slot.moe and cfg.n_experts):
            sp["norm2"] = P(d, axes=("embed",), init="zeros")
            sp.update(moe_specs() if (slot.moe and cfg.n_experts) else mlp_specs())
        slots.append(sp)

    specs: SpecTree = {
        "embed": ParamSpec((Vp, d), ("vocab", "embed"), init="small_normal"),
        "final_norm": ParamSpec((d,), ("embed",), init="zeros"),
        "slots": tuple(slots),
    }
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((d, Vp), ("embed", "vocab"))
    return specs


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------

def build_cache_specs(cfg: ArchConfig, batch: int, max_seq: int) -> SpecTree:
    """Spec tree for the serve-time cache (logical axes included)."""
    hd, K, R = cfg.resolved_head_dim, cfg.n_kv_heads, cfg.n_repeat
    di, N = cfg.d_inner, cfg.ssm_state
    H, Pd, W = cfg.mamba_heads, cfg.mamba_headdim, cfg.mamba_conv

    def C(*shape, axes):
        return ParamSpec((R, *shape), ("layers", *axes))

    slots = []
    for slot in cfg.pattern:
        sp = {}
        if slot.kind in (ATTN_GLOBAL, ATTN_LOCAL):
            sp["k"] = C(batch, max_seq, K, hd,
                        axes=("batch", "cache_seq", "kv_heads", "head_dim"))
            sp["v"] = C(batch, max_seq, K, hd,
                        axes=("batch", "cache_seq", "kv_heads", "head_dim"))
        elif slot.kind == MAMBA:
            sp["conv_x"] = C(batch, W - 1, di, axes=("batch", None, "inner"))
            sp["conv_bc"] = C(batch, W - 1, 2 * N, axes=("batch", None, None))
            sp["ssm"] = C(batch, H, Pd, N,
                          axes=("batch", "mamba_heads", None, None))
        if slot.cross_attn:
            sp["xk"] = C(batch, cfg.n_frontend_tokens, K, hd,
                         axes=("batch", None, "kv_heads", "head_dim"))
            sp["xv"] = C(batch, cfg.n_frontend_tokens, K, hd,
                         axes=("batch", None, "kv_heads", "head_dim"))
        slots.append(sp)
    return {"slots": tuple(slots)}


# ---------------------------------------------------------------------------
# Slot application
# ---------------------------------------------------------------------------

@dataclass
class Ctx:
    cfg: ArchConfig
    mode: str                          # train | prefill | decode
    positions: jax.Array               # (S,) absolute positions
    pos: Any = None                    # decode write index (scalar) or None
    frontend: Any = None               # (B, Tf, d) embeddings or None
    constrain: Constrain = _ID


def _project_qkv(p, x, ctx, prefix=""):
    cfg = ctx.cfg
    q = jnp.einsum("bsd,dhe->bshe", x, p[prefix + "wq"])
    k = jnp.einsum("bsd,dke->bske", x, p[prefix + "wk"])
    v = jnp.einsum("bsd,dke->bske", x, p[prefix + "wv"])
    return q, k, v


def _out_proj(p, o, ctx, prefix=""):
    """Heads are laid out kv-group-major: h = k*Gp + g with Gp = Hp/K slots
    per kv head, of which G_real = H/K are real — so padded heads keep the
    canonical GQA mapping (head h -> kv h//G_real among real heads).  Pad
    slots (g >= G_real) are masked to zero here, making outputs exact."""
    cfg = ctx.cfg
    Hp, K = cfg.padded_heads, cfg.n_kv_heads
    gp, g_real = Hp // K, cfg.n_heads // K
    mask = ((jnp.arange(Hp) % gp) < g_real).astype(o.dtype)
    wo = p[prefix + "wo"] * mask[:, None, None]
    return jnp.einsum("bshe,hed->bsd", o, wo)


def _self_attention(slot: LayerSpec, p, h, ctx: Ctx, cache):
    cfg = ctx.cfg
    x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
    q, k, v = _project_qkv(p, x, ctx)
    q = L.apply_rope(q, ctx.positions, cfg.rope_theta)
    k = L.apply_rope(k, ctx.positions, cfg.rope_theta)
    window = cfg.local_window if slot.kind == ATTN_LOCAL else None
    new_cache = {}
    if ctx.mode == "decode":
        kc = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, ctx.pos, 0, 0))
        vc = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, ctx.pos, 0, 0))
        o = L.decode_attention(q, kc, vc, cache_len=ctx.pos + 1, window=window)
        new_cache = {"k": kc, "v": vc}
    else:
        if slot.kind == ATTN_LOCAL:
            o = L.local_block_attention(q, k, v, window=window)
        else:
            o = L.flash_attention(q, k, v, causal=True)
        if ctx.mode == "prefill":
            new_cache = {"k": k.astype(h.dtype), "v": v.astype(h.dtype)}
    return h + _out_proj(p, o, ctx), new_cache


def _cross_attention(p, h, ctx: Ctx, cache):
    cfg = ctx.cfg
    x = L.rms_norm(h, p["xnorm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhe->bshe", x, p["xwq"])
    new_cache = {}
    if ctx.mode == "decode":
        xk, xv = cache["xk"], cache["xv"]
        new_cache = {"xk": xk, "xv": xv}
    else:
        f = ctx.frontend
        xk = jnp.einsum("btd,dke->btke", f, p["xwk"])
        xv = jnp.einsum("btd,dke->btke", f, p["xwv"])
        if ctx.mode == "prefill":
            new_cache = {"xk": xk.astype(h.dtype), "xv": xv.astype(h.dtype)}
    o = L.cross_attention(q, xk, xv)
    return h + _out_proj(p, o, ctx, "x"), new_cache


def _mlp_block(slot: LayerSpec, p, h, ctx: Ctx):
    cfg = ctx.cfg
    if not (cfg.d_ff or (slot.moe and cfg.n_experts)):
        return h, jnp.zeros((), F32)
    x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
    aux = jnp.zeros((), F32)
    if slot.moe and cfg.n_experts:
        impl = moe_mlp_scatter if cfg.moe_impl == "scatter" else moe_mlp
        y, aux = impl(
            x, p["router"], p["e_gate"], p["e_up"], p["e_down"],
            n_experts=cfg.n_experts, k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
            group_size=cfg.moe_group_size if ctx.mode != "decode" else 1,
            constrain=ctx.constrain)
        if cfg.moe_dense_residual:
            y = y + L.gated_mlp(x, p["w_gate_res"], p["w_up_res"], p["w_down_res"])
    else:
        y = L.gated_mlp(x, p["w_gate"], p["w_up"], p["w_down"])
    return h + y, aux


def apply_slot(slot: LayerSpec, p, h, ctx: Ctx, cache):
    """Returns (h, new_cache, aux_loss, tap_scalar)."""
    aux = jnp.zeros((), F32)
    new_cache = {}
    if slot.kind in (ATTN_GLOBAL, ATTN_LOCAL):
        h, nc = _self_attention(slot, p, h, ctx, cache)
        new_cache.update(nc)
    elif slot.kind == MAMBA:
        x = L.rms_norm(h, p["norm1"], ctx.cfg.norm_eps)
        y, mc, _ = mamba_forward(x, p, ctx.cfg,
                                 mode=ctx.mode, cache=cache or None,
                                 constrain=ctx.constrain)
        h = h + y
        if mc is not None:
            new_cache.update(mc)
    if slot.cross_attn:
        h, nc = _cross_attention(p, h, ctx, cache)
        new_cache.update(nc)
    h, moe_aux = _mlp_block(slot, p, h, ctx)
    return h, new_cache, aux + moe_aux


# ---------------------------------------------------------------------------
# Trunk
# ---------------------------------------------------------------------------

def _tap(cfg: ArchConfig, h: jax.Array) -> dict:
    """Per-sample field tap: residual norm + strided snapshot (B, tap_dim)."""
    hf = h.astype(F32)
    norm = jnp.sqrt(jnp.mean(hf * hf, axis=(1, 2)))           # (B,)
    stride = max(1, cfg.d_model // cfg.tap_snapshot_dim)
    snap = jnp.mean(hf, axis=1)[:, ::stride][:, : cfg.tap_snapshot_dim]
    return {"resid_norm": norm, "snapshot": snap}


def trunk(cfg: ArchConfig, params, h, ctx: Ctx, cache=None):
    """Scan the stacked pattern over repeats.

    h: (B, S, d).  cache: stacked cache pytree or None.
    Returns (h, new_cache_or_None, aux_loss, taps).
    """
    slots_params = params["slots"]
    have_cache = cache is not None
    xs = (slots_params, cache["slots"]) if have_cache else (slots_params,)

    # group k=remat_block repeats per checkpointed scan step (train only):
    # boundary stash shrinks k-fold at no extra recompute
    k = cfg.remat_block if (ctx.mode == "train" and cfg.remat
                            and cfg.n_repeat % max(cfg.remat_block, 1) == 0) else 1

    # Activation layout: train/prefill shard the batch (data-parallel).
    # Decode is weight-stationary: activations are tiny (B,1,d), so we shard
    # their *feature* dim over `data` to line up with the FSDP weight shards —
    # GSPMD then computes partial sums and all-reduces the (B,1,f) activations
    # instead of all-gathering ~50 GB of weights per token (§Perf it-6).
    act_axes = (("batch", None, None) if ctx.mode != "decode"
                else (None, None, "embed"))

    def one_repeat(x, sp, cs):
        new_cs = []
        aux = jnp.zeros((), F32)
        for i, slot in enumerate(cfg.pattern):
            x = ctx.constrain(x, act_axes)
            x, nc, a = apply_slot(slot, sp[i], x, ctx, cs[i])
            new_cs.append(nc)
            aux = aux + a
        return x, tuple(new_cs), aux

    def block(carry, xs_slice):
        x = carry
        sp = xs_slice[0]
        cs = xs_slice[1] if have_cache else None
        aux = jnp.zeros((), F32)
        if k == 1:
            x, new_cs, aux = one_repeat(
                x, sp, cs if cs is not None else tuple({} for _ in cfg.pattern))
        else:  # k inner repeats; params carry a (k, ...) leading dim
            for j in range(k):
                spj = jax.tree.map(lambda t: t[j], sp)
                x, _, a = one_repeat(x, spj, tuple({} for _ in cfg.pattern))
                aux = aux + a
            new_cs = tuple({} for _ in cfg.pattern)
        ys = {"aux": aux, "tap": _tap(cfg, x)}
        if have_cache or ctx.mode == "prefill":
            ys["cache"] = new_cs
        return x, ys

    if k > 1:
        xs = jax.tree.map(
            lambda t: t.reshape(cfg.n_repeat // k, k, *t.shape[1:]), xs)
    block_fn = jax.checkpoint(block) if (cfg.remat and ctx.mode == "train") else block
    h, ys = lax.scan(block_fn, h, xs)
    new_cache = {"slots": ys["cache"]} if "cache" in ys else None
    return h, new_cache, jnp.sum(ys["aux"]), ys["tap"]


@jax.custom_vjp
def _grad_barrier_bf16(x):
    """Identity fwd; casts the cotangent to bf16.

    Without this, the f32 loss head poisons the whole backward pass: dot
    cotangents stay f32, so every bwd weight all-gather, dx all-reduce and
    grad reduction moves twice the bytes (measured: llama3 train collectives
    were 100% f32 — EXPERIMENTS.md §Perf iteration 1)."""
    return x


def _gb_fwd(x):
    return x, None


def _gb_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


_grad_barrier_bf16.defvjp(_gb_fwd, _gb_bwd)


def embed_inputs(cfg: ArchConfig, params, batch: dict, ctx: Ctx) -> jax.Array:
    """tokens (B,S) -> (B,S,d); audio frontend feeds embeddings directly."""
    if cfg.frontend == "audio" and "frames" in batch:
        return batch["frames"].astype(cfg.dtype)
    tok = batch["tokens"]
    h = jnp.take(params["embed"], tok, axis=0)
    return ctx.constrain(h, ("batch", None, None))


def lm_head(cfg: ArchConfig, params, h: jax.Array) -> jax.Array:
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["head"] if "head" in params else params["embed"].T
    return jnp.einsum("bsd,dv->bsv", h, w, preferred_element_type=F32)


# ---------------------------------------------------------------------------
# Top-level model functions
# ---------------------------------------------------------------------------

def loss_fn(cfg: ArchConfig, params, batch: dict, constrain: Constrain = _ID):
    """Train-mode forward + softmax xent (+ z-loss + MoE aux)."""
    S = (batch["frames"].shape[1] if cfg.frontend == "audio" and "frames" in batch
         else batch["tokens"].shape[1])
    ctx = Ctx(cfg=cfg, mode="train", positions=jnp.arange(S),
              frontend=batch.get("frontend"), constrain=constrain)
    h = embed_inputs(cfg, params, batch, ctx)
    h, _, aux, taps = trunk(cfg, params, h, ctx)
    if cfg.dtype == jnp.bfloat16:
        h = _grad_barrier_bf16(h)   # keep the trunk backward pass in bf16
    logits = lm_head(cfg, params, h)                          # (B,S,Vp) f32
    labels = batch["labels"]
    mask = (labels >= 0).astype(F32)
    labels = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)                   # (B,S)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    xent = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    zloss = 1e-4 * jnp.mean(lse * lse)
    total = xent + zloss + 0.01 * aux
    metrics = {"loss": xent, "zloss": zloss, "moe_aux": aux}
    return total, (metrics, taps)


def prefill(cfg: ArchConfig, params, batch: dict, constrain: Constrain = _ID):
    """Fill caches for S tokens; return last-position logits + cache + taps."""
    S = (batch["frames"].shape[1] if cfg.frontend == "audio" and "frames" in batch
         else batch["tokens"].shape[1])
    ctx = Ctx(cfg=cfg, mode="prefill", positions=jnp.arange(S),
              frontend=batch.get("frontend"), constrain=constrain)
    h = embed_inputs(cfg, params, batch, ctx)
    h, cache, _, taps = trunk(cfg, params, h, ctx)
    logits = lm_head(cfg, params, h[:, -1:, :])
    return logits[:, 0], cache, taps


def decode_step(cfg: ArchConfig, params, cache, tokens, pos,
                constrain: Constrain = _ID, frontend=None):
    """One decode step: tokens (B,1) at absolute position ``pos``.

    Returns (next_tokens (B,), new_cache, taps).
    """
    ctx = Ctx(cfg=cfg, mode="decode", positions=pos + jnp.arange(1), pos=pos,
              frontend=frontend, constrain=constrain)
    h = jnp.take(params["embed"], tokens, axis=0)
    h = constrain(h, ("batch", None, None))
    h, new_cache, _, taps = trunk(cfg, params, h, ctx, cache=cache)
    logits = lm_head(cfg, params, h)                          # (B,1,Vp)
    nxt = jnp.argmax(logits[:, 0, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    return nxt, new_cache, taps
