"""Core transformer layers: RMSNorm, RoPE, attention variants, gated MLP.

Attention is implemented as *flash-style chunked attention in pure JAX*
(``lax.scan`` over KV chunks with an online softmax).  This keeps HLO size and
peak memory independent of sequence length, which is what makes the 32k/500k
dry-run cells compile and fit.  The Pallas TPU kernel (``repro.kernels.flash``)
implements the same contract for real hardware and is validated against
``repro.kernels.ref`` in CI; the chunked-JNP path is the portable fallback the
CPU-hosted dry-run lowers.

Head layout & sharding
----------------------
Q heads are padded to a multiple of the `model` mesh axis (``Hp``), with the
pad rows of ``wo`` masked to zero so outputs are exact — this keeps attention
tensor-parallel even for head counts like 56/40/24 that 16 does not divide.
Head ``h`` uses KV head ``h // (Hp//K)`` (k-major).  GQA broadcast happens
per-KV-chunk via ``jnp.repeat`` of a *replicated* (or K-sharded) chunk, which
GSPMD materializes as a local slice — no collective, no full-size temp.
Decode uses the grouped ``(B,K,G,D)`` einsum instead (no repeat at all) so a
seq-sharded cache keeps scores seq-sharded and softmax reduces via GSPMD
all-reduces (flash-decode equivalent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(F32))).astype(dt)


def gated_rms_norm(x: jax.Array, gate: jax.Array, weight: jax.Array,
                   eps: float = 1e-5) -> jax.Array:
    """Mamba2-style: normalize x * silu(gate)."""
    return rms_norm(x * jax.nn.silu(gate.astype(F32)).astype(x.dtype), weight, eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, head_dim); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    angles = positions[..., :, None].astype(F32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (pure JAX)
# ---------------------------------------------------------------------------

def _expand_kv(k: jax.Array, heads: int) -> jax.Array:
    """(B, T, K, D) -> (B, T, heads, D), k-major repeat (head h -> kv h//G)."""
    g = heads // k.shape[2]
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def _chunk_attend(q, k, v, mask, scale):
    """One KV chunk with flat padded heads.  All f32.

    q: (B, Q, H, D); k/v: (B, T, Kh, D); mask: (Q, T) True=keep.
    Returns row-max m (B,H,Q), exp-sum l (B,H,Q), weighted values o (B,Q,H,D).
    """
    H = q.shape[2]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    s = jnp.einsum("bqhd,bthd->bhqt", q.astype(F32), k.astype(F32),
                   preferred_element_type=F32) * scale
    s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                # (B,H,Q)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(m[..., None] > NEG_INF / 2, p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", p, v.astype(F32))
    return m, l, o


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    q_offset: int | jax.Array = 0,
                    kv_len: int | jax.Array | None = None,
                    chunk: int = 512) -> jax.Array:
    """Online-softmax attention over KV chunks.

    q: (B, S, H, D); k, v: (B, T, Kh, D) with Kh | H.
    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``kv_len``: number of valid cache positions (masks the rest).
    ``window``: sliding-window size (local attention).
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    scale = 1.0 / (D ** 0.5)
    chunk = min(chunk, T)
    n_chunks = (T + chunk - 1) // chunk
    Tp = n_chunks * chunk
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, *k.shape[2:]), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, *v.shape[2:]), 1, 0)

    q_pos = q_offset + jnp.arange(S)
    valid_t = T if kv_len is None else kv_len

    def body(carry, xs):
        m_prev, l_prev, o_prev = carry
        kj, vj, j = xs
        t_pos = j * chunk + jnp.arange(chunk)
        mask = t_pos[None, :] < valid_t
        if causal:
            mask = mask & (t_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (t_pos[None, :] > q_pos[:, None] - window)
        mask = jnp.broadcast_to(mask, (S, chunk))
        m_j, l_j, o_j = _chunk_attend(q, kj, vj, mask, scale)
        m_new = jnp.maximum(m_prev, m_j)
        a_prev = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
        a_j = jnp.where(m_j > NEG_INF / 2, jnp.exp(m_j - m_new), 0.0)
        l_new = l_prev * a_prev + l_j * a_j
        o_new = (o_prev * jnp.moveaxis(a_prev, 1, 2)[..., None]
                 + o_j * jnp.moveaxis(a_j, 1, 2)[..., None])
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, H, S), NEG_INF, F32)
    l0 = jnp.zeros((B, H, S), F32)
    o0 = jnp.zeros((B, S, H, D), F32)
    # named scope == Pallas-kernel boundary: everything inside runs
    # VMEM-resident in kernels/flash.py on TPU; the roofline analyzer uses
    # the marker to account it as fused (EXPERIMENTS.md §Perf it-2)
    with jax.named_scope("kernel_flash_kv_scan"):
        (m, l, o), _ = lax.scan(body, (m0, l0, o0),
                                (kc, vc, jnp.arange(n_chunks)))
        l = jnp.maximum(l, 1e-30)
        o = o / jnp.moveaxis(l, 1, 2)[..., None]
    return o.astype(q.dtype)


def local_block_attention(q, k, v, *, window: int) -> jax.Array:
    """Banded sliding-window attention: each q block (size=window) attends to
    itself + the previous block — exact for window <= block size, and only 2x
    the optimal FLOPs (vs S/window for a full masked matrix)."""
    B, S, H, D = q.shape
    w = window
    if S <= w:  # degenerate: plain causal attention
        return flash_attention(q, k, v, causal=True, chunk=min(512, S))
    assert S % w == 0, f"seq {S} % window {w} != 0"
    nb = S // w
    Kh = k.shape[2]
    qb = q.reshape(B, nb, w, H, D)
    kb = k.reshape(B, nb, w, Kh, D)
    vb = v.reshape(B, nb, w, Kh, D)
    k2 = jnp.concatenate([jnp.roll(kb, 1, axis=1), kb], axis=2)  # (B,nb,2w,Kh,D)
    v2 = jnp.concatenate([jnp.roll(vb, 1, axis=1), vb], axis=2)
    scale = 1.0 / (D ** 0.5)

    def one_block(args):
        qi, ki, vi, i = args          # (B,w,H,D), (B,2w,Kh,D)
        ki = _expand_kv(ki, H)
        vi = _expand_kv(vi, H)
        s = jnp.einsum("bqhd,bthd->bhqt", qi.astype(F32), ki.astype(F32),
                       preferred_element_type=F32) * scale
        qpos = jnp.arange(w)
        tpos = jnp.arange(2 * w) - w
        mask = (tpos[None, :] <= qpos[:, None]) & (tpos[None, :] > qpos[:, None] - w)
        mask = mask & ((i > 0) | (tpos[None, :] >= 0))
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqt,bthd->bqhd", p, vi.astype(F32))

    with jax.named_scope("kernel_local_attn"):
        o = lax.map(one_block, (jnp.moveaxis(qb, 1, 0), jnp.moveaxis(k2, 1, 0),
                                jnp.moveaxis(v2, 1, 0), jnp.arange(nb)))
    return jnp.moveaxis(o, 0, 1).reshape(B, S, H, D).astype(q.dtype)


def cross_attention(q, k, v, *, q_chunk: int = 2048) -> jax.Array:
    """Unmasked cross-attention (text q over frontend kv), q-chunked."""
    B, S, H, D = q.shape
    scale = 1.0 / (D ** 0.5)
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)

    def one(qblk):
        s = jnp.einsum("bqhd,bthd->bhqt", qblk.astype(F32), k.astype(F32),
                       preferred_element_type=F32) * scale
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqt,bthd->bqhd", p, v.astype(F32))

    qc = min(q_chunk, S)
    if S <= qc:
        return one(q).astype(q.dtype)
    assert S % qc == 0, (S, qc)
    nq = S // qc
    qs = jnp.moveaxis(q.reshape(B, nq, qc, H, D), 1, 0)
    o = lax.map(one, qs)
    return jnp.moveaxis(o, 0, 1).reshape(B, S, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, cache_len, window=None) -> jax.Array:
    """Single-position attention against a (possibly seq-sharded) KV cache.

    q: (B, 1, H, D); caches: (B, T, Kh, D).  Grouped (B,K,G,D) einsum — no KV
    repeat, scores stay seq-sharded, softmax reduces over the sharded T axis
    via GSPMD all-reduces (flash-decode equivalent).
    """
    B, _, H, D = q.shape
    T, Kh = k_cache.shape[1], k_cache.shape[2]
    G = H // Kh
    scale = 1.0 / (D ** 0.5)
    qg = q[:, 0].reshape(B, Kh, G, D)                      # k-major: h = k*G+g
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(F32), k_cache.astype(F32),
                   preferred_element_type=F32) * scale
    t = jnp.arange(T)[None, None, None, :]
    mask = t < cache_len
    if window is not None:
        mask = mask & (t > cache_len - 1 - window)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(F32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def gated_mlp(x, w_gate, w_up, w_down) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w_gate).astype(F32)).astype(x.dtype)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    return jnp.einsum("bsf,fd->bsd", h * u, w_down)
