"""Minimal pure-JAX parameter/module system.

Parameters are nested dicts of arrays.  Each leaf has a parallel
:class:`ParamSpec` describing its shape, dtype, init scale and **logical axis
names** — the sharding layer (``repro.launch.shardings``) maps logical names to
mesh axes with divisibility fallback.  This mirrors MaxText's
``logical_axis_rules`` without a flax dependency.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones | small_normal
    scale: float | None = None            # None -> 1/sqrt(fan_in)
    dtype: Any = None                     # None -> model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


SpecTree = dict  # nested dict[str, ParamSpec | SpecTree]


def tree_map_specs(fn: Callable[[ParamSpec], Any], specs: SpecTree):
    """Map over a spec tree, preserving structure."""
    return jax.tree.map(fn, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def materialize(specs: SpecTree, key: jax.Array, dtype) -> dict:
    """Randomly initialize a parameter tree from its specs."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        dt = spec.dtype or dtype
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dt)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dt)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(fan_in)
            if spec.init == "small_normal":
                scale = 0.02
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dt)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract(specs: SpecTree, dtype, sharding_fn=None) -> dict:
    """ShapeDtypeStruct tree (optionally with shardings) — no allocation."""

    def one(spec: ParamSpec):
        dt = spec.dtype or dtype
        if sharding_fn is None:
            return jax.ShapeDtypeStruct(spec.shape, dt)
        return jax.ShapeDtypeStruct(spec.shape, dt, sharding=sharding_fn(spec))

    return tree_map_specs(one, specs)


def param_bytes(specs: SpecTree, dtype) -> int:
    total = 0
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec)):
        dt = np.dtype(spec.dtype or dtype)
        total += int(np.prod(spec.shape)) * dt.itemsize
    return total
