"""Step factories: build jitted train / prefill / decode steps for an arch.

``make_train_step`` closes over (cfg, optimizer config, constrain) and
implements gradient accumulation over microbatches with ``lax.scan`` — the
activation-memory knob that fits the 398–480B archs on 16 GB v5e chips.
Every step also returns the broker ``taps`` pytree (the paper's in-graph field
extraction); the host-side broker streams the addressable shards.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T
from repro.optim import adamw

F32 = jnp.float32


def _split_microbatches(batch: dict, n_mb: int) -> dict:
    def split(x):
        return x.reshape(n_mb, x.shape[0] // n_mb, *x.shape[1:])
    return {k: split(v) for k, v in batch.items()}


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    n_microbatches: int = 1,
                    constrain: T.Constrain = T._ID) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics, taps)."""

    accum_dtype = jnp.bfloat16 if cfg.opt_8bit else F32

    def loss(params, mb):
        return T.loss_fn(cfg, params, mb, constrain=constrain)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            (_, (metrics, taps)), grads = grad_fn(params, batch)
        else:
            mbs = _split_microbatches(batch, n_microbatches)

            def body(carry, mb):
                acc = carry
                (_, (metrics, taps)), grads = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), acc, grads)
                return acc, (metrics, taps)

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            grads, (metrics_all, taps_all) = jax.lax.scan(body, acc0, mbs)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            metrics = jax.tree.map(lambda x: x[-1], metrics_all)
            taps = jax.tree.map(lambda x: x[-1], taps_all)

        params, opt_state, opt_metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics, taps

    return train_step


def make_prefill_step(cfg: ArchConfig, constrain: T.Constrain = T._ID) -> Callable:
    def prefill_step(params, batch):
        return T.prefill(cfg, params, batch, constrain=constrain)
    return prefill_step


def make_decode_step(cfg: ArchConfig, constrain: T.Constrain = T._ID) -> Callable:
    def serve_step(params, cache, tokens, pos):
        return T.decode_step(cfg, params, cache, tokens, pos,
                             constrain=constrain)
    return serve_step


def step_for_shape(cfg: ArchConfig, shape: ShapeConfig,
                   constrain: T.Constrain = T._ID,
                   opt_cfg: adamw.AdamWConfig | None = None) -> Callable:
    """The lowerable callable for a dry-run cell."""
    if shape.kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig(use_8bit=cfg.opt_8bit)
        return make_train_step(cfg, opt_cfg, shape.microbatches, constrain)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, constrain)
    return make_decode_step(cfg, constrain)
