"""Producer-rank -> group -> endpoint mapping (paper §3.1, Fig 1).

The paper divides MPI processes into groups; each group registers with one
Cloud endpoint (ratio 16:1:16 producers:endpoints:executors in §4.3).  Here
producers are mesh data-slices (or CFD ranks), and the planner picks the
group count from the bandwidth model the paper leaves as future work §6:
outbound per-producer bandwidth vs inbound per-endpoint bandwidth.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass


def partition_of(key: str, n: int) -> int:
    """Stable partition for a record key: crc32, never ``hash``.

    This is the single hash family for every keyed routing decision —
    the shuffle stage, the broker shard map, and the window stripe locks
    all call it, so a key's state and its records can never disagree on
    ownership.  crc32 is stable across processes and Python versions
    (``hash`` is salted per-process and would break replay determinism).
    """
    if n <= 0:
        raise ValueError(f"need n >= 1 partitions, got {n}")
    return zlib.crc32(key.encode()) % n


@dataclass(frozen=True)
class GroupPlan:
    n_producers: int
    n_groups: int                      # == number of endpoints used
    executors_per_group: int

    def group_of(self, rank: int) -> int:
        if not (0 <= rank < self.n_producers):
            raise ValueError(f"rank {rank} out of range [0,{self.n_producers})")
        return rank % self.n_groups     # round-robin keeps groups balanced

    def ranks_in(self, group: int) -> list[int]:
        if not (0 <= group < self.n_groups):
            raise ValueError(f"group {group} out of range [0,{self.n_groups})")
        return list(self._membership[group])

    @property
    def _membership(self) -> tuple[tuple[int, ...], ...]:
        # Built once per plan: rescanning all n_producers per ranks_in()
        # call is quadratic when enumerating every group at 1k-10k streams.
        cached = getattr(self, "_members", None)
        if cached is None:
            members: list[list[int]] = [[] for _ in range(self.n_groups)]
            for r in range(self.n_producers):
                members[self.group_of(r)].append(r)
            cached = tuple(tuple(m) for m in members)
            object.__setattr__(self, "_members", cached)
        return cached

    @property
    def n_executors(self) -> int:
        return self.n_groups * self.executors_per_group


def plan_groups(n_producers: int, *,
                producer_out_bw: float = 1.0e9,
                endpoint_in_bw: float = 10.0e9,
                record_rate_hz: float = 1.0,
                record_bytes: float = 1.0e6,
                executors_per_group: int | None = None,
                max_ratio: int = 16) -> GroupPlan:
    """Pick #endpoints so no endpoint's inbound link saturates.

    demand per producer = record_rate * record_bytes (<= producer_out_bw);
    producers per endpoint = endpoint_in_bw // demand, capped at ``max_ratio``
    (the paper's 16:1 operating point).
    """
    if n_producers <= 0:
        raise ValueError("need >= 1 producer")
    demand = min(record_rate_hz * record_bytes, producer_out_bw)
    per_ep = max(1, min(max_ratio, int(endpoint_in_bw // max(demand, 1.0))))
    n_groups = max(1, (n_producers + per_ep - 1) // per_ep)
    if executors_per_group is None:
        executors_per_group = min(per_ep, max_ratio)   # paper: 16 exec / ep
    return GroupPlan(n_producers=n_producers, n_groups=n_groups,
                     executors_per_group=executors_per_group)
