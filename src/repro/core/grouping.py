"""Producer-rank -> group -> endpoint mapping (paper §3.1, Fig 1).

The paper divides MPI processes into groups; each group registers with one
Cloud endpoint (ratio 16:1:16 producers:endpoints:executors in §4.3).  Here
producers are mesh data-slices (or CFD ranks), and the planner picks the
group count from the bandwidth model the paper leaves as future work §6:
outbound per-producer bandwidth vs inbound per-endpoint bandwidth.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class GroupPlan:
    n_producers: int
    n_groups: int                      # == number of endpoints used
    executors_per_group: int

    def group_of(self, rank: int) -> int:
        if not (0 <= rank < self.n_producers):
            raise ValueError(f"rank {rank} out of range [0,{self.n_producers})")
        return rank % self.n_groups     # round-robin keeps groups balanced

    def ranks_in(self, group: int) -> list[int]:
        return [r for r in range(self.n_producers) if self.group_of(r) == group]

    @property
    def n_executors(self) -> int:
        return self.n_groups * self.executors_per_group


def plan_groups(n_producers: int, *,
                producer_out_bw: float = 1.0e9,
                endpoint_in_bw: float = 10.0e9,
                record_rate_hz: float = 1.0,
                record_bytes: float = 1.0e6,
                executors_per_group: int | None = None,
                max_ratio: int = 16) -> GroupPlan:
    """Pick #endpoints so no endpoint's inbound link saturates.

    demand per producer = record_rate * record_bytes (<= producer_out_bw);
    producers per endpoint = endpoint_in_bw // demand, capped at ``max_ratio``
    (the paper's 16:1 operating point).
    """
    if n_producers <= 0:
        raise ValueError("need >= 1 producer")
    demand = min(record_rate_hz * record_bytes, producer_out_bw)
    per_ep = max(1, min(max_ratio, int(endpoint_in_bw // max(demand, 1.0))))
    n_groups = max(1, (n_producers + per_ep - 1) // per_ep)
    if executors_per_group is None:
        executors_per_group = min(per_ep, max_ratio)   # paper: 16 exec / ep
    return GroupPlan(n_producers=n_producers, n_groups=n_groups,
                     executors_per_group=executors_per_group)
