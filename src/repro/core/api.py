"""The paper's C/C++ API surface (Listing 1.1), 1:1 in Python.

    struct CloudEndpoint endpoints[NUM_GROUPS];
    broker_ctx* broker_init(char* field_name, int group_id);
    broker_write(broker_ctx*, int step, void* data, size_t len);
    broker_finalize(broker_ctx*);

``broker_init`` registers a field + group with the shared Broker (connecting
the calling rank's group to its designated Cloud endpoint); ``broker_write``
converts one in-memory chunk into a stream record and enqueues it on the
asynchronous group sender; ``broker_finalize`` drains and closes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.broker import Broker, BrokerConfig
from repro.core.grouping import GroupPlan, plan_groups
from repro.core.records import FieldSchema


@dataclass
class CloudEndpoint:
    """Paper: {char* service_ip; int service_port;}."""
    service_ip: str
    service_port: int
    handle: object = None          # the in-process Endpoint (Redis stand-in)

    def healthy(self) -> bool:
        return self.handle is not None and self.handle.healthy()

    def push(self, group_id: int, blob: bytes) -> None:
        self.handle.push(group_id, blob)


@dataclass
class broker_ctx:
    broker: Broker
    field_name: str
    rank: int
    group_id: int


_shared_broker: Broker | None = None


def broker_connect(endpoints: list[CloudEndpoint], n_producers: int,
                   cfg: BrokerConfig | None = None,
                   plan: GroupPlan | None = None) -> Broker:
    """Job-level setup: bind the producer job to a set of Cloud endpoints."""
    global _shared_broker
    plan = plan or plan_groups(n_producers,
                               executors_per_group=16)
    plan = GroupPlan(n_producers=n_producers,
                     n_groups=min(plan.n_groups, len(endpoints)),
                     executors_per_group=plan.executors_per_group)
    _shared_broker = Broker(plan, endpoints, cfg)
    return _shared_broker


def broker_init(field_name: str, rank: int, shape=(), dtype="float32",
                broker: Broker | None = None) -> broker_ctx:
    b = broker or _shared_broker
    if b is None:
        raise RuntimeError("call broker_connect(endpoints, n_producers) first")
    g = b.plan.group_of(rank)
    b.register(FieldSchema(field_name=field_name, shape=tuple(shape),
                           dtype=dtype, group_id=g))
    return broker_ctx(broker=b, field_name=field_name, rank=rank, group_id=g)


def broker_write(ctx: broker_ctx, step: int, data, data_len: int | None = None) -> bool:
    arr = np.asarray(data)
    if data_len is not None:
        arr = arr.reshape(-1)[:data_len]
    return ctx.broker.write(ctx.field_name, ctx.rank, step, arr)


def broker_finalize(ctx: broker_ctx):
    return ctx.broker.finalize()
