"""The paper's C/C++ API surface (Listing 1.1), 1:1 in Python.

    struct CloudEndpoint endpoints[NUM_GROUPS];
    broker_ctx* broker_init(char* field_name, int group_id);
    broker_write(broker_ctx*, int step, void* data, size_t len);
    broker_finalize(broker_ctx*);

**Deprecated compatibility shim.**  Since the ``repro.workflow`` redesign
this module is a thin veneer over :class:`repro.workflow.Session`:
``broker_connect`` opens a module-global Session (the C API is inherently
global — Listing 1.1 has no session object to thread through), and every
``broker_ctx`` wraps a typed :class:`repro.workflow.FieldHandle`.  New code
should construct a ``Session`` directly; this surface is kept so the
paper's listings keep running verbatim.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from repro.core.broker import Broker, BrokerConfig, BrokerStats
from repro.core.grouping import GroupPlan, plan_groups
from repro.core.transport import CloudEndpoint  # noqa: F401  (re-export)
from repro.workflow.config import WorkflowConfig
from repro.workflow.session import FieldHandle, Session


@dataclass
class broker_ctx:
    broker: Broker
    field_name: str
    rank: int
    group_id: int
    handle: FieldHandle | None = None


_shared_session: Session | None = None
_shared_broker: Broker | None = None    # deprecated alias of _shared_session.broker


def broker_connect(endpoints: list[CloudEndpoint], n_producers: int,
                   cfg: BrokerConfig | None = None,
                   plan: GroupPlan | None = None) -> Broker:
    """Job-level setup: bind the producer job to a set of Cloud endpoints.

    Deprecated — use ``repro.workflow.Session`` for new code."""
    global _shared_session, _shared_broker
    plan = plan or plan_groups(n_producers, executors_per_group=16)
    if plan.n_groups > len(endpoints):
        warnings.warn(
            f"GroupPlan asks for {plan.n_groups} groups but only "
            f"{len(endpoints)} endpoints are connected; shrinking to "
            f"{len(endpoints)} groups (each endpoint absorbs more producers — "
            "resize the deployment or the plan)",
            RuntimeWarning, stacklevel=2)
    effective = GroupPlan(n_producers=n_producers,
                          n_groups=min(plan.n_groups, len(endpoints)),
                          executors_per_group=plan.executors_per_group)
    wf = WorkflowConfig.from_broker_config(cfg or BrokerConfig(), effective)
    _shared_session = Session(wf, endpoints=endpoints)
    _shared_broker = _shared_session.broker
    _shared_broker.planned_groups = plan.n_groups
    return _shared_broker


def broker_init(field_name: str, rank: int, shape=(), dtype="float32",
                broker: Broker | None = None) -> broker_ctx:
    b = broker or _shared_broker
    if b is None:
        raise RuntimeError("call broker_connect(endpoints, n_producers) first")
    g = b.plan.group_of(rank)
    # coerce_dtype=False: the paper's broker_write shipped payloads in their
    # input dtype (the declared dtype is schema metadata) — preserve that.
    h = FieldHandle(b, field_name, shape=shape, dtype=dtype,
                    coerce_dtype=False)
    return broker_ctx(broker=b, field_name=field_name, rank=rank, group_id=g,
                      handle=h)


def broker_write(ctx: broker_ctx, step: int, data, data_len: int | None = None) -> bool:
    arr = np.asarray(data)
    if data_len is not None:
        arr = arr.reshape(-1)[:data_len]
    return ctx.handle.write(step, arr, rank=ctx.rank)


def broker_finalize(ctx: broker_ctx) -> BrokerStats:
    if _shared_session is not None and ctx.broker is _shared_session.broker:
        return _shared_session.close()
    return ctx.broker.finalize()
