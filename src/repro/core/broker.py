"""The ElasticBroker producer-side runtime.

Mirrors the paper's design (§3.1): producer ranks are partitioned into groups;
each group registers with one Cloud endpoint; ``write`` converts a field
snapshot into a stream record and hands it to an **asynchronous dispatcher**
(bounded queue + background sender thread per group) so the producer —
an OpenFOAM solver there, a JAX train/serve step here — never stalls on the
wide-area link.  That asynchrony is what produces the paper's Fig-6 result
(ElasticBroker ≈ simulation-only elapsed time, file-based I/O much slower).

Fault tolerance beyond the paper: bounded-queue backpressure policies
(block / drop_oldest / sample), endpoint failure detection and group
re-routing to surviving endpoints, and per-group delivery metrics.

Wire aggregation (the paper's "data aggregation" duty): each sender
wake-up coalesces all queued records — up to the sender's ``batch_cap``,
seeded from ``cfg.max_batch_records`` and adjustable at runtime
(``Broker.set_batch_cap``, driven by the elasticity controller from queue
depth) — into one batched frame (core/records.py ``encode_batch``), so
framing, compression, and the endpoint's bandwidth model are paid per batch
rather than per record.  ``stats.frames_sent`` vs ``stats.sent`` shows the
achieved aggregation ratio.

Stats accounting is race-free by construction: every ``_GroupSender`` owns a
lock-guarded :class:`_SenderStats` that only its producers/sender touch, and
``Broker.stats`` merges them into one :class:`BrokerStats` view on read —
counters stay exact under arbitrary producer/sender concurrency (the seed
shared one unlocked dataclass across all sender threads, so ``+=`` lost
updates under load).
"""
from __future__ import annotations

import queue
import threading
import warnings
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.grouping import GroupPlan
from repro.core.records import (FieldSchema, StreamRecord, decode, encode,
                                encode_batch, wrap_seq)
from repro.core.transport import Transport
from repro.runtime.clock import Clock, ensure_clock
from repro.runtime.wal import WalSegment, WalStore
from repro.tenancy import TenantAdmission, TenantRegistry, merge_counts


@dataclass
class BrokerConfig:
    compress: str = "int8+zstd"       # none | zstd | int8 | int8+zstd
    queue_capacity: int = 256         # records per group queue
    backpressure: str = "drop_oldest" # block | drop_oldest | sample
    sample_keep: int = 2              # with `sample`: keep 1 of N on pressure
    flush_timeout_s: float = 10.0
    retry_limit: int = 3
    # Wire aggregation: each sender wake-up coalesces every record already
    # queued (up to this many) into one batched frame — one msgpack frame,
    # one zstd pass, one Endpoint.push per batch instead of per record.
    # 1 disables coalescing (seed per-record framing).  This seeds each
    # sender's mutable ``batch_cap``.
    max_batch_records: int = 32
    delta_encode: bool = False        # delta-vs-previous-step in batch frames
    # Delivery guarantee.  "exactly-once" logs every record to a per-group
    # write-ahead segment (runtime.wal) before it ships: the WAL replaces
    # the sender queue, endpoints dedupe on frame seq, and unacked tails
    # replay across endpoint failover and broker restarts.  Requires
    # backpressure="block" (a drop policy contradicts the guarantee).
    delivery: str = "at-most-once"    # at-most-once | exactly-once
    wal_capacity_bytes: int = 16 << 20  # per-group WAL byte bound
    # Sharded fan-in: the broker splits into this many group-owning shards
    # (group g lives on shard g % n_shards), each with its own endpoint
    # ring, WAL segments, and sender stats, behind a thin routing layer.
    # 1 keeps the paper's single fan-in.  Clamped to n_groups.
    n_shards: int = 1
    # ---- multi-tenant QoS admission ------------------------------------
    # Active only when the Broker is built with a TenantRegistry (and the
    # backpressure policy is not "block"); plain deployments are untouched.
    # Parking starts when a shard's queued records cross high_water_frac of
    # its aggregate queue capacity; parked traffic re-admits once the
    # sender's own queue falls to low_water_frac of its capacity.
    high_water_frac: float = 0.75
    low_water_frac: float = 0.25
    park_capacity: int | None = None  # parked records/sender (None: queue_capacity)


@dataclass
class BrokerStats:
    """Merged, read-only view over the per-sender counters (``Broker.stats``
    builds a fresh one per read)."""

    written: int = 0
    sent: int = 0                     # records delivered
    frames_sent: int = 0              # wire frames pushed (≤ sent)
    dropped: int = 0
    rerouted: int = 0
    bytes_sent: int = 0
    send_errors: int = 0
    # frames given up on (at-most-once retry exhaustion, or an exactly-once
    # drain whose endpoints were all dead past the flush timeout) — always
    # paired with a RuntimeWarning, never silent
    frames_abandoned: int = 0
    # exactly-once replay traffic: frames/records re-shipped from the WAL
    # after a failover or restart (also counted in frames_sent/sent)
    frames_replayed: int = 0
    records_replayed: int = 0
    queue_high_water: int = 0
    # Effective deployment shape: a connect-time plan that asks for more
    # groups than there are endpoints is silently shrunk; these two fields
    # make that visible (planned != effective ⇒ mis-sized deployment).
    planned_groups: int = 0
    effective_groups: int = 0
    # per-tenant loss ledger (tenant -> counters, see repro.tenancy.ledger);
    # empty unless the broker was built with a TenantRegistry
    tenants: dict = field(default_factory=dict)


_COUNTER_FIELDS = ("written", "sent", "frames_sent", "dropped", "rerouted",
                   "bytes_sent", "send_errors", "frames_abandoned",
                   "frames_replayed", "records_replayed")


class _SenderStats:
    """Lock-guarded per-sender counters.  One instance per ``_GroupSender``;
    the producer threads (submit/submit_batch) and the sender thread mutate
    it under ``lock``, so reads via ``snapshot()`` are exact."""

    __slots__ = ("lock", "written", "sent", "frames_sent", "dropped",
                 "rerouted", "bytes_sent", "send_errors", "frames_abandoned",
                 "frames_replayed", "records_replayed", "queue_high_water",
                 "tenants")

    def __init__(self):
        self.lock = threading.Lock()
        for f in _COUNTER_FIELDS:
            setattr(self, f, 0)
        self.queue_high_water = 0
        # tenant -> counter dict (repro.tenancy.ledger.TENANT_COUNTERS);
        # stays empty unless the QoS plane is active
        self.tenants: dict[str, dict[str, int]] = {}

    def add(self, **deltas: int) -> None:
        with self.lock:
            for name, d in deltas.items():
                setattr(self, name, getattr(self, name) + d)

    def add_tenant(self, tenant: str, **deltas: int) -> None:
        with self.lock:
            c = self.tenants.setdefault(tenant, {})
            for name, d in deltas.items():
                c[name] = c.get(name, 0) + d

    def observe_depth(self, depth: int) -> None:
        with self.lock:
            if depth > self.queue_high_water:
                self.queue_high_water = depth

    def snapshot(self) -> dict:
        with self.lock:
            out = {f: getattr(self, f) for f in _COUNTER_FIELDS}
            out["queue_high_water"] = self.queue_high_water
            return out

    def tenant_snapshot(self) -> dict[str, dict[str, int]]:
        with self.lock:
            return {n: dict(c) for n, c in self.tenants.items()}


class _GroupSender(threading.Thread):
    """One background sender per producer group (paper: one TCP stream per
    group to its designated endpoint)."""

    def __init__(self, group_id: int, endpoints: list[Transport], primary: int,
                 cfg: BrokerConfig, clock: Clock | None = None, *,
                 wal: WalSegment | None = None,
                 go: threading.Event | None = None,
                 tenants: TenantRegistry | None = None):
        super().__init__(daemon=True, name=f"broker-g{group_id}")
        self.group_id = group_id
        self.endpoints = endpoints            # anything satisfying Transport
        self.primary = primary
        self.cfg = cfg
        self.clock = ensure_clock(clock)
        # QoS plane: with a registry, admission becomes priority-aware —
        # parkable tenants hold out of the shared queue under backlog
        # pressure and eviction sheds the lowest priority class first
        self.tenants = tenants
        self._shard: _BrokerShard | None = None   # set by the owning shard
        self._park: deque = deque()               # parked items, FIFO
        self._park_records = 0
        self._park_tenants: dict[str, int] = {}   # currently parked, per tenant
        self._q_tenants: dict[str, int] = {}      # currently queued, per tenant
        # each sender owns its counters; Broker.stats merges them on read
        self.stats = _SenderStats()
        # mutable wire-aggregation cap, adapted at runtime from queue depth
        # by the elasticity controller (seeded from the static config)
        self.batch_cap = max(1, cfg.max_batch_records)
        self.q: queue.Queue = queue.Queue(maxsize=cfg.queue_capacity)
        # record-accurate backlog: q.qsize() counts queue ITEMS, but a
        # submit_batch item is a whole record list — telemetry reading
        # qsize() under-reports by the batch width (a "depth 2" queue can
        # hide hundreds of records), which starves the controller's
        # backlog/shard signals.  This counter tracks records admitted and
        # not yet sent (including the chunk the sender is pacing out).
        self._q_records = 0
        self._q_lock = threading.Lock()
        # NB: must not be named `_stop` — that would shadow Thread._stop(),
        # which threading.join() calls on finished threads
        self._stop_evt = threading.Event()
        self._sample_lock = threading.Lock()
        self._sample_ctr = 0
        # -- exactly-once state ------------------------------------------
        # In exactly-once mode the WAL *is* the queue: producers append,
        # this thread ships through the segment's `shipped` pointer, so
        # wire order == seq order by construction.
        self.wal = wal
        self._killed = False                  # simulated crash (kill())
        # held shut while a restored Session rebuilds plan/ledger state;
        # Broker.release() opens it (normal construction pre-sets it)
        if go is None:                        # standalone sender: open gate
            go = threading.Event()
            go.set()
        self._go = go
        self._replay_horizon = 0
        if wal is not None:
            # entries adopted from a previous broker incarnation replay
            # first; count acks at-or-below this horizon as replay traffic
            self._replay_horizon = wal.last_seq
            wal.rewind_shipped()

    @property
    def _exactly_once(self) -> bool:
        return self.wal is not None

    def set_batch_cap(self, cap: int) -> int:
        self.batch_cap = max(1, int(cap))
        return self.batch_cap

    def _q_add(self, n: int, tenant: str | None = None) -> None:
        with self._q_lock:
            self._q_records += n
            if tenant is not None and self.tenants is not None:
                self._q_tenants[tenant] = self._q_tenants.get(tenant, 0) + n

    def _q_sub_chunk(self, recs: list[StreamRecord]) -> None:
        """Decrement the record backlog for a sent/abandoned chunk, split by
        tenant when the QoS plane is active (a coalesced chunk can mix
        tenants across queue items)."""
        if self.tenants is None:
            self._q_add(-len(recs))
            return
        counts: dict[str, int] = {}
        for r in recs:
            counts[r.tenant] = counts.get(r.tenant, 0) + 1
        with self._q_lock:
            self._q_records -= len(recs)
            for t, m in counts.items():
                self._q_tenants[t] = self._q_tenants.get(t, 0) - m

    def queued_records(self) -> int:
        """Records in the shared queue only (parked records excluded) —
        the high-water signal that drives parking."""
        with self._q_lock:
            return self._q_records

    def _count_chunk_tenants(self, recs: list[StreamRecord],
                             counter: str) -> None:
        """Per-tenant accounting for a whole outbound chunk (no-op without
        the QoS plane)."""
        if self.tenants is None:
            return
        counts: dict[str, int] = {}
        for r in recs:
            counts[r.tenant] = counts.get(r.tenant, 0) + 1
        for t, m in counts.items():
            self.stats.add_tenant(t, **{counter: m})

    def _sample_tick(self) -> bool:
        """1-of-N admission under `sample` pressure, race-free."""
        with self._sample_lock:
            self._sample_ctr += 1
            return self._sample_ctr % self.cfg.sample_keep == 0

    # ---- producer side ------------------------------------------------
    def _evict_one(self) -> bool:
        """Drop the oldest queue item, counting its records (items are single
        records or submit_batch lists)."""
        try:
            evicted = self.q.get_nowait()
        except queue.Empty:
            return False
        n = len(evicted) if isinstance(evicted, list) else 1
        self._q_add(-n)
        self.stats.add(dropped=n)
        return True

    # ---- QoS admission (active only with a TenantRegistry) -------------
    @staticmethod
    def _item_meta(item) -> tuple[int, str]:
        """(record count, tenant) of a queue item — items are single records
        or single-tenant submit_batch lists."""
        if isinstance(item, list):
            return len(item), item[0].tenant
        return 1, item.tenant

    def _over_high_water(self) -> bool:
        """Shard-level pressure signal: queued records (across the owning
        shard's senders) at or past high_water_frac of aggregate capacity."""
        if self._shard is not None:
            depth = self._shard.queue_records()
            n_senders = len(self._shard.senders)
        else:
            depth, n_senders = self.queued_records(), 1
        return depth >= self.cfg.high_water_frac * \
            self.cfg.queue_capacity * max(1, n_senders)

    def _evict_for(self, priority: int) -> bool:
        """Evict the oldest queue item of the LOWEST evictable priority class
        (<= the incoming record's class).  Never touches a higher-priority
        tenant: if only higher classes are queued, the caller's record is the
        one that gets dropped."""
        with self.q.mutex:
            best_i: int | None = None
            best_pr: int | None = None
            for i, it in enumerate(self.q.queue):
                _, t = self._item_meta(it)
                pr = self.tenants.priority(t)
                if pr <= priority and (best_pr is None or pr < best_pr):
                    best_i, best_pr = i, pr
            if best_i is None:
                return False
            victim = self.q.queue[best_i]
            del self.q.queue[best_i]
            self.q.not_full.notify()
        n, vt = self._item_meta(victim)
        self._q_add(-n, vt)
        self.stats.add(dropped=n)
        self.stats.add_tenant(vt, evicted=n)
        return True

    def _park_item(self, item, n: int, tenant: str) -> None:
        """Admit a parkable tenant's item into the bounded side-park instead
        of the shared queue.  Overflow evicts the oldest parked item —
        counted per tenant, never silent."""
        cap = self.cfg.park_capacity or self.cfg.queue_capacity
        evictions: list[tuple[str, int]] = []
        with self._q_lock:
            self._park.append(item)
            self._park_records += n
            self._park_tenants[tenant] = self._park_tenants.get(tenant, 0) + n
            while self._park_records > cap and len(self._park) > 1:
                old = self._park.popleft()
                m, ot = self._item_meta(old)
                self._park_records -= m
                self._park_tenants[ot] = self._park_tenants.get(ot, 0) - m
                evictions.append((ot, m))
        self.stats.add_tenant(tenant, admitted=n, parked_total=n)
        for ot, m in evictions:
            self.stats.add(dropped=m)
            self.stats.add_tenant(ot, evicted=m)

    def _maybe_unpark(self) -> None:
        """Re-admit parked items (oldest first) once the sender's own queue
        has fallen to the low-water mark — or unconditionally during a
        stop-drain, so parked records flush rather than strand."""
        if self._park_records == 0:
            return
        low = self.cfg.low_water_frac * self.cfg.queue_capacity
        draining = self._stop_evt.is_set()
        while True:
            with self._q_lock:
                if not self._park:
                    return
                if not draining and self._q_records > low:
                    return
                item = self._park[0]
                try:
                    self.q.put_nowait(item)
                except queue.Full:
                    return
                self._park.popleft()
                n, t = self._item_meta(item)
                self._park_records -= n
                self._park_tenants[t] = self._park_tenants.get(t, 0) - n
                self._q_records += n
                self._q_tenants[t] = self._q_tenants.get(t, 0) + n
            self.stats.add_tenant(t, unparked=n)

    def _submit_qos(self, item, n: int, tenant: str) -> int:
        """Priority-aware admission, replacing the anonymous drop policy when
        the QoS plane is active: parkable tenants side-park under shard
        backlog pressure, and on a full queue the lowest priority class at or
        below the incoming record's is evicted first."""
        st = self.stats
        if self.cfg.backpressure == "block":
            # block semantics keep their no-shed guarantee; only account
            self.clock.queue_put(self.q, item)
            self._q_add(n, tenant)
            st.add_tenant(tenant, admitted=n)
            return n
        if self.tenants.parks(tenant) and (
                self._park_tenants.get(tenant, 0) > 0
                or self._over_high_water()):
            # once a tenant has parked records, later ones park too —
            # re-admission is FIFO, so per-stream order is preserved
            self._park_item(item, n, tenant)
            return n
        try:
            self.q.put_nowait(item)
            self._q_add(n, tenant)
            st.add_tenant(tenant, admitted=n)
            return n
        except queue.Full:
            pass
        if self._evict_for(self.tenants.priority(tenant)):
            try:
                self.q.put_nowait(item)
                self._q_add(n, tenant)
                st.add_tenant(tenant, admitted=n)
                return n
            except queue.Full:
                pass
        st.add(dropped=n)
        st.add_tenant(tenant, dropped=n)
        return 0

    def _submit_eo(self, recs: list[StreamRecord]) -> int:
        """Exactly-once admission: log each record to the WAL before it can
        ship.  Blocks (bounded-WAL backpressure) until space frees.  A
        *killed* sender still appends — the WAL outlives this broker
        incarnation and its successor ships the record — but a gracefully
        stopped one refuses new records.  ``written`` is not counted here:
        in exactly-once mode it derives from the WAL itself (see
        :meth:`stats_snapshot`), the one ledger producers share across
        broker incarnations."""
        n = 0
        for rec in recs:
            blob = encode(rec, compress=self.cfg.compress)
            while True:
                if self._stop_evt.is_set() and not self._killed:
                    return n                  # graceful shutdown: refuse
                if self.wal.try_append(blob, rec) is not None:
                    break
                self.clock.sleep(0.005)       # WAL full: bounded backpressure
            self.stats.observe_depth(self.wal.unshipped_count())
            if self.tenants is not None:
                # counted at append: try_append is atomic, so the per-tenant
                # admitted count is exact across broker incarnations
                self.stats.add_tenant(rec.tenant, admitted=1)
            n += 1
        return n

    def submit(self, rec: StreamRecord) -> bool:
        if self._exactly_once:
            return self._submit_eo([rec]) == 1
        self.stats.add(written=1)
        self.stats.observe_depth(self.backlog())
        if self.tenants is not None:
            return self._submit_qos(rec, 1, rec.tenant) == 1
        if self.cfg.backpressure == "block":
            self.clock.queue_put(self.q, rec)
            self._q_add(1)
            return True
        try:
            self.q.put_nowait(rec)
            self._q_add(1)
            return True
        except queue.Full:
            if self.cfg.backpressure == "drop_oldest":
                self._evict_one()
                try:
                    self.q.put_nowait(rec)
                    self._q_add(1)
                    return True
                except queue.Full:
                    self.stats.add(dropped=1)
                    return False
            # sample: keep 1 of N while under pressure
            if self._sample_tick():
                if self._evict_one():
                    try:
                        self.q.put_nowait(rec)
                        self._q_add(1)
                        return True
                    except queue.Full:
                        pass
            self.stats.add(dropped=1)
            return False

    def submit_batch(self, recs: list[StreamRecord]) -> int:
        """Enqueue a pre-batched record list as ONE queue item, so the whole
        batch leaves as (at most) one wire frame regardless of sender-thread
        timing — this is what gives ``FieldHandle.write_batch`` its ≤ one
        frame per (field, group) guarantee.  Returns #records accepted."""
        if not recs:
            return 0
        if self._exactly_once:
            return self._submit_eo(list(recs))
        self.stats.add(written=len(recs))
        self.stats.observe_depth(self.backlog())
        item = list(recs)
        if self.tenants is not None:
            # queue items must be single-tenant for priority eviction and
            # park accounting; mixed batches split (rare — FieldHandle and
            # Broker.write_batch are single-tenant per call)
            if len({r.tenant for r in item}) == 1:
                return self._submit_qos(item, len(item), item[0].tenant)
            total = 0
            by_tenant: dict[str, list[StreamRecord]] = {}
            for r in item:
                by_tenant.setdefault(r.tenant, []).append(r)
            for tname, sub in by_tenant.items():
                total += self._submit_qos(sub, len(sub), tname)
            return total
        if self.cfg.backpressure == "block":
            self.clock.queue_put(self.q, item)
            self._q_add(len(item))
            return len(item)
        try:
            self.q.put_nowait(item)
            self._q_add(len(item))
            return len(item)
        except queue.Full:
            if self.cfg.backpressure == "drop_oldest":
                self._evict_one()
                try:
                    self.q.put_nowait(item)
                    self._q_add(len(item))
                    return len(item)
                except queue.Full:
                    pass
            elif self.cfg.backpressure == "sample":
                # same 1-of-N policy as submit(), at batch granularity
                if self._sample_tick() and self._evict_one():
                    try:
                        self.q.put_nowait(item)
                        self._q_add(len(item))
                        return len(item)
                    except queue.Full:
                        pass
            # overflow: the whole batch is one unit — drop it whole
            self.stats.add(dropped=len(item))
            return 0

    # ---- sender loop ---------------------------------------------------
    def run(self):
        try:
            if not self._go.is_set():
                self.clock.wait_event(self._go)
            if self._exactly_once:
                self._run_wal()
            else:
                self._run_queue()
        finally:
            # leave the clock's schedule on exit so a virtual schedule never
            # waits out the dead-participant watchdog for this thread
            self.clock.detach()

    def _run_queue(self):
        """At-most-once drain: each wake-up takes every queued record (up to
        ``batch_cap``, re-read per wake-up so the controller can retune it
        live) and ships them as one batched wire frame, so a burst of writes
        pays framing/compression/bandwidth-model cost once per batch, not
        once per record.  Queue items are single records (``submit``) or
        record lists (``submit_batch``); an oversized list is chunked at the
        cap."""
        while not self._killed \
                and (not self._stop_evt.is_set() or not self.q.empty()
                     or self._park_records > 0):
            if self.tenants is not None:
                self._maybe_unpark()
            cap = max(1, self.batch_cap)
            item = self.clock.queue_get(self.q, timeout=0.05)
            if item is None:
                continue
            recs = list(item) if isinstance(item, list) else [item]
            while len(recs) < cap:
                try:
                    nxt = self.q.get_nowait()
                except queue.Empty:
                    break
                recs.extend(nxt if isinstance(nxt, list) else [nxt])
            for i in range(0, len(recs), cap):
                chunk = recs[i:i + cap]
                if len(chunk) == 1:
                    blob = encode(chunk[0], compress=self.cfg.compress)
                else:
                    blob = encode_batch(chunk, compress=self.cfg.compress,
                                        delta=self.cfg.delta_encode)
                sent = self._send(blob)
                # decremented only now: records stay on the backlog while
                # the sender paces the frame out through the endpoint's
                # bandwidth model — that wait IS the congestion the
                # controller's backlog signals are meant to see
                self._q_sub_chunk(chunk)
                if sent:
                    self.stats.add(sent=len(chunk), frames_sent=1,
                                   bytes_sent=len(blob))
                    self._count_chunk_tenants(chunk, "sent")
                else:
                    # retries exhausted: the frame is gone.  Loudly — silent
                    # loss is indistinguishable from a broken pipeline.
                    self.stats.add(dropped=len(chunk), frames_abandoned=1)
                    self._count_chunk_tenants(chunk, "evicted")
                    warnings.warn(
                        f"broker group {self.group_id}: abandoned a frame of "
                        f"{len(chunk)} record(s) after {self.cfg.retry_limit} "
                        "failed sends (at-most-once delivery: records are "
                        "lost; use delivery='exactly-once' for replay)",
                        RuntimeWarning, stacklevel=2)

    def _run_wal(self):
        """Exactly-once ship loop: fetch unshipped WAL entries in seq order,
        wrap them with their seq range, and retry each frame until an
        endpoint acks it — head-of-line blocking is intentional (acks are
        contiguous).  Entries adopted from a dead broker incarnation (seq <=
        the replay horizon) are replay traffic; the receive-side SeqLedger
        makes re-sends idempotent."""
        wal = self.wal
        while not self._killed:
            entries = wal.fetch_unshipped(max(1, self.batch_cap))
            if not entries:
                if self._stop_evt.is_set() and wal.unshipped_count() == 0:
                    return
                self.clock.sleep(0.02)
                continue
            if len(entries) == 1:
                blob = entries[0].blob        # reuse the logged encoding
                recs_n = 1
            else:
                recs = [e.rec if e.rec is not None else decode(e.blob)
                        for e in entries]
                blob = encode_batch(recs, compress=self.cfg.compress,
                                    delta=self.cfg.delta_encode)
                recs_n = len(recs)
            wire = wrap_seq(entries[0].seq, recs_n, blob)
            if not self._ship(wire, entries):
                return                        # killed mid-retry

    def _ship(self, wire: bytes, entries) -> bool:
        """Retry one wrapped frame until acked (exactly-once never drops on
        its own).  During a stop-drain with every endpoint dead we abandon
        after the flush timeout — loudly — instead of hanging teardown."""
        last = entries[-1].seq
        n = len(entries)
        deadline = None
        while True:
            if self._send(wire):
                self.wal.ack(last)
                replayed = sum(1 for e in entries
                               if e.seq <= self._replay_horizon)
                extra = {"frames_replayed": 1, "records_replayed": replayed} \
                    if replayed else {}
                self.stats.add(sent=n, frames_sent=1, bytes_sent=len(wire),
                               **extra)
                if self.tenants is not None:
                    self._count_chunk_tenants(
                        [e.rec if e.rec is not None else decode(e.blob)
                         for e in entries], "sent")
                return True
            if self._killed:
                return False
            if self._stop_evt.is_set():
                if deadline is None:
                    deadline = self.clock.now() + self.cfg.flush_timeout_s
                elif self.clock.now() >= deadline:
                    self.wal.ack(last)        # consume so teardown can exit
                    self.stats.add(dropped=n, frames_abandoned=1)
                    if self.tenants is not None:
                        self._count_chunk_tenants(
                            [e.rec if e.rec is not None else decode(e.blob)
                             for e in entries], "evicted")
                    warnings.warn(
                        f"broker group {self.group_id}: abandoned a frame of "
                        f"{n} record(s) at shutdown — no endpoint recovered "
                        f"within flush_timeout_s={self.cfg.flush_timeout_s}",
                        RuntimeWarning, stacklevel=2)
                    return True
            self.clock.sleep(0.05)

    def _send(self, blob: bytes) -> bool:
        """Send to primary; on failure re-route to the next healthy endpoint
        (pure remapping — the paper's grouping makes failover trivial)."""
        n = len(self.endpoints)
        for attempt in range(self.cfg.retry_limit):
            ep = self.endpoints[(self.primary + attempt) % n]
            try:
                if ep.healthy():
                    ep.push(self.group_id, blob)
                    if attempt > 0:
                        self.stats.add(rerouted=1)
                        self.primary = (self.primary + attempt) % n
                    return True
            except Exception:
                pass
            self.stats.add(send_errors=1)
        return False

    @staticmethod
    def _endpoint_load(ep) -> float | None:
        """Routing-load estimate for reroute target selection: buffered
        backlog plus current ingest rate.  None when the binding exposes no
        telemetry (bare transports) — callers fall back to ring order."""
        handle = getattr(ep, "handle", None)
        if handle is None:
            return None
        try:
            return float(handle.pending()) + float(handle.ingest_rate())
        except Exception:
            return None

    def reroute(self) -> int | None:
        """Proactively move the primary off a known-dead endpoint (the
        controller's FailureDetector path) instead of waiting for the next
        send to burn retries.  Returns the new primary index, or None when no
        healthy endpoint exists.

        Target selection is least-loaded, not first-surviving: when an
        endpoint dies mid-spike, every orphaned group rerouting to the same
        "next" survivor would dogpile it while emptier endpoints idle.
        Candidates are ranked by pending+ingest telemetry; ties (and
        endpoints with no telemetry) resolve in ring order, which keeps the
        choice deterministic."""
        n = len(self.endpoints)
        candidates: list[tuple[int, float | None]] = []
        for shift in range(1, n + 1):
            idx = (self.primary + shift) % n
            try:
                if not self.endpoints[idx].healthy():
                    continue
            except Exception:
                continue
            candidates.append((idx, self._endpoint_load(self.endpoints[idx])))
        if not candidates:
            return None
        if any(load is None for _, load in candidates):
            best = candidates[0][0]       # no telemetry: legacy ring order
        else:
            best = min(candidates, key=lambda c: c[1])[0]
        if best != self.primary:
            self.primary = best
            self.stats.add(rerouted=1)
        return best

    def backlog(self) -> int:
        """Records admitted but not yet handed to the wire.  Counted in
        RECORDS, not queue items: ``q.qsize()`` would report a whole
        ``submit_batch`` list as depth 1, hiding the real backlog from the
        controller's ``backlog_high`` / ``shard_backlog_high`` signals."""
        if self._exactly_once:
            return self.wal.unshipped_count()
        with self._q_lock:
            # parked records are admitted-but-unsent: they belong on the
            # backlog (flush() must wait for the park to drain)
            return self._q_records + self._park_records

    def tenant_backlog(self) -> tuple[dict[str, int], dict[str, int]]:
        """(queued, parked) records per tenant — live gauges for telemetry
        and ledger-closure checks."""
        with self._q_lock:
            return dict(self._q_tenants), dict(self._park_tenants)

    def stats_snapshot(self) -> dict:
        snap = self.stats.snapshot()
        if self._exactly_once:
            # written derives from the WAL (total ever appended to this
            # group's segment): producers may append across broker
            # incarnations — racing a restart — and the segment is the one
            # ledger they all share, so it is the only exact count
            snap["written"] = self.wal.points()["last"]
        return snap

    def stop(self, timeout: float):
        self._stop_evt.set()
        self._go.set()                        # never strand a paused sender
        # clock-mediated join: under VirtualClock a native join would stall
        # the schedule (the joiner is runnable but blocked outside the clock)
        self.clock.join(self, timeout=timeout)

    def kill(self):
        """Simulated crash: stop immediately without draining.  In
        exactly-once mode unacked WAL entries survive in the (external)
        WalStore and replay in the next broker incarnation; in at-most-once
        mode queued records are lost, exactly as a real crash would lose
        them."""
        self._killed = True
        self._stop_evt.set()
        self._go.set()                        # never strand a paused sender
        self.clock.join(self, timeout=5.0)


class _BrokerShard:
    """One group-owning shard of the sharded fan-in.

    A shard runs the :class:`_GroupSender` threads for its groups against
    its OWN endpoint ring (a shard-local list: senders size their failover
    ring from it, and :meth:`attach_endpoint` grows it independently), and
    owns its groups' WAL segments and per-sender stats.  The :class:`Broker`
    above it is a thin routing layer — ``write``/``write_batch`` route by
    ``group % n_shards`` — so no producer ever funnels through a single
    fan-in lock or sender set."""

    def __init__(self, shard_id: int, groups: list[int],
                 endpoints: list[Transport], cfg: BrokerConfig,
                 clock: Clock, *, wal: WalStore | None,
                 go: threading.Event,
                 tenants: TenantRegistry | None = None):
        self.shard_id = shard_id
        self.cfg = cfg
        # shard-local ring: a copy, so each shard's failover surface and
        # dynamic attaches are its own (the router fans attaches out to
        # every shard in fleet order, keeping indices aligned)
        self.endpoints = list(endpoints)
        self.senders: dict[int, _GroupSender] = {}
        for g in groups:
            s = _GroupSender(g, self.endpoints, g % len(self.endpoints),
                             cfg, clock,
                             wal=wal.segment(g) if wal else None,
                             go=go, tenants=tenants)
            s._shard = self      # backref for the shard-level park signal
            clock.thread_started(s)
            s.start()
            self.senders[g] = s

    def attach_endpoint(self, ep: Transport) -> int:
        """Grow this shard's ring; returns the new shard-local index (equal
        to the fleet index when the router fans out in order)."""
        self.endpoints.append(ep)
        return len(self.endpoints) - 1

    def reroute_from_endpoint(self, endpoint_idx: int) -> int:
        """Re-point every one of this shard's groups whose primary is the
        dead endpoint.  Returns #groups rerouted."""
        n = 0
        for s in self.senders.values():
            if s.primary == endpoint_idx and s.reroute() is not None:
                n += 1
        return n

    def groups_on_endpoint(self, endpoint_idx: int) -> int:
        return sum(1 for s in self.senders.values()
                   if s.primary == endpoint_idx)

    def backlog(self) -> int:
        return sum(s.backlog() for s in self.senders.values())

    def queue_records(self) -> int:
        """Aggregate queued records across this shard's senders, excluding
        parks — the shard backlog that triggers QoS parking."""
        return sum(s.queued_records() for s in self.senders.values())

    def telemetry(self) -> dict:
        """Shard-level control-plane rollup — one row per shard in
        ``TelemetrySnapshot.shards``."""
        row = dict.fromkeys(_COUNTER_FIELDS, 0)
        depth = 0
        for s in self.senders.values():
            snap = s.stats_snapshot()
            for f in _COUNTER_FIELDS:
                row[f] += snap[f]
            depth += s.backlog()
        row.update(shard=self.shard_id, groups=len(self.senders),
                   queue_depth=depth, endpoints=len(self.endpoints))
        return row


class Broker:
    """Producer-side broker: one per job, shared by all local ranks.

    Internally sharded (``cfg.n_shards``): group-owning :class:`_BrokerShard`
    objects run the senders; this class is the routing layer that preserves
    the original single-broker surface (stats merge, group telemetry,
    flush/finalize/kill, WAL bookkeeping) on top of them."""

    def __init__(self, plan: GroupPlan, endpoints: list[Transport],
                 cfg: BrokerConfig | None = None, *,
                 clock: Clock | None = None, wal: WalStore | None = None,
                 paused: bool = False,
                 tenants: TenantRegistry | None = None):
        assert len(endpoints) >= plan.n_groups, (
            f"{plan.n_groups} groups need >= that many endpoints, "
            f"got {len(endpoints)}")
        self.plan = plan
        self.cfg = cfg or BrokerConfig()
        self.clock = ensure_clock(clock)
        self.endpoints = list(endpoints)
        self.planned_groups = plan.n_groups
        self.effective_groups = plan.n_groups
        self.schemas: dict[str, FieldSchema] = {}
        self.wal = wal
        # ---- multi-tenant QoS plane ------------------------------------
        self.tenants = tenants
        self._quota = TenantAdmission(tenants, self.clock) \
            if tenants is not None and tenants.has_quota else None
        self._quota_lock = threading.Lock()
        self._quota_rejected: dict[str, int] = {}
        if self.cfg.delivery == "exactly-once":
            if self.cfg.backpressure != "block":
                raise ValueError(
                    "delivery='exactly-once' requires backpressure='block' "
                    "(a drop policy contradicts the guarantee)")
            if self.wal is None:
                self.wal = WalStore(capacity_bytes=self.cfg.wal_capacity_bytes,
                                    queue_capacity=self.cfg.queue_capacity)
        elif self.wal is not None:
            raise ValueError("a WalStore requires delivery='exactly-once'")
        # `paused` holds the senders shut until release() — Session.restore
        # uses it so replay cannot race the plan/ledger state restore
        self._go = threading.Event()
        if not paused:
            self._go.set()
        self.n_shards = max(1, min(int(self.cfg.n_shards), plan.n_groups))
        self.shards: list[_BrokerShard] = []
        for sid in range(self.n_shards):
            groups = [g for g in range(plan.n_groups)
                      if g % self.n_shards == sid]
            self.shards.append(_BrokerShard(
                sid, groups, self.endpoints, self.cfg, self.clock,
                wal=self.wal, go=self._go, tenants=tenants))

    def shard_of(self, group: int) -> int:
        return group % self.n_shards

    def _sender(self, group: int) -> _GroupSender:
        return self.shards[group % self.n_shards].senders[group]

    @property
    def _senders(self) -> dict[int, _GroupSender]:
        """Merged group->sender view across shards (observability, tests,
        and whole-fleet operations; routing uses :meth:`_sender`)."""
        out: dict[int, _GroupSender] = {}
        for shard in self.shards:
            out.update(shard.senders)
        return out

    def release(self) -> None:
        """Open the sender gate of a ``paused=True`` broker (replay starts)."""
        self._go.set()

    # ---- observability --------------------------------------------------
    @property
    def stats(self) -> BrokerStats:
        """Exact merged view: per-sender counters aggregated on read."""
        out = BrokerStats(planned_groups=self.planned_groups,
                          effective_groups=self.effective_groups)
        for s in self._senders.values():
            snap = s.stats_snapshot()
            for f in _COUNTER_FIELDS:
                setattr(out, f, getattr(out, f) + snap[f])
            out.queue_high_water = max(out.queue_high_water,
                                       snap["queue_high_water"])
            if self.tenants is not None:
                merge_counts(out.tenants, s.stats.tenant_snapshot())
        if self.tenants is not None:
            with self._quota_lock:
                rejected = dict(self._quota_rejected)
            merge_counts(out.tenants,
                         {t: {"quota_rejected": n}
                          for t, n in rejected.items()})
        return out

    def group_telemetry(self) -> list[dict]:
        """Per-group control-plane sample: live queue depth, batch cap,
        primary endpoint, and the sender's exact counters — the broker's
        contribution to ``runtime.telemetry.TelemetrySnapshot``."""
        rows = []
        for g, s in sorted(self._senders.items()):
            row = s.stats_snapshot()
            row.update(group=g, shard=self.shard_of(g),
                       queue_depth=s.backlog(),
                       queue_capacity=self.cfg.queue_capacity,
                       batch_cap=s.batch_cap, primary=s.primary)
            rows.append(row)
        return rows

    def shard_telemetry(self) -> list[dict]:
        """Per-shard control-plane rollup (one row per shard, ascending):
        queue depth, sender counters, ring size — the sharded fan-in's
        contribution to ``TelemetrySnapshot.shards``, which is what lets
        the controller see one hot shard inside an otherwise calm fleet."""
        return [shard.telemetry() for shard in self.shards]

    def tenant_telemetry(self) -> dict[str, dict]:
        """Per-tenant QoS rollup (counters + live queued/parked gauges) —
        the broker's contribution to ``TelemetrySnapshot.tenants``.  Empty
        without a TenantRegistry."""
        if self.tenants is None:
            return {}
        out: dict[str, dict] = {
            name: {"backlog": 0, "parked": 0} for name in self.tenants.names()}
        merged: dict[str, dict[str, int]] = {}
        for s in self._senders.values():
            merge_counts(merged, s.stats.tenant_snapshot())
            queued, parked = s.tenant_backlog()
            for t, m in queued.items():
                out.setdefault(t, {"backlog": 0, "parked": 0})["backlog"] += m
            for t, m in parked.items():
                row = out.setdefault(t, {"backlog": 0, "parked": 0})
                row["backlog"] += m
                row["parked"] += m
        with self._quota_lock:
            merge_counts(merged, {t: {"quota_rejected": n}
                                  for t, n in self._quota_rejected.items()})
        for t, counts in merged.items():
            out.setdefault(t, {"backlog": 0, "parked": 0}).update(counts)
        return out

    # ---- control-plane actuators ----------------------------------------
    def set_batch_cap(self, cap: int, group: int | None = None) -> None:
        """Retune wire aggregation at runtime (controller: deep queue ⇒
        bigger frames to amortize, shallow queue ⇒ small frames for
        latency).  ``group=None`` applies to every sender."""
        targets = self._senders.values() if group is None \
            else [self._sender(group)]
        for s in targets:
            s.set_batch_cap(cap)

    def reroute_group(self, group: int) -> int | None:
        """Move one group's primary to the next healthy endpoint."""
        return self._sender(group).reroute()

    def reroute_from_endpoint(self, endpoint_idx: int) -> int:
        """Detector-driven failover, fanned out shard by shard: every group
        whose primary is the dead endpoint is proactively re-pointed on its
        owning shard.  Returns #groups rerouted."""
        return sum(shard.reroute_from_endpoint(endpoint_idx)
                   for shard in self.shards)

    def groups_on_endpoint(self, endpoint_idx: int) -> int:
        """#groups whose primary currently targets this endpoint — the
        cloud capacity plane's drain gate (a node may only power off once
        this reaches zero and its endpoint queue is empty)."""
        return sum(shard.groups_on_endpoint(endpoint_idx)
                   for shard in self.shards)

    def attach_endpoint(self, ep: Transport) -> int:
        """Register a freshly provisioned endpoint fleet-wide: append to the
        router's list and fan out to every shard's ring in order, so the
        shard-local index equals the fleet index on all of them.  Senders
        size their failover ring from their shard's list per call, so the
        new slot becomes routable on the next send/reroute.  Returns the
        new endpoint's fleet index."""
        self.endpoints.append(ep)
        fleet_idx = len(self.endpoints) - 1
        for shard in self.shards:
            idx = shard.attach_endpoint(ep)
            assert idx == fleet_idx, (
                f"shard {shard.shard_id} ring diverged: local idx {idx} != "
                f"fleet idx {fleet_idx}")
        return fleet_idx

    # -- the paper's three-call API surface lives in core.api ------------
    def register(self, schema: FieldSchema) -> None:
        self.schemas[f"{schema.field_name}/g{schema.group_id}"] = schema

    def _check_tenant(self, tenant: str) -> str:
        if self.tenants is not None and tenant not in self.tenants:
            raise ValueError(f"unknown tenant {tenant!r}: declare it in the "
                             "TenantRegistry before writing")
        return tenant

    def _quota_take(self, tenant: str, n: int) -> int:
        """Front-door rate quota: grant up to n admission tokens; the
        rejected remainder is counted per tenant, never silent."""
        if self._quota is None:
            return n
        granted = self._quota.take(tenant, n)
        if granted < n:
            with self._quota_lock:
                self._quota_rejected[tenant] = \
                    self._quota_rejected.get(tenant, 0) + (n - granted)
        return granted

    def write(self, field_name: str, rank: int, step: int,
              payload: np.ndarray, *, t: float | None = None,
              tenant: str = "default") -> bool:
        """``t`` overrides the event timestamp (default: the clock's now).
        Producers that know their simulation time should pass it — event
        time then survives backpressure stalls and crash-recovery delays,
        keeping window membership identical across replays.  ``tenant``
        tags the record with its QoS class (repro.tenancy)."""
        self._check_tenant(tenant)
        if self._quota_take(tenant, 1) < 1:
            return False
        g = self.plan.group_of(rank)
        rec = StreamRecord(field_name=field_name, group_id=g, rank=rank,
                           step=step, payload=np.asarray(payload),
                           t_generated=self.clock.now() if t is None
                           else float(t), tenant=tenant)
        return self._sender(g).submit(rec)

    def write_batch(self, field_name: str, ranks, steps, payloads, *,
                    t: float | None = None, tenant: str = "default") -> int:
        """Submit many records at once, one aggregated queue item per group,
        so each group ships the batch as (at most) one wire frame.  ``ranks``,
        ``steps`` and ``payloads`` are aligned sequences; returns #records
        accepted (backpressure may drop whole per-group batches).  ``t``:
        explicit event timestamp, as in :meth:`write`.  ``tenant`` applies
        to every record in the call; the rate quota (if any) admits a prefix
        and counts the rejected remainder."""
        self._check_tenant(tenant)
        triplets = list(zip(ranks, steps, payloads))
        granted = self._quota_take(tenant, len(triplets))
        by_group: dict[int, list[StreamRecord]] = {}
        now = self.clock.now() if t is None else float(t)
        for rank, step, payload in triplets[:granted]:
            g = self.plan.group_of(rank)
            by_group.setdefault(g, []).append(
                StreamRecord(field_name=field_name, group_id=g, rank=rank,
                             step=step, payload=np.asarray(payload),
                             t_generated=now, tenant=tenant))
        return sum(self._sender(g).submit_batch(recs)
                   for g, recs in by_group.items())

    def flush(self, timeout: float | None = None) -> None:
        """Block until every written record is delivered (or dropped/errored
        out) — exact accounting, no queue-emptiness race.

        Gives up early only when *this* flush has watched a full retry budget
        burn with zero delivery progress.  The error window is measured as a
        delta from the start of the flush (and restarts whenever a record is
        delivered or dropped), so error counts accumulated during a past
        failure episode cannot trigger a return while records written after
        the endpoints recovered are still in flight."""
        deadline = self.clock.now() + (timeout or self.cfg.flush_timeout_s)
        if self.cfg.delivery == "exactly-once":
            # the WAL is the exact in-flight ledger: flushed means every
            # appended record is acked by an endpoint.  No early give-up —
            # an endpoint may come back, and giving up early would lie.
            while self.clock.now() < deadline:
                if self.wal.unacked_records() == 0:
                    return
                self.clock.sleep(0.01)
            return
        st = self.stats
        err_mark = st.send_errors
        progress_mark = st.sent + st.dropped
        while self.clock.now() < deadline:
            st = self.stats
            undelivered = st.written - st.sent - st.dropped
            if undelivered <= 0 \
                    and all(s.backlog() == 0 for s in self._senders.values()):
                return
            delivered = st.sent + st.dropped
            if delivered != progress_mark:     # progress: restart error window
                progress_mark = delivered
                err_mark = st.send_errors
            elif st.send_errors - err_mark >= \
                    self.cfg.retry_limit * max(undelivered, 1):
                return  # endpoints down and this flush's retries exhausted
            self.clock.sleep(0.01)

    def finalize(self) -> BrokerStats:
        self.flush()
        for s in self._senders.values():
            s.stop(timeout=self.cfg.flush_timeout_s)
        return self.stats

    # ---- exactly-once lifecycle -----------------------------------------
    def kill(self) -> BrokerStats:
        """Simulated hard crash: every sender stops without draining (see
        _GroupSender.kill).  Returns the final stats of this incarnation so
        a replacement broker can fold them into its accounting."""
        for s in self._senders.values():
            s.kill()
        return self.stats

    def commit_wal(self) -> dict[int, dict]:
        """Checkpoint hook: mark everything appended so far as committed
        (the caller guarantees the pipeline is quiescent, i.e. it is all
        acked and applied) and trim.  Returns post-commit trim points."""
        out = {}
        for g, s in self._senders.items():
            if s.wal is not None:
                s.wal.commit(s.wal.last_seq)
                out[g] = s.wal.points()
        return out

    def wal_points(self) -> dict[int, dict]:
        """Read-only per-group WAL trim points ({} in at-most-once mode)."""
        return self.wal.points() if self.wal is not None else {}

    def unacked_records(self) -> int:
        return self.wal.unacked_records() if self.wal is not None else 0
