"""The ElasticBroker producer-side runtime.

Mirrors the paper's design (§3.1): producer ranks are partitioned into groups;
each group registers with one Cloud endpoint; ``write`` converts a field
snapshot into a stream record and hands it to an **asynchronous dispatcher**
(bounded queue + background sender thread per group) so the producer —
an OpenFOAM solver there, a JAX train/serve step here — never stalls on the
wide-area link.  That asynchrony is what produces the paper's Fig-6 result
(ElasticBroker ≈ simulation-only elapsed time, file-based I/O much slower).

Fault tolerance beyond the paper: bounded-queue backpressure policies
(block / drop_oldest / sample), endpoint failure detection and group
re-routing to surviving endpoints, and per-group delivery metrics.

Wire aggregation (the paper's "data aggregation" duty): each sender
wake-up coalesces all queued records — up to the sender's ``batch_cap``,
seeded from ``cfg.max_batch_records`` and adjustable at runtime
(``Broker.set_batch_cap``, driven by the elasticity controller from queue
depth) — into one batched frame (core/records.py ``encode_batch``), so
framing, compression, and the endpoint's bandwidth model are paid per batch
rather than per record.  ``stats.frames_sent`` vs ``stats.sent`` shows the
achieved aggregation ratio.

Stats accounting is race-free by construction: every ``_GroupSender`` owns a
lock-guarded :class:`_SenderStats` that only its producers/sender touch, and
``Broker.stats`` merges them into one :class:`BrokerStats` view on read —
counters stay exact under arbitrary producer/sender concurrency (the seed
shared one unlocked dataclass across all sender threads, so ``+=`` lost
updates under load).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from repro.core.grouping import GroupPlan
from repro.core.records import FieldSchema, StreamRecord, encode, encode_batch
from repro.core.transport import Transport
from repro.runtime.clock import Clock, ensure_clock


@dataclass
class BrokerConfig:
    compress: str = "int8+zstd"       # none | zstd | int8 | int8+zstd
    queue_capacity: int = 256         # records per group queue
    backpressure: str = "drop_oldest" # block | drop_oldest | sample
    sample_keep: int = 2              # with `sample`: keep 1 of N on pressure
    flush_timeout_s: float = 10.0
    retry_limit: int = 3
    # Wire aggregation: each sender wake-up coalesces every record already
    # queued (up to this many) into one batched frame — one msgpack frame,
    # one zstd pass, one Endpoint.push per batch instead of per record.
    # 1 disables coalescing (seed per-record framing).  This seeds each
    # sender's mutable ``batch_cap``.
    max_batch_records: int = 32
    delta_encode: bool = False        # delta-vs-previous-step in batch frames


@dataclass
class BrokerStats:
    """Merged, read-only view over the per-sender counters (``Broker.stats``
    builds a fresh one per read)."""

    written: int = 0
    sent: int = 0                     # records delivered
    frames_sent: int = 0              # wire frames pushed (≤ sent)
    dropped: int = 0
    rerouted: int = 0
    bytes_sent: int = 0
    send_errors: int = 0
    queue_high_water: int = 0
    # Effective deployment shape: a connect-time plan that asks for more
    # groups than there are endpoints is silently shrunk; these two fields
    # make that visible (planned != effective ⇒ mis-sized deployment).
    planned_groups: int = 0
    effective_groups: int = 0


_COUNTER_FIELDS = ("written", "sent", "frames_sent", "dropped", "rerouted",
                   "bytes_sent", "send_errors")


class _SenderStats:
    """Lock-guarded per-sender counters.  One instance per ``_GroupSender``;
    the producer threads (submit/submit_batch) and the sender thread mutate
    it under ``lock``, so reads via ``snapshot()`` are exact."""

    __slots__ = ("lock", "written", "sent", "frames_sent", "dropped",
                 "rerouted", "bytes_sent", "send_errors", "queue_high_water")

    def __init__(self):
        self.lock = threading.Lock()
        for f in _COUNTER_FIELDS:
            setattr(self, f, 0)
        self.queue_high_water = 0

    def add(self, **deltas: int) -> None:
        with self.lock:
            for name, d in deltas.items():
                setattr(self, name, getattr(self, name) + d)

    def observe_depth(self, depth: int) -> None:
        with self.lock:
            if depth > self.queue_high_water:
                self.queue_high_water = depth

    def snapshot(self) -> dict:
        with self.lock:
            out = {f: getattr(self, f) for f in _COUNTER_FIELDS}
            out["queue_high_water"] = self.queue_high_water
            return out


class _GroupSender(threading.Thread):
    """One background sender per producer group (paper: one TCP stream per
    group to its designated endpoint)."""

    def __init__(self, group_id: int, endpoints: list[Transport], primary: int,
                 cfg: BrokerConfig, clock: Clock | None = None):
        super().__init__(daemon=True, name=f"broker-g{group_id}")
        self.group_id = group_id
        self.endpoints = endpoints            # anything satisfying Transport
        self.primary = primary
        self.cfg = cfg
        self.clock = ensure_clock(clock)
        # each sender owns its counters; Broker.stats merges them on read
        self.stats = _SenderStats()
        # mutable wire-aggregation cap, adapted at runtime from queue depth
        # by the elasticity controller (seeded from the static config)
        self.batch_cap = max(1, cfg.max_batch_records)
        self.q: queue.Queue = queue.Queue(maxsize=cfg.queue_capacity)
        # NB: must not be named `_stop` — that would shadow Thread._stop(),
        # which threading.join() calls on finished threads
        self._stop_evt = threading.Event()
        self._sample_lock = threading.Lock()
        self._sample_ctr = 0

    def set_batch_cap(self, cap: int) -> int:
        self.batch_cap = max(1, int(cap))
        return self.batch_cap

    def _sample_tick(self) -> bool:
        """1-of-N admission under `sample` pressure, race-free."""
        with self._sample_lock:
            self._sample_ctr += 1
            return self._sample_ctr % self.cfg.sample_keep == 0

    # ---- producer side ------------------------------------------------
    def _evict_one(self) -> bool:
        """Drop the oldest queue item, counting its records (items are single
        records or submit_batch lists)."""
        try:
            evicted = self.q.get_nowait()
        except queue.Empty:
            return False
        self.stats.add(dropped=len(evicted) if isinstance(evicted, list) else 1)
        return True

    def submit(self, rec: StreamRecord) -> bool:
        self.stats.add(written=1)
        self.stats.observe_depth(self.q.qsize())
        if self.cfg.backpressure == "block":
            self.clock.queue_put(self.q, rec)
            return True
        try:
            self.q.put_nowait(rec)
            return True
        except queue.Full:
            if self.cfg.backpressure == "drop_oldest":
                self._evict_one()
                try:
                    self.q.put_nowait(rec)
                    return True
                except queue.Full:
                    self.stats.add(dropped=1)
                    return False
            # sample: keep 1 of N while under pressure
            if self._sample_tick():
                if self._evict_one():
                    try:
                        self.q.put_nowait(rec)
                        return True
                    except queue.Full:
                        pass
            self.stats.add(dropped=1)
            return False

    def submit_batch(self, recs: list[StreamRecord]) -> int:
        """Enqueue a pre-batched record list as ONE queue item, so the whole
        batch leaves as (at most) one wire frame regardless of sender-thread
        timing — this is what gives ``FieldHandle.write_batch`` its ≤ one
        frame per (field, group) guarantee.  Returns #records accepted."""
        if not recs:
            return 0
        self.stats.add(written=len(recs))
        self.stats.observe_depth(self.q.qsize())
        item = list(recs)
        if self.cfg.backpressure == "block":
            self.clock.queue_put(self.q, item)
            return len(item)
        try:
            self.q.put_nowait(item)
            return len(item)
        except queue.Full:
            if self.cfg.backpressure == "drop_oldest":
                self._evict_one()
                try:
                    self.q.put_nowait(item)
                    return len(item)
                except queue.Full:
                    pass
            elif self.cfg.backpressure == "sample":
                # same 1-of-N policy as submit(), at batch granularity
                if self._sample_tick() and self._evict_one():
                    try:
                        self.q.put_nowait(item)
                        return len(item)
                    except queue.Full:
                        pass
            # overflow: the whole batch is one unit — drop it whole
            self.stats.add(dropped=len(item))
            return 0

    # ---- sender loop ---------------------------------------------------
    def run(self):
        """Drain the queue in aggregated frames: each wake-up takes every
        queued record (up to ``batch_cap``, re-read per wake-up so the
        controller can retune it live) and ships them as one batched wire
        frame, so a burst of writes pays framing/compression/bandwidth-model
        cost once per batch, not once per record.  Queue items are single
        records (``submit``) or record lists (``submit_batch``); an oversized
        list is chunked at the cap."""
        while not self._stop_evt.is_set() or not self.q.empty():
            cap = max(1, self.batch_cap)
            item = self.clock.queue_get(self.q, timeout=0.05)
            if item is None:
                continue
            recs = list(item) if isinstance(item, list) else [item]
            while len(recs) < cap:
                try:
                    nxt = self.q.get_nowait()
                except queue.Empty:
                    break
                recs.extend(nxt if isinstance(nxt, list) else [nxt])
            for i in range(0, len(recs), cap):
                chunk = recs[i:i + cap]
                if len(chunk) == 1:
                    blob = encode(chunk[0], compress=self.cfg.compress)
                else:
                    blob = encode_batch(chunk, compress=self.cfg.compress,
                                        delta=self.cfg.delta_encode)
                if self._send(blob):
                    self.stats.add(sent=len(chunk), frames_sent=1,
                                   bytes_sent=len(blob))
                else:
                    self.stats.add(dropped=len(chunk))  # retries exhausted
        # leave the clock's schedule on exit so a virtual schedule never
        # waits out the dead-participant watchdog for this thread
        self.clock.detach()

    def _send(self, blob: bytes) -> bool:
        """Send to primary; on failure re-route to the next healthy endpoint
        (pure remapping — the paper's grouping makes failover trivial)."""
        n = len(self.endpoints)
        for attempt in range(self.cfg.retry_limit):
            ep = self.endpoints[(self.primary + attempt) % n]
            try:
                if ep.healthy():
                    ep.push(self.group_id, blob)
                    if attempt > 0:
                        self.stats.add(rerouted=1)
                        self.primary = (self.primary + attempt) % n
                    return True
            except Exception:
                pass
            self.stats.add(send_errors=1)
        return False

    def reroute(self) -> int | None:
        """Proactively move the primary off a known-dead endpoint (the
        controller's FailureDetector path) instead of waiting for the next
        send to burn retries.  Returns the new primary index, or None when no
        healthy endpoint exists."""
        n = len(self.endpoints)
        for shift in range(1, n + 1):
            idx = (self.primary + shift) % n
            try:
                if self.endpoints[idx].healthy():
                    if idx != self.primary:
                        self.primary = idx
                        self.stats.add(rerouted=1)
                    return idx
            except Exception:
                continue
        return None

    def stop(self, timeout: float):
        self._stop_evt.set()
        # clock-mediated join: under VirtualClock a native join would stall
        # the schedule (the joiner is runnable but blocked outside the clock)
        self.clock.join(self, timeout=timeout)


class Broker:
    """Producer-side broker: one per job, shared by all local ranks."""

    def __init__(self, plan: GroupPlan, endpoints: list[Transport],
                 cfg: BrokerConfig | None = None, *,
                 clock: Clock | None = None):
        assert len(endpoints) >= plan.n_groups, (
            f"{plan.n_groups} groups need >= that many endpoints, "
            f"got {len(endpoints)}")
        self.plan = plan
        self.cfg = cfg or BrokerConfig()
        self.clock = ensure_clock(clock)
        self.endpoints = list(endpoints)
        self.planned_groups = plan.n_groups
        self.effective_groups = plan.n_groups
        self.schemas: dict[str, FieldSchema] = {}
        self._senders: dict[int, _GroupSender] = {}
        for g in range(plan.n_groups):
            s = _GroupSender(g, endpoints, g % len(endpoints), self.cfg,
                             self.clock)
            self.clock.thread_started(s)
            s.start()
            self._senders[g] = s

    # ---- observability --------------------------------------------------
    @property
    def stats(self) -> BrokerStats:
        """Exact merged view: per-sender counters aggregated on read."""
        out = BrokerStats(planned_groups=self.planned_groups,
                          effective_groups=self.effective_groups)
        for s in self._senders.values():
            snap = s.stats.snapshot()
            for f in _COUNTER_FIELDS:
                setattr(out, f, getattr(out, f) + snap[f])
            out.queue_high_water = max(out.queue_high_water,
                                       snap["queue_high_water"])
        return out

    def group_telemetry(self) -> list[dict]:
        """Per-group control-plane sample: live queue depth, batch cap,
        primary endpoint, and the sender's exact counters — the broker's
        contribution to ``runtime.telemetry.TelemetrySnapshot``."""
        rows = []
        for g, s in sorted(self._senders.items()):
            row = s.stats.snapshot()
            row.update(group=g, queue_depth=s.q.qsize(),
                       queue_capacity=self.cfg.queue_capacity,
                       batch_cap=s.batch_cap, primary=s.primary)
            rows.append(row)
        return rows

    # ---- control-plane actuators ----------------------------------------
    def set_batch_cap(self, cap: int, group: int | None = None) -> None:
        """Retune wire aggregation at runtime (controller: deep queue ⇒
        bigger frames to amortize, shallow queue ⇒ small frames for
        latency).  ``group=None`` applies to every sender."""
        targets = self._senders.values() if group is None \
            else [self._senders[group]]
        for s in targets:
            s.set_batch_cap(cap)

    def reroute_group(self, group: int) -> int | None:
        """Move one group's primary to the next healthy endpoint."""
        return self._senders[group].reroute()

    def reroute_from_endpoint(self, endpoint_idx: int) -> int:
        """Detector-driven failover: every group whose primary is the dead
        endpoint is proactively re-pointed.  Returns #groups rerouted."""
        n = 0
        for s in self._senders.values():
            if s.primary == endpoint_idx and s.reroute() is not None:
                n += 1
        return n

    # -- the paper's three-call API surface lives in core.api ------------
    def register(self, schema: FieldSchema) -> None:
        self.schemas[f"{schema.field_name}/g{schema.group_id}"] = schema

    def write(self, field_name: str, rank: int, step: int,
              payload: np.ndarray) -> bool:
        g = self.plan.group_of(rank)
        rec = StreamRecord(field_name=field_name, group_id=g, rank=rank,
                           step=step, payload=np.asarray(payload),
                           t_generated=self.clock.now())
        return self._senders[g].submit(rec)

    def write_batch(self, field_name: str, ranks, steps, payloads) -> int:
        """Submit many records at once, one aggregated queue item per group,
        so each group ships the batch as (at most) one wire frame.  ``ranks``,
        ``steps`` and ``payloads`` are aligned sequences; returns #records
        accepted (backpressure may drop whole per-group batches)."""
        by_group: dict[int, list[StreamRecord]] = {}
        now = self.clock.now()
        for rank, step, payload in zip(ranks, steps, payloads):
            g = self.plan.group_of(rank)
            by_group.setdefault(g, []).append(
                StreamRecord(field_name=field_name, group_id=g, rank=rank,
                             step=step, payload=np.asarray(payload),
                             t_generated=now))
        return sum(self._senders[g].submit_batch(recs)
                   for g, recs in by_group.items())

    def flush(self, timeout: float | None = None) -> None:
        """Block until every written record is delivered (or dropped/errored
        out) — exact accounting, no queue-emptiness race.

        Gives up early only when *this* flush has watched a full retry budget
        burn with zero delivery progress.  The error window is measured as a
        delta from the start of the flush (and restarts whenever a record is
        delivered or dropped), so error counts accumulated during a past
        failure episode cannot trigger a return while records written after
        the endpoints recovered are still in flight."""
        deadline = self.clock.now() + (timeout or self.cfg.flush_timeout_s)
        st = self.stats
        err_mark = st.send_errors
        progress_mark = st.sent + st.dropped
        while self.clock.now() < deadline:
            st = self.stats
            undelivered = st.written - st.sent - st.dropped
            if undelivered <= 0 and all(s.q.empty() for s in self._senders.values()):
                return
            delivered = st.sent + st.dropped
            if delivered != progress_mark:     # progress: restart error window
                progress_mark = delivered
                err_mark = st.send_errors
            elif st.send_errors - err_mark >= self.cfg.retry_limit * max(undelivered, 1):
                return  # endpoints down and this flush's retries exhausted
            self.clock.sleep(0.01)

    def finalize(self) -> BrokerStats:
        self.flush()
        for s in self._senders.values():
            s.stop(timeout=self.cfg.flush_timeout_s)
        return self.stats
