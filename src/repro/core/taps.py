"""Bridge between in-graph field taps and the host-side broker.

The model trunk emits a ``taps`` pytree per step:
  resid_norm: (R, B)  — per-layer-repeat, per-sample residual norms
  snapshot:   (R, B, tap_dim) — strided residual field vectors

Batch stays sharded over the mesh ``data`` axis, so each data-slice is a
"process region" (the paper's MPI process).  ``TapStreamer.publish`` slices
the per-region rows out of the (addressable) tap arrays and issues ONE
``FieldHandle.write_batch`` per field — all regions of a field ride a single
aggregated queue item per group, i.e. ≤ one wire frame per (field, group) —
asynchronously, on the broker's sender threads, never blocking the train
loop.
"""
from __future__ import annotations

import numpy as np

from repro.core.broker import Broker
from repro.workflow.session import FieldHandle, Session


class TapStreamer:
    """One per training/serving job; ranks = mesh data slices (regions).

    Accepts a :class:`repro.workflow.Session` (preferred — handles come from
    ``session.open_field``) or a bare :class:`Broker` (legacy call sites)."""

    def __init__(self, session: Session | Broker, n_regions: int,
                 fields: tuple[str, ...] = ("resid_norm", "snapshot")):
        self.n_regions = n_regions
        self.fields = fields
        if isinstance(session, Session):
            self._handles = {f: session.open_field(f) for f in fields}
        else:
            self._handles = {f: FieldHandle(session, f) for f in fields}

    def publish(self, step: int, taps: dict) -> int:
        """taps: pytree of numpy/jax arrays with a batch axis at dim 1.

        Region r owns the batch rows [r*B/n, (r+1)*B/n).  Returns #records.
        """
        n = 0
        for f in self.fields:
            arr = np.asarray(taps[f])
            B = arr.shape[1]
            per = max(1, B // self.n_regions)
            ranks, payloads = [], []
            for r in range(self.n_regions):
                rows = arr[:, r * per:(r + 1) * per]
                if rows.size == 0:
                    continue
                # region field snapshot: mean over region samples -> (R,) or (R,tap)
                ranks.append(r)
                payloads.append(rows.mean(axis=1))
            n += self._handles[f].write_batch(step, payloads, ranks=ranks)
        return n
