"""Bridge between in-graph field taps and the host-side broker.

The model trunk emits a ``taps`` pytree per step:
  resid_norm: (R, B)  — per-layer-repeat, per-sample residual norms
  snapshot:   (R, B, tap_dim) — strided residual field vectors

Batch stays sharded over the mesh ``data`` axis, so each data-slice is a
"process region" (the paper's MPI process).  ``TapStreamer.publish`` slices
the per-region rows out of the (addressable) tap arrays and issues one
``broker_write`` per (field, region) — asynchronously, on the broker's
sender threads, never blocking the train loop.
"""
from __future__ import annotations

import numpy as np

from repro.core.api import broker_ctx, broker_init, broker_write
from repro.core.broker import Broker


class TapStreamer:
    """One per training/serving job; ranks = mesh data slices (regions)."""

    def __init__(self, broker: Broker, n_regions: int,
                 fields: tuple[str, ...] = ("resid_norm", "snapshot")):
        self.n_regions = n_regions
        self.fields = fields
        self._ctx: dict[tuple[str, int], broker_ctx] = {}
        for f in fields:
            for r in range(n_regions):
                self._ctx[(f, r)] = broker_init(f, r, broker=broker)

    def publish(self, step: int, taps: dict) -> int:
        """taps: pytree of numpy/jax arrays with a batch axis at dim 1.

        Region r owns the batch rows [r*B/n, (r+1)*B/n).  Returns #records.
        """
        n = 0
        for f in self.fields:
            arr = np.asarray(taps[f])
            B = arr.shape[1]
            per = max(1, B // self.n_regions)
            for r in range(self.n_regions):
                rows = arr[:, r * per:(r + 1) * per]
                if rows.size == 0:
                    continue
                # region field snapshot: mean over region samples -> (R,) or (R,tap)
                payload = rows.mean(axis=1)
                if broker_write(self._ctx[(f, r)], step, payload):
                    n += 1
        return n
