"""Pluggable producer→endpoint transports (the broker's wire seam).

The paper's Listing 1.1 binds producer groups to ``struct CloudEndpoint
{char* service_ip; int service_port;}``.  The broker only ever needs two
operations from that struct — *is the service up* and *ship one framed
blob* — so those two calls are the :class:`Transport` protocol, and
anything implementing them can carry a group's stream:

* :class:`CloudEndpoint` — the paper's struct.  By default it delegates
  straight to the in-process :class:`repro.streaming.endpoint.Endpoint`
  (the Redis stand-in) via ``handle``; when a ``transport`` is attached it
  routes through that instead, so the same object works for both wirings.
* :class:`LoopbackTransport` — frames travel over a real localhost TCP
  socket to a server thread that feeds the Endpoint.  Functionally
  identical to the in-process path (same failover/health semantics), it
  exists to prove the seam: a future Redis/ADIOS2/gRPC transport only has
  to implement ``healthy``/``push``/``close``.
"""
from __future__ import annotations

import socket
import struct
import threading
from dataclasses import dataclass
from typing import Protocol, runtime_checkable


@runtime_checkable
class Transport(Protocol):
    """What the broker's group senders require of an endpoint binding."""

    def healthy(self) -> bool:
        ...

    def push(self, group_id: int, blob: bytes) -> None:
        ...

    def close(self) -> None:
        ...


@dataclass
class CloudEndpoint:
    """Paper: {char* service_ip; int service_port;}."""

    service_ip: str
    service_port: int
    handle: object = None       # the in-process Endpoint (Redis stand-in)
    transport: object = None    # optional wire transport (e.g. loopback TCP)
    detached: bool = False      # powered off by the cloud capacity plane

    def healthy(self) -> bool:
        if self.detached:
            return False
        if self.transport is not None:
            return self.transport.healthy()
        return self.handle is not None and self.handle.healthy()

    def push(self, group_id: int, blob: bytes) -> None:
        if self.transport is not None:
            self.transport.push(group_id, blob)
        else:
            self.handle.push(group_id, blob)

    def close(self) -> None:
        if self.transport is not None:
            self.transport.close()

    def detach(self) -> None:
        """Power-off detach: tear down the wire binding and mark the slot
        dead.  The slot object itself stays in every endpoint list as a
        tombstone so fleet indices (group primaries, node records) remain
        stable after scale-in."""
        self.detached = True
        if self.transport is not None:
            self.transport.close()


# ---------------------------------------------------------------------------
# Loopback TCP transport
# ---------------------------------------------------------------------------

_HDR = struct.Struct("!BII")    # frame type, group_id, payload length
_T_DATA = 0
_T_HEALTH = 1


def _recv_exact(conn: socket.socket, n: int) -> bytes | None:
    buf = b""
    while len(buf) < n:
        chunk = conn.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def _answer(endpoint, typ: int, gid: int, blob: bytes) -> bool:
    """Server-side frame semantics, shared by every loopback flavor: a
    health probe answers the endpoint's health; a data frame is pushed and
    acked (1) or rejected (0)."""
    if typ == _T_HEALTH:
        return bool(endpoint.healthy())
    try:
        endpoint.push(gid, blob)
        return True
    except Exception:
        return False


class LoopbackTransport:
    """Ship frames to an Endpoint over a localhost TCP socket.

    Server side: an accept loop on 127.0.0.1:<ephemeral>; every frame is
    either data (``Endpoint.push``) or a health probe, answered with a
    one-byte ack (1 = accepted / healthy, 0 = endpoint down).  Client
    side: a persistent connection (lock-guarded — multiple group senders
    may share one endpoint) with one reconnect attempt on socket failure.
    A rejected data frame raises ``ConnectionError`` exactly like the
    in-process path, so the broker's retry/failover logic is transport-
    agnostic.
    """

    def __init__(self, endpoint):
        self.endpoint = endpoint
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind(("127.0.0.1", 0))
        self._srv.listen(16)
        # accept() must wake periodically: close() from another thread does
        # not reliably interrupt a blocking accept on all platforms
        self._srv.settimeout(0.2)
        self.port = self._srv.getsockname()[1]
        self._closing = threading.Event()
        self._cli: socket.socket | None = None
        self._cli_lock = threading.Lock()
        self._accepter = threading.Thread(target=self._accept_loop,
                                          daemon=True,
                                          name=f"loopback-:{self.port}")
        self._accepter.start()

    # ---- server side ----------------------------------------------------
    def _accept_loop(self):
        while not self._closing.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            threading.Thread(target=self._serve, args=(conn,),
                             daemon=True).start()

    def _serve(self, conn: socket.socket):
        try:
            while True:
                hdr = _recv_exact(conn, _HDR.size)
                if hdr is None:
                    return
                typ, gid, ln = _HDR.unpack(hdr)
                blob = _recv_exact(conn, ln) if ln else b""
                if blob is None:
                    return
                ok = _answer(self.endpoint, typ, gid, blob)
                conn.sendall(b"\x01" if ok else b"\x00")
        except OSError:
            pass
        finally:
            conn.close()

    # ---- client side ----------------------------------------------------
    def _request(self, typ: int, group_id: int, blob: bytes) -> bool:
        with self._cli_lock:
            for attempt in range(2):           # one reconnect on stale socket
                try:
                    if self._cli is None:
                        self._cli = socket.create_connection(
                            ("127.0.0.1", self.port), timeout=5.0)
                    self._cli.sendall(_HDR.pack(typ, group_id, len(blob)) + blob)
                    ack = _recv_exact(self._cli, 1)
                    if ack is None:
                        raise ConnectionError("loopback server hung up")
                    return ack == b"\x01"
                except OSError:
                    if self._cli is not None:
                        try:
                            self._cli.close()
                        finally:
                            self._cli = None
                    if attempt:
                        raise
        raise ConnectionError("unreachable")   # pragma: no cover

    def healthy(self) -> bool:
        if self._closing.is_set():
            return False
        try:
            return self._request(_T_HEALTH, 0, b"")
        except OSError:
            return False

    def push(self, group_id: int, blob: bytes) -> None:
        if not self._request(_T_DATA, group_id, blob):
            raise ConnectionError(
                f"endpoint behind loopback:{self.port} rejected frame")

    def close(self) -> None:
        self._closing.set()
        with self._cli_lock:
            if self._cli is not None:
                try:
                    self._cli.close()
                finally:
                    self._cli = None
        try:
            self._srv.close()
        except OSError:
            pass


class VirtualLoopbackTransport:
    """The loopback frame protocol on simulated time.

    Chaos/replay scenarios want coverage of the real TCP framing path, but
    socket I/O blocks outside a :class:`~repro.runtime.clock.VirtualClock`
    schedule.  This transport packs each request into the exact byte frame
    ``LoopbackTransport`` would put on the wire, re-parses it with the same
    header codec, and answers it through the same server-side handler
    (:func:`_answer`) — synchronously, in-process, deterministically.  Same
    framing, same rejection semantics, zero sockets.  An optional
    ``latency_s`` charges virtual time per round-trip."""

    _ports = iter(range(50_000, 60_000))

    def __init__(self, endpoint, clock=None, latency_s: float = 0.0):
        from repro.runtime.clock import ensure_clock
        self.endpoint = endpoint
        self.clock = ensure_clock(clock)
        self.latency_s = latency_s
        self.port = next(self._ports)
        self._closing = False

    def _request(self, typ: int, group_id: int, blob: bytes) -> bool:
        if self._closing:
            raise ConnectionError("virtual loopback transport closed")
        wire = _HDR.pack(typ, group_id, len(blob)) + blob
        typ2, gid2, ln = _HDR.unpack(wire[:_HDR.size])  # server-side parse
        payload = wire[_HDR.size:_HDR.size + ln]
        if self.latency_s:
            self.clock.sleep(self.latency_s)
        return _answer(self.endpoint, typ2, gid2, payload)

    def healthy(self) -> bool:
        if self._closing:
            return False
        return self._request(_T_HEALTH, 0, b"")

    def push(self, group_id: int, blob: bytes) -> None:
        if not self._request(_T_DATA, group_id, blob):
            raise ConnectionError(
                f"endpoint behind virtual-loopback:{self.port} rejected frame")

    def close(self) -> None:
        self._closing = True
