"""Stream records: the Cloud-native unit ElasticBroker ships.

A record carries one field snapshot from one producer rank at one step,
exactly like the paper's ``broker_write(ctx, step, data, len)`` payloads:
timestep + serialized field data + schema, msgpack-framed, optionally
zstd-compressed or int8 block-quantized (the TPU-side Pallas ``quant`` kernel
implements the same codec in-graph; this is the host-side mirror).

Wire frames (first byte is the frame tag):

* ``M`` — one record, msgpack          * ``Z`` — one record, zstd(msgpack)
* ``B`` — record batch, msgpack        * ``C`` — record batch, zstd(msgpack)

Batched frames (``encode_batch``/``decode_batch``) amortize the per-message
cost that dominates streaming pipelines: N records share **one** msgpack
frame, **one** zstd pass, and **one** int8 quantization pass over the
concatenated payload buffer.  Identity columns (field/group/rank) collapse
to a scalar when uniform across the batch (the shared-schema header).
Optional delta encoding (``delta=True``) stores ``payload[i] -
payload[i-1]`` whenever record i-1 belongs to the same stream and has the
same shape — a big win for slowly-varying CFD fields under zstd/int8; the
``d`` flag column marks delta'd records and decode reconstructs the chain in
order (chains reset at every stream/shape change).  ``decode_any``
dispatches on the tag and always returns a list, so consumers
(Endpoint.push) are agnostic to framing.

int8 batch frames use **per-stream scales** (enc tag ``int8s``): quantization
blocks restart at every record boundary instead of running blindly over the
concatenated buffer, and deltas are **closed-loop** — each delta is taken
against the *dequantized* reconstruction of the previous record, so the
decoder's accumulated value is bitwise the encoder's reconstruction and
quantization error no longer accumulates along a delta chain (every record's
error is bounded by its own quantization step).  Legacy ``int8`` batch
frames (shared blocks over the concatenated buffer) still decode.

The uniform non-delta ``int8s`` rows path can quantize/dequantize through
the Pallas kernel (kernels/quant.py) instead of host numpy —
``set_quant_backend("auto"|"numpy"|"pallas")`` — with **byte-identical**
wire frames in both directions; numpy stays the reference oracle and the
CPU fallback.
"""
from __future__ import annotations

import struct
import time
from dataclasses import dataclass, field
from typing import Any

import msgpack
import numpy as np

try:
    import zstandard as zstd
    _ZSTD_C = zstd.ZstdCompressor(level=1)
    _ZSTD_D = zstd.ZstdDecompressor()
except Exception:  # pragma: no cover
    zstd = None

QBLOCK = 256
# scale = max|block| * (1/127), as an explicit f32 multiply: XLA rewrites
# division-by-constant into multiply-by-reciprocal, so the kernel and the
# host path must share the multiply form for byte-identical frames
_INV127 = np.float32(1.0 / 127.0)


@dataclass(frozen=True)
class FieldSchema:
    """Registered at broker_init, mirrors the paper's field registration."""

    field_name: str              # e.g. "velocity_x", "resid_norm/layer"
    shape: tuple[int, ...]       # per-record payload shape
    dtype: str                   # numpy dtype name
    group_id: int                # producer group (paper: MPI process group)


@dataclass
class StreamRecord:
    field_name: str
    group_id: int
    rank: int                    # producer rank within the job
    step: int                    # simulation / training step
    payload: np.ndarray
    t_generated: float = field(default_factory=time.time)
    tenant: str = "default"      # QoS tenant class (repro.tenancy)

    def key(self) -> str:
        return f"{self.field_name}/g{self.group_id}/r{self.rank}"


# ---------------------------------------------------------------------------
# Wire codec
# ---------------------------------------------------------------------------

def quantize_int8(x: np.ndarray) -> dict:
    """Blockwise int8: flat blocks of QBLOCK with one f32 scale each — the
    host mirror of kernels/quant.py."""
    flat = np.asarray(x, np.float32).reshape(-1)
    pad = (-flat.size) % QBLOCK
    padded = np.pad(flat, (0, pad))
    blocks = padded.reshape(-1, QBLOCK)
    scale = np.maximum(np.abs(blocks).max(axis=1), 1e-20) * _INV127
    q = np.clip(np.round(blocks / scale[:, None]), -127, 127).astype(np.int8)
    return {"q": q.tobytes(), "scale": scale.astype(np.float32).tobytes(),
            "n": int(flat.size), "shape": list(x.shape)}


def dequantize_int8(d: dict) -> np.ndarray:
    q = np.frombuffer(d["q"], np.int8).reshape(-1, QBLOCK).astype(np.float32)
    scale = np.frombuffer(d["scale"], np.float32)
    flat = (q * scale[:, None]).reshape(-1)[: d["n"]]
    return flat.reshape(d["shape"])


def _quantize_stream(flat: np.ndarray) -> tuple[bytes, bytes]:
    """Record-local int8: blocks of QBLOCK restart at the record boundary and
    the last block is truncated (no padding on the wire).  Returns
    (q bytes — exactly flat.size — , per-block f32 scale bytes)."""
    n = flat.size
    nb = max(1, (n + QBLOCK - 1) // QBLOCK)
    padded = np.pad(flat, (0, nb * QBLOCK - n)).reshape(nb, QBLOCK)
    scale = np.maximum(np.abs(padded).max(axis=1), 1e-20) * _INV127
    q = np.clip(np.round(padded / scale[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1)[:n].tobytes(), scale.astype(np.float32).tobytes()


def _dequantize_stream(qb: bytes, sb: bytes, n: int,
                       q_off: int = 0, s_off: int = 0) -> np.ndarray:
    """Inverse of ``_quantize_stream`` reading at byte offsets into shared
    buffers (the batch frame concatenates every record's q/scale bytes)."""
    nb = max(1, (n + QBLOCK - 1) // QBLOCK)
    q = np.frombuffer(qb, np.int8, count=n, offset=q_off).astype(np.float32)
    scale = np.frombuffer(sb, np.float32, count=nb, offset=s_off)
    padded = np.pad(q, (0, nb * QBLOCK - n)).reshape(nb, QBLOCK)
    return (padded * scale[:, None]).reshape(-1)[:n]


def _quantize_stream_rows(mat: np.ndarray) -> tuple[bytes, bytes]:
    """Vectorized ``_quantize_stream`` over B same-length records (rows):
    one numpy pass instead of B, bitwise-identical bytes (blocks still
    restart at every record boundary).  This keeps the batched-frame
    encode cheaper than B single encodes on the broker hot path."""
    b, n = mat.shape
    nb = max(1, (n + QBLOCK - 1) // QBLOCK)
    padded = np.pad(mat, ((0, 0), (0, nb * QBLOCK - n))).reshape(b * nb,
                                                                 QBLOCK)
    scale = np.maximum(np.abs(padded).max(axis=1), 1e-20) * _INV127
    q = np.clip(np.round(padded / scale[:, None]), -127, 127).astype(np.int8)
    q = np.ascontiguousarray(q.reshape(b, nb * QBLOCK)[:, :n])
    return q.tobytes(), scale.astype(np.float32).tobytes()


def _dequantize_stream_rows(qb: bytes, sb: bytes, b: int, n: int) -> np.ndarray:
    """Vectorized ``_dequantize_stream`` for B same-length records; returns
    a (B, n) float32 array, bitwise-identical to the per-record path."""
    nb = max(1, (n + QBLOCK - 1) // QBLOCK)
    q = np.frombuffer(qb, np.int8, count=b * n).reshape(b, n).astype(
        np.float32)
    scale = np.frombuffer(sb, np.float32, count=b * nb)
    padded = np.pad(q, ((0, 0), (0, nb * QBLOCK - n))).reshape(b * nb, QBLOCK)
    return (padded * scale[:, None]).reshape(b, nb * QBLOCK)[:, :n]


# ---- device (Pallas) rows codec -------------------------------------------
# The uniform non-delta ``int8s`` path — the broker hot path — can run its
# quantization pass through kernels/quant.py instead of host numpy, so a
# device-resident producer never round-trips payloads through the host.
# Backend knob: "numpy" forces the host path, "pallas" forces the kernel
# (interpret mode off-TPU — what the parity tests pin), "auto" picks the
# kernel only on native accelerator backends.  The numpy path remains the
# reference oracle: both directions are **byte-identical** — same block
# layout, same scale formula (max|block|/127 with a 1e-20 floor), and both
# np.round and jnp.round round half to even.

_QUANT_BACKENDS = ("auto", "numpy", "pallas")
_quant_backend = "auto"


def set_quant_backend(mode: str) -> str:
    """Select the rows-codec backend; returns the previous setting."""
    global _quant_backend
    if mode not in _QUANT_BACKENDS:
        raise ValueError(f"quant backend must be one of {_QUANT_BACKENDS}")
    prev, _quant_backend = _quant_backend, mode
    return prev


def get_quant_backend() -> str:
    return _quant_backend


def _pallas_rows_active() -> bool:
    if _quant_backend == "numpy":
        return False
    if _quant_backend == "pallas":
        return True
    import jax     # lazy: records must import without touching jax
    return jax.default_backend() in ("tpu", "gpu")


def _quantize_stream_rows_pallas(mat: np.ndarray) -> tuple[bytes, bytes]:
    """``_quantize_stream_rows`` through the Pallas quant kernel.  Same
    (b·nb, QBLOCK) row layout, byte-identical output."""
    import jax.numpy as jnp
    from repro.kernels import ops
    b, n = mat.shape
    nb = max(1, (n + QBLOCK - 1) // QBLOCK)
    padded = np.pad(mat, ((0, 0), (0, nb * QBLOCK - n))).reshape(b * nb,
                                                                 QBLOCK)
    q, scale = ops.quantize(jnp.asarray(padded), block_rows=QBLOCK)
    q = np.asarray(q).reshape(b, nb * QBLOCK)[:, :n]
    return (np.ascontiguousarray(q).tobytes(),
            np.asarray(scale).astype(np.float32, copy=False).tobytes())


def _dequantize_stream_rows_pallas(qb: bytes, sb: bytes, b: int,
                                   n: int) -> np.ndarray:
    """``_dequantize_stream_rows`` through the Pallas dequant kernel."""
    import jax.numpy as jnp
    from repro.kernels import ops
    nb = max(1, (n + QBLOCK - 1) // QBLOCK)
    q = np.zeros((b, nb * QBLOCK), np.int8)
    q[:, :n] = np.frombuffer(qb, np.int8, count=b * n).reshape(b, n)
    scale = np.frombuffer(sb, np.float32, count=b * nb)
    x = ops.dequantize(jnp.asarray(q.reshape(b * nb, QBLOCK)),
                       jnp.asarray(scale), block_rows=QBLOCK)
    return np.asarray(x).reshape(b, nb * QBLOCK)[:, :n]


def _quant_rows(mat: np.ndarray) -> tuple[bytes, bytes]:
    if _pallas_rows_active():
        return _quantize_stream_rows_pallas(mat)
    return _quantize_stream_rows(mat)


def _dequant_rows(qb: bytes, sb: bytes, b: int, n: int) -> np.ndarray:
    if _pallas_rows_active():
        return _dequantize_stream_rows_pallas(qb, sb, b, n)
    return _dequantize_stream_rows(qb, sb, b, n)


def encode(rec: StreamRecord, *, compress: str = "zstd") -> bytes:
    """compress: none | zstd | int8 | int8+zstd."""
    arr = np.asarray(rec.payload)
    if compress.startswith("int8"):
        payload: Any = quantize_int8(arr)
        enc = "int8"
    else:
        payload = {"raw": arr.astype(np.float32).tobytes(),
                   "shape": list(arr.shape)}
        enc = "raw"
    msg = {
        "f": rec.field_name, "g": rec.group_id, "r": rec.rank,
        "s": rec.step, "t": rec.t_generated, "e": enc, "p": payload,
    }
    if rec.tenant != "default":
        # the tenant column only appears on tagged traffic, so default-tenant
        # frames stay byte-identical with pre-tenancy peers
        msg["u"] = rec.tenant
    blob = msgpack.packb(msg, use_bin_type=True)
    if compress.endswith("zstd") and zstd is not None:
        return b"Z" + _ZSTD_C.compress(blob)
    return b"M" + blob


def decode(data: bytes) -> StreamRecord:
    tag, blob = data[:1], data[1:]
    if tag == b"Z":
        blob = _ZSTD_D.decompress(blob)
    msg = msgpack.unpackb(blob, raw=False)
    if msg["e"] == "int8":
        payload = dequantize_int8(msg["p"])
    else:
        payload = np.frombuffer(msg["p"]["raw"], np.float32).reshape(
            msg["p"]["shape"])
    return StreamRecord(field_name=msg["f"], group_id=msg["g"], rank=msg["r"],
                        step=msg["s"], payload=payload, t_generated=msg["t"],
                        tenant=msg.get("u", "default"))


# ---------------------------------------------------------------------------
# Batched wire codec — one frame / one zstd pass / one quant pass per N recs
# ---------------------------------------------------------------------------

def _pack_col(vals: list):
    """Shared-schema header: collapse a uniform identity column to a scalar."""
    return vals[0] if all(v == vals[0] for v in vals) else list(vals)


def _unpack_col(v, n: int) -> list:
    return list(v) if isinstance(v, list) else [v] * n


def encode_batch(recs: list[StreamRecord], *, compress: str = "zstd",
                 delta: bool = False) -> bytes:
    """Encode N records into one aggregated wire frame.

    compress: none | zstd | int8 | int8+zstd (same modes as ``encode``).
    delta: store payload[i] - payload[i-1] when record i-1 is from the same
    stream with the same shape (flagged per record in the ``d`` column).
    With int8, deltas are closed-loop (taken against the dequantized
    reconstruction) so chain error never accumulates; with raw floats,
    reconstruction is float-exact only to roundoff ((b-a)+a can differ from
    b in the last ulp) — disable delta where bitwise fidelity matters.
    """
    if not recs:
        raise ValueError("encode_batch needs at least one record")
    flags: list[int] = []
    if compress.startswith("int8"):
        # per-stream scales + closed-loop deltas (enc tag "int8s")
        flats = [np.asarray(r.payload, np.float32).reshape(-1) for r in recs]
        sizes = {f.size for f in flats}
        if not delta and len(sizes) == 1:
            # uniform non-delta batch (the broker hot path): one vectorized
            # quantization pass over all records at once
            qb, sb = _quant_rows(np.stack(flats))
            flags = [0] * len(recs)
            payload: Any = {"q": qb, "scale": sb}
        else:
            qs, scales = [], []
            prev_key = prev_shape = None
            prev_recon = None
            for rec, flat in zip(recs, flats):
                shape = np.asarray(rec.payload).shape
                chained = (delta and prev_recon is not None
                           and rec.key() == prev_key and shape == prev_shape)
                src = flat - prev_recon if chained else flat
                flags.append(1 if chained else 0)
                qb, sb = _quantize_stream(src)
                qs.append(qb)
                scales.append(sb)
                recon = _dequantize_stream(qb, sb, flat.size)
                if chained:
                    recon = recon + prev_recon
                prev_key, prev_shape, prev_recon = rec.key(), shape, recon
            payload = {"q": b"".join(qs), "scale": b"".join(scales)}
        enc = "int8s"
    else:
        flats = []
        prev_key = prev_shape = None
        prev_flat = None
        for rec in recs:
            arr = np.asarray(rec.payload, np.float32)
            flat = arr.reshape(-1)
            if (delta and prev_flat is not None and rec.key() == prev_key
                    and arr.shape == prev_shape):
                flats.append(flat - prev_flat)
                flags.append(1)
            else:
                flats.append(flat)
                flags.append(0)
            prev_key, prev_shape, prev_flat = rec.key(), arr.shape, flat
        buf = np.concatenate(flats) if flats else np.zeros(0, np.float32)
        payload = {"raw": buf.tobytes()}
        enc = "raw"
    msg = {
        "n": len(recs),
        "f": _pack_col([r.field_name for r in recs]),
        "g": _pack_col([r.group_id for r in recs]),
        "r": _pack_col([r.rank for r in recs]),
        "s": [r.step for r in recs],
        "t": [r.t_generated for r in recs],
        "e": enc,
        "d": flags if any(flags) else 0,
        "sh": [list(np.asarray(r.payload).shape) for r in recs],
        "p": payload,
    }
    if any(r.tenant != "default" for r in recs):
        # uniform-collapsed like the other identity columns; absent entirely
        # for default-only batches (frame bytes unchanged vs. pre-tenancy)
        msg["u"] = _pack_col([r.tenant for r in recs])
    blob = msgpack.packb(msg, use_bin_type=True)
    if compress.endswith("zstd") and zstd is not None:
        return b"C" + _ZSTD_C.compress(blob)
    return b"B" + blob


def decode_batch(data: bytes) -> list[StreamRecord]:
    tag, blob = data[:1], data[1:]
    if tag == b"C":
        blob = _ZSTD_D.decompress(blob)
    msg = msgpack.unpackb(blob, raw=False)
    n = msg["n"]
    per_stream = msg["e"] == "int8s"
    if msg["e"] == "int8":          # legacy frames: shared concatenated blocks
        d = dict(msg["p"])
        d["shape"] = [d["n"]]   # flatten; per-record shapes applied below
        buf = dequantize_int8(d)
    elif not per_stream:
        buf = np.frombuffer(msg["p"]["raw"], np.float32)
    fields = _unpack_col(msg["f"], n)
    groups = _unpack_col(msg["g"], n)
    ranks = _unpack_col(msg["r"], n)
    tenants = _unpack_col(msg.get("u", "default"), n)
    flags = _unpack_col(msg["d"], n) if msg["d"] else [0] * n
    shapes = [tuple(s) for s in msg["sh"]]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    rows = None
    if per_stream and not any(flags) and len(set(sizes)) == 1:
        rows = _dequant_rows(msg["p"]["q"], msg["p"]["scale"], n, sizes[0])
    out: list[StreamRecord] = []
    off = q_off = s_off = 0
    prev_flat = None
    for i in range(n):
        shape, size = shapes[i], sizes[i]
        if rows is not None:
            flat = rows[i]
        elif per_stream:
            flat = _dequantize_stream(msg["p"]["q"], msg["p"]["scale"], size,
                                      q_off=q_off, s_off=s_off)
            q_off += size
            s_off += 4 * max(1, (size + QBLOCK - 1) // QBLOCK)
        else:
            flat = buf[off: off + size]
            off += size
        if flags[i]:
            flat = flat + prev_flat
        prev_flat = flat
        out.append(StreamRecord(field_name=fields[i], group_id=groups[i],
                                rank=ranks[i], step=msg["s"][i],
                                payload=flat.reshape(shape),
                                t_generated=msg["t"][i],
                                tenant=tenants[i]))
    return out


def decode_any(data: bytes) -> list[StreamRecord]:
    """Tag-dispatching decode: single-record or batch frame -> list."""
    if data[:1] == b"S":                    # seq-wrapped exactly-once frame
        data = unwrap_seq(data)[2]
    if data[:1] in (b"B", b"C"):
        return decode_batch(data)
    return [decode(data)]


# ---- exactly-once delivery framing (tag ``S``) -----------------------------
# ``S`` + base_seq(u64) + count(u32) + inner frame.  The WAL sequence range
# [base, base+count) travels in-band with the frame so the Transport protocol
# is untouched; endpoints unwrap it for receive-side dedupe (runtime.wal).
_SEQ_HDR = struct.Struct("!QI")


def wrap_seq(base_seq: int, count: int, blob: bytes) -> bytes:
    """Prefix a wire frame with its WAL seq range (exactly-once delivery)."""
    return b"S" + _SEQ_HDR.pack(base_seq, count) + blob


def unwrap_seq(data: bytes) -> tuple[int | None, int, bytes]:
    """Split a seq-wrapped frame into (base_seq, count, inner).  Frames
    without the ``S`` tag pass through as (None, 0, data)."""
    if data[:1] != b"S":
        return None, 0, data
    base, count = _SEQ_HDR.unpack_from(data, 1)
    return base, count, data[1 + _SEQ_HDR.size:]
