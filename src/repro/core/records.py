"""Stream records: the Cloud-native unit ElasticBroker ships.

A record carries one field snapshot from one producer rank at one step,
exactly like the paper's ``broker_write(ctx, step, data, len)`` payloads:
timestep + serialized field data + schema, msgpack-framed, optionally
zstd-compressed or int8 block-quantized (the TPU-side Pallas ``quant`` kernel
implements the same codec in-graph; this is the host-side mirror).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import msgpack
import numpy as np

try:
    import zstandard as zstd
    _ZSTD_C = zstd.ZstdCompressor(level=1)
    _ZSTD_D = zstd.ZstdDecompressor()
except Exception:  # pragma: no cover
    zstd = None

QBLOCK = 256


@dataclass(frozen=True)
class FieldSchema:
    """Registered at broker_init, mirrors the paper's field registration."""

    field_name: str              # e.g. "velocity_x", "resid_norm/layer"
    shape: tuple[int, ...]       # per-record payload shape
    dtype: str                   # numpy dtype name
    group_id: int                # producer group (paper: MPI process group)


@dataclass
class StreamRecord:
    field_name: str
    group_id: int
    rank: int                    # producer rank within the job
    step: int                    # simulation / training step
    payload: np.ndarray
    t_generated: float = field(default_factory=time.time)

    def key(self) -> str:
        return f"{self.field_name}/g{self.group_id}/r{self.rank}"


# ---------------------------------------------------------------------------
# Wire codec
# ---------------------------------------------------------------------------

def quantize_int8(x: np.ndarray) -> dict:
    """Blockwise int8: flat blocks of QBLOCK with one f32 scale each — the
    host mirror of kernels/quant.py."""
    flat = np.asarray(x, np.float32).reshape(-1)
    pad = (-flat.size) % QBLOCK
    padded = np.pad(flat, (0, pad))
    blocks = padded.reshape(-1, QBLOCK)
    scale = np.maximum(np.abs(blocks).max(axis=1), 1e-20) / 127.0
    q = np.clip(np.round(blocks / scale[:, None]), -127, 127).astype(np.int8)
    return {"q": q.tobytes(), "scale": scale.astype(np.float32).tobytes(),
            "n": int(flat.size), "shape": list(x.shape)}


def dequantize_int8(d: dict) -> np.ndarray:
    q = np.frombuffer(d["q"], np.int8).reshape(-1, QBLOCK).astype(np.float32)
    scale = np.frombuffer(d["scale"], np.float32)
    flat = (q * scale[:, None]).reshape(-1)[: d["n"]]
    return flat.reshape(d["shape"])


def encode(rec: StreamRecord, *, compress: str = "zstd") -> bytes:
    """compress: none | zstd | int8 | int8+zstd."""
    arr = np.asarray(rec.payload)
    if compress.startswith("int8"):
        payload: Any = quantize_int8(arr)
        enc = "int8"
    else:
        payload = {"raw": arr.astype(np.float32).tobytes(),
                   "shape": list(arr.shape)}
        enc = "raw"
    msg = {
        "f": rec.field_name, "g": rec.group_id, "r": rec.rank,
        "s": rec.step, "t": rec.t_generated, "e": enc, "p": payload,
    }
    blob = msgpack.packb(msg, use_bin_type=True)
    if compress.endswith("zstd") and zstd is not None:
        return b"Z" + _ZSTD_C.compress(blob)
    return b"M" + blob


def decode(data: bytes) -> StreamRecord:
    tag, blob = data[:1], data[1:]
    if tag == b"Z":
        blob = _ZSTD_D.decompress(blob)
    msg = msgpack.unpackb(blob, raw=False)
    if msg["e"] == "int8":
        payload = dequantize_int8(msg["p"])
    else:
        payload = np.frombuffer(msg["p"]["raw"], np.float32).reshape(
            msg["p"]["shape"])
    return StreamRecord(field_name=msg["f"], group_id=msg["g"], rank=msg["r"],
                        step=msg["s"], payload=payload, t_generated=msg["t"])
