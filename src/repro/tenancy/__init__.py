"""Multi-tenant QoS plane: tenant specs, quota admission, loss ledger."""
from repro.tenancy.admission import TenantAdmission
from repro.tenancy.ledger import TENANT_COUNTERS, closure_errors, merge_counts, zero_counts
from repro.tenancy.spec import DEFAULT_TENANT, TenantRegistry, TenantSpec

__all__ = [
    "DEFAULT_TENANT",
    "TENANT_COUNTERS",
    "TenantAdmission",
    "TenantRegistry",
    "TenantSpec",
    "closure_errors",
    "merge_counts",
    "zero_counts",
]
