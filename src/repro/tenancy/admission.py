"""Per-tenant rate-quota admission: a clock-driven token bucket.

Sits at the broker's front door (``Broker.write`` / ``write_batch``),
*before* records enter the data plane, so quota rejections never consume
queue capacity.  Buckets refill continuously from the injected clock —
virtual or wall — which keeps quota decisions deterministic under the
scenario runner's VirtualClock.

Tenants without a declared ``rate_quota_rps`` are never throttled.
"""
from __future__ import annotations

import threading

from repro.tenancy.spec import TenantRegistry


class TenantAdmission:
    """Token buckets keyed by tenant, capacity = ``burst_s`` seconds of quota."""

    def __init__(self, registry: TenantRegistry, clock, *, burst_s: float = 1.0):
        self.registry = registry
        self.clock = clock
        self._lock = threading.Lock()
        # name -> [tokens, last_refill_t, rate, capacity]
        self._buckets: dict[str, list[float]] = {}
        for spec in registry:
            if spec.rate_quota_rps is not None:
                cap = max(1.0, spec.rate_quota_rps * burst_s)
                self._buckets[spec.name] = [cap, None, spec.rate_quota_rps, cap]

    def take(self, tenant: str, n: int) -> int:
        """Grant up to ``n`` admission tokens for ``tenant``; returns the
        granted count (``n`` when the tenant has no quota)."""
        bucket = self._buckets.get(tenant)
        if bucket is None or n <= 0:
            return max(n, 0)
        now = self.clock.now()
        with self._lock:
            tokens, last, rate, cap = bucket
            if last is not None and now > last:
                tokens = min(cap, tokens + (now - last) * rate)
            granted = min(n, int(tokens))
            bucket[0] = tokens - granted
            bucket[1] = now
            return granted
