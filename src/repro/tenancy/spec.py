"""Tenant declarations for the multi-tenant QoS plane.

A *tenant* is a class of traffic with its own service contract: the
combustion run's alert stream is not the same workload as a best-effort
archival tap, even when both ride the same broker.  Each tenant declares

  * a **priority class** (higher = more important; admission/eviction in
    the broker never sheds a tenant to benefit a lower-priority one),
  * an optional **p99 latency target** — tenants with a target are the
    *protected* set: when a shard's backlog crosses its high-water mark,
    traffic from strictly lower-priority tenants is parked first,
  * an optional **rate quota** (records/s token bucket at the broker
    front door; rejections are counted per tenant, never silent),
  * a **weight** used by the debt-weighted scale policy and by cost
    attribution.

The registry is immutable after construction and always contains the
``default`` tenant so untagged traffic keeps working unchanged.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass

DEFAULT_TENANT = "default"


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's declared service contract."""

    name: str
    priority: int = 0
    p99_target_s: float | None = None
    rate_quota_rps: float | None = None
    weight: float = 1.0

    def validate(self) -> None:
        if not self.name or not isinstance(self.name, str):
            raise ValueError("tenant name must be a non-empty string")
        if not isinstance(self.priority, int) or self.priority < 0:
            raise ValueError(f"tenant {self.name!r}: priority must be an int >= 0")
        if self.p99_target_s is not None and self.p99_target_s <= 0:
            raise ValueError(f"tenant {self.name!r}: p99_target_s must be > 0")
        if self.rate_quota_rps is not None and self.rate_quota_rps <= 0:
            raise ValueError(f"tenant {self.name!r}: rate_quota_rps must be > 0")
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")

    def to_dict(self) -> dict:
        return asdict(self)


class TenantRegistry:
    """Immutable name -> TenantSpec lookup with QoS-plane derived facts.

    ``protected_priority`` is the highest priority class among tenants
    that declared a p99 target; tenants strictly below it are *parkable*
    (their records are held out of the shared queues under pressure).
    ``None`` when no tenant declared a target — then the QoS plane never
    parks anything.
    """

    def __init__(self, specs=()):
        by_name: dict[str, TenantSpec] = {}
        for spec in specs:
            spec.validate()
            if spec.name in by_name:
                raise ValueError(f"duplicate tenant {spec.name!r}")
            by_name[spec.name] = spec
        if DEFAULT_TENANT not in by_name:
            by_name[DEFAULT_TENANT] = TenantSpec(DEFAULT_TENANT)
        self._specs = by_name
        targeted = [s.priority for s in by_name.values() if s.p99_target_s is not None]
        self.protected_priority: int | None = max(targeted) if targeted else None
        self.has_quota = any(s.rate_quota_rps is not None for s in by_name.values())

    def spec(self, name: str) -> TenantSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise KeyError(f"unknown tenant {name!r}") from None

    def priority(self, name: str) -> int:
        return self.spec(name).priority

    def parks(self, name: str) -> bool:
        """True when this tenant's records park under backlog pressure."""
        if self.protected_priority is None:
            return False
        return self.spec(name).priority < self.protected_priority

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._specs))

    def __contains__(self, name: str) -> bool:
        return name in self._specs

    def __iter__(self):
        return iter(sorted(self._specs.values(), key=lambda s: s.name))

    def __len__(self) -> int:
        return len(self._specs)
