"""Per-tenant loss-ledger arithmetic.

Every record a tenant offers to the broker lands in exactly one bucket:

  ``quota_rejected``  refused at the front door by the rate quota
  ``dropped``         refused at admission (queue full, no evictable
                      lower-priority victim / sample policy) — never
                      entered the data plane
  ``admitted``        entered the data plane (queue, park, or WAL)

and every *admitted* record is conserved:

  admitted == sent + evicted + backlog(queue + park)

``evicted`` covers post-admission shedding: priority eviction under
backpressure, park overflow, and abandoned send frames.  After a clean
``finalize()`` the backlog term is zero, so the ledger closes as
``admitted == sent + evicted`` — and with loss-free endpoints,
``sent == analyzed``, which is the invariant the atlas checks in every
scenario.
"""
from __future__ import annotations

TENANT_COUNTERS = (
    "admitted",
    "sent",
    "dropped",
    "evicted",
    "parked_total",
    "unparked",
    "quota_rejected",
)


def zero_counts() -> dict[str, int]:
    return {k: 0 for k in TENANT_COUNTERS}


def merge_counts(into: dict[str, dict[str, int]], frm: dict[str, dict[str, int]]) -> None:
    """Fold one tenant->counters map into another, additively."""
    for name, counts in frm.items():
        dst = into.setdefault(name, zero_counts())
        for k, v in counts.items():
            dst[k] = dst.get(k, 0) + v


def closure_errors(tenants: dict[str, dict[str, int]], *,
                   backlog: dict[str, int] | None = None) -> list[str]:
    """Check the per-tenant conservation law; returns human-readable
    violations (empty list == ledger closed)."""
    errs = []
    for name in sorted(tenants):
        c = tenants[name]
        left = c.get("admitted", 0)
        right = (c.get("sent", 0) + c.get("evicted", 0)
                 + (backlog or {}).get(name, 0))
        if left != right:
            errs.append(
                f"tenant {name!r}: admitted={left} != sent+evicted+backlog={right} ({c})")
    return errs
