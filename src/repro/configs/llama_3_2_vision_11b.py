"""Llama-3.2-Vision-11B — cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB per the assignment: ``input_specs()`` feeds
precomputed patch embeddings (batch, n_frontend_tokens, d_model) consumed by
the cross-attention slots.
"""
from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
    pattern=(
        LayerSpec(kind=ATTN_GLOBAL),
        LayerSpec(kind=ATTN_GLOBAL),
        LayerSpec(kind=ATTN_GLOBAL),
        LayerSpec(kind=ATTN_GLOBAL),
        LayerSpec(kind=ATTN_GLOBAL, cross_attn=True),
    ),
    frontend="vision",
    n_frontend_tokens=1024,
)
