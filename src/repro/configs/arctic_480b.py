"""Snowflake Arctic-480B — 128-expert top-2 MoE + dense residual per layer.
[hf:Snowflake/snowflake-arctic-base; hf]

128 experts / 16-way `model` axis = 8 experts per slice.  8-bit optimizer
states + 4 microbatches fit v5e HBM at 256-way sharding.
"""
from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base; hf",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    moe_d_ff=4864,
    vocab_size=32000,
    rope_theta=1e4,
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    pattern=(LayerSpec(kind=ATTN_GLOBAL, moe=True),),
    opt_8bit=True,
    # 4 microbatches x 5-layer remat blocks; 512-token routing groups shrink
    # the GShard dispatch einsums ~6.7x (E*C: 10240 -> 1536) at 50% capacity
    # headroom (§Perf it-5)
    microbatch_overrides={"train_4k": 4},
    remat_block=5,
    moe_group_size=512,
)
