"""Minitron-8B — dense, GQA(kv=8), pruned Nemotron. [arXiv:2407.14679; hf]"""
from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    source="arXiv:2407.14679; hf",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    rope_theta=1e4,
    pattern=(LayerSpec(kind=ATTN_GLOBAL),),
)
