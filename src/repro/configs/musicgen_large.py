"""MusicGen-large — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (batch, seq, d_model); the backbone + 2048-way
codebook head are real.  kv=32 == n_heads (MHA).
"""
from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284; hf",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=1e4,
    pattern=(LayerSpec(kind=ATTN_GLOBAL),),
    frontend="audio",
)
