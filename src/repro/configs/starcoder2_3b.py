"""StarCoder2-3B — dense, GQA(kv=2), RoPE. [arXiv:2402.19173; hf]

Modeled with global attention: its 4k sliding window equals the train seq len
(noted in DESIGN.md §10).
"""
from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173; hf",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    rope_theta=1e5,
    pattern=(LayerSpec(kind=ATTN_GLOBAL),),
)
