"""Llama-3-405B — dense, GQA(kv=8), 128k vocab. [arXiv:2407.21783; unverified]

8-bit optimizer states + 8 gradient-accumulation microbatches are required to
fit a v5e-16GB chip at 256-way sharding (see DESIGN.md §4 and EXPERIMENTS.md).
"""
from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    source="arXiv:2407.21783; unverified",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=5e5,
    pattern=(LayerSpec(kind=ATTN_GLOBAL),),
    opt_8bit=True,
    # 8 microbatches x 6-layer remat blocks: boundary stash 21 x 268 MB =
    # 5.6 GB/chip (§Perf it-3/it-5: mb=16 regressed — 2x FSDP regathers)
    microbatch_overrides={"train_4k": 8},
    remat_block=6,
)
