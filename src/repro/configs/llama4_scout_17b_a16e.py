"""Llama-4-Scout-17B-16E — MoE 16 experts top-1, GQA(kv=8).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Every layer is MoE (interleave step 1).  Experts shard 1-per-chip-slice over
the 16-way `model` axis (classic EP).
"""
from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    moe_d_ff=8192,
    vocab_size=202048,
    rope_theta=5e5,
    n_experts=16,
    experts_per_token=1,
    pattern=(LayerSpec(kind=ATTN_GLOBAL, moe=True),),
    microbatch_overrides={"train_4k": 2},
)
