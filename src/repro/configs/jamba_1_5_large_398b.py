"""Jamba-1.5-Large-398B — Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

Pattern period 8: one global-attention slot per 8 layers (1:7), MoE on
alternating slots.  Mamba slots use the Mamba2/SSD layer (DESIGN.md §10).
Sub-quadratic (mamba-dominated) => runs long_500k.
"""
from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL, MAMBA

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887; hf",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    moe_d_ff=24576,
    vocab_size=65536,
    rope_theta=1e4,
    n_experts=16,
    experts_per_token=2,
    ssm_state=128,
    mamba_headdim=128,
    pattern=(
        LayerSpec(kind=MAMBA),
        LayerSpec(kind=MAMBA, moe=True),
        LayerSpec(kind=MAMBA),
        LayerSpec(kind=ATTN_GLOBAL, moe=True),
        LayerSpec(kind=MAMBA),
        LayerSpec(kind=MAMBA, moe=True),
        LayerSpec(kind=MAMBA),
        LayerSpec(kind=MAMBA, moe=True),
    ),
    opt_8bit=True,
    supports_long_context=True,
    microbatch_overrides={"train_4k": 8},
)
