"""Architecture / run configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`.  The model
stack (``repro.models``) consumes only this dataclass, so adding an arch is one
file in ``repro/configs``.

Layer heterogeneity (local/global attention interleave, mamba/attn hybrids,
cross-attention VLM layers, MoE periodicity) is expressed as a repeating
``pattern`` of :class:`LayerSpec` slots.  The transformer stacks parameters per
slot across ``n_repeat`` repeats and runs ``lax.scan`` over repeats, keeping the
HLO (and CPU compile time) proportional to the pattern length, not ``n_layers``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------

ATTN_GLOBAL = "attn_global"
ATTN_LOCAL = "attn_local"
MAMBA = "mamba"


@dataclass(frozen=True)
class LayerSpec:
    """One slot in the repeating layer pattern."""

    kind: str = ATTN_GLOBAL          # attn_global | attn_local | mamba
    moe: bool = False                # MoE MLP instead of dense MLP
    cross_attn: bool = False         # extra cross-attention block (VLM)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    # gradient-accumulation microbatches for train cells (tuned per arch below)
    microbatches: int = 1


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ArchConfig:
    # identity ------------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"            # dense | moe | hybrid | ssm | audio | vlm
    source: str = ""                 # citation tag from the assignment

    # trunk ----------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0                # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # layer pattern ---------------------------------------------------------
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    local_window: int = 4096         # for attn_local slots

    # MoE -------------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # 0 -> d_ff
    moe_dense_residual: bool = False # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"         # einsum (GShard baseline) | scatter
    moe_group_size: int = 1024       # routing group (GShard G); C ~ k*gs*cf/E

    # Mamba2 ----------------------------------------------------------------
    ssm_state: int = 128
    mamba_expand: int = 2
    mamba_headdim: int = 64
    mamba_conv: int = 4
    mamba_chunk: int = 256           # SSD chunk length

    # frontends (stubs per the assignment) -----------------------------------
    frontend: str = "none"           # none | audio | vision
    n_frontend_tokens: int = 1024    # vision: #patch embeddings fed to cross-attn

    # q-heads are padded to a multiple of this (the `model` mesh axis size) so
    # attention stays tensor-parallel for head counts 16 doesn't divide
    # (56/40/24).  Pad rows of wo are masked to zero => exact outputs.
    head_pad_to: int = 16

    # numerics / training -----------------------------------------------------
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # checkpoint every k pattern-repeats (k>1 shrinks the scan boundary stash
    # k-fold for the same recompute — total recompute is one extra fwd pass
    # either way; see EXPERIMENTS.md §Perf it-3)
    remat_block: int = 1
    opt_8bit: bool = False           # 8-bit blockwise m/v (needed for >=398B archs)
    # per-shape microbatch override, e.g. {"train_4k": 8}
    microbatch_overrides: dict = field(default_factory=dict)
    # long_500k applicability (sub-quadratic attention only)
    supports_long_context: bool = False
    # broker tap configuration (the paper's technique, on by default)
    tap_fields: tuple[str, ...] = ("resid_norm", "snapshot")
    tap_snapshot_dim: int = 64       # per-region downsampled field vector length

    # ------------------------------------------------------------------ props
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 so the vocab dim always shards."""
        return _round_up(self.vocab_size, 128)

    @property
    def padded_heads(self) -> int:
        if not self.n_heads:
            return 0
        hp = _round_up(self.n_heads, self.head_pad_to)
        assert hp % max(self.n_kv_heads, 1) == 0, (hp, self.n_kv_heads)
        return hp

    @property
    def n_repeat(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}"
        )
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_heads(self) -> int:
        return self.d_inner // self.mamba_headdim

    @property
    def is_attention_free(self) -> bool:
        return all(s.kind == MAMBA for s in self.pattern)

    # ------------------------------------------------------------------ flops
    def param_count(self) -> int:
        """Total parameters (dense count; MoE counts all experts)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE counts top-k experts)."""
        return _param_count(self, active_only=True)

    def model_flops(self, shape: ShapeConfig) -> float:
        """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N active params."""
        n = self.active_param_count()
        if shape.kind == "train":
            tokens = shape.seq_len * shape.global_batch
            return 6.0 * n * tokens
        if shape.kind == "prefill":
            tokens = shape.seq_len * shape.global_batch
            return 2.0 * n * tokens
        # decode: one new token per batch element
        return 2.0 * n * shape.global_batch

    # ------------------------------------------------------------------ misc
    def shape_cells(self) -> list[ShapeConfig]:
        cells = []
        for s in SHAPES.values():
            if s.name == "long_500k" and not self.supports_long_context:
                continue
            mb = self.microbatch_overrides.get(s.name, s.microbatches)
            cells.append(replace(s, microbatches=mb))
        return cells

    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        pat = self.pattern[: min(len(self.pattern), 4)]
        # keep pattern shape but at most 2 repeats
        n_layers = len(pat) * min(2, max(1, self.n_layers // len(self.pattern)))
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            pattern=pat,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            moe_d_ff=128 if self.n_experts else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=32,
            mamba_headdim=32,
            mamba_chunk=32,
            local_window=64,
            n_frontend_tokens=16,
            dtype=jnp.float32,
            remat=False,
            opt_8bit=False,
            microbatch_overrides={},
        )


def _param_count(cfg: ArchConfig, *, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.n_repeat * sum(_slot_params(cfg, slot, active_only) for slot in cfg.pattern)
    total += cfg.padded_vocab * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.padded_vocab * d  # output head
    total += d  # final norm
    return total


def _slot_params(cfg: ArchConfig, slot: LayerSpec, active_only: bool) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    p = 0
    if slot.kind in (ATTN_GLOBAL, ATTN_LOCAL):
        p += d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d + d
    elif slot.kind == MAMBA:
        di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.mamba_heads
        p += d * (2 * di + 2 * ns + nh)
        p += cfg.mamba_conv * (di + 2 * ns)
        p += nh + nh + di
        p += di * d + d
    if slot.cross_attn:
        p += d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d + 2 * d
    if slot.moe and cfg.n_experts:
        eff = cfg.moe_d_ff or cfg.d_ff
        n_e = cfg.experts_per_token if active_only else cfg.n_experts
        p += n_e * (3 * d * eff) + d * cfg.n_experts + d
        if cfg.moe_dense_residual:
            p += 3 * d * cfg.d_ff
    elif cfg.d_ff:
        p += 3 * d * cfg.d_ff + d
    return p
