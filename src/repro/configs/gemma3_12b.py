"""Gemma-3-12B — dense, 5:1 local:global interleave, GQA(kv=8), 256k vocab.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ArchConfig, LayerSpec, ATTN_GLOBAL, ATTN_LOCAL

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    source="hf:google/gemma-3-1b-pt; unverified",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    rope_theta=1e6,
    local_window=1024,
    pattern=(
        LayerSpec(kind=ATTN_LOCAL),
        LayerSpec(kind=ATTN_LOCAL),
        LayerSpec(kind=ATTN_LOCAL),
        LayerSpec(kind=ATTN_LOCAL),
        LayerSpec(kind=ATTN_LOCAL),
        LayerSpec(kind=ATTN_GLOBAL),
    ),
    microbatch_overrides={"train_4k": 2},
)
