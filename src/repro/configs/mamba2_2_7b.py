"""Mamba2-2.7B — attention-free SSD (state-space duality). [arXiv:2405.21060;
unverified]

d_ff=0: pure Mamba2 blocks, no MLP.  Attention-free => runs long_500k.
d_inner = 2*2560 = 5120, 80 SSD heads of headdim 64, state 128.
"""
from repro.configs.base import ArchConfig, LayerSpec, MAMBA

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060; unverified",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    mamba_expand=2,
    mamba_headdim=64,
    pattern=(LayerSpec(kind=MAMBA),),
    supports_long_context=True,
)
