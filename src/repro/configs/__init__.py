"""Architecture registry: ``repro.configs.get("llama3-405b")``."""
from __future__ import annotations

from repro.configs.base import ArchConfig, LayerSpec, ShapeConfig, SHAPES

from repro.configs.starcoder2_3b import CONFIG as _starcoder2_3b
from repro.configs.minitron_8b import CONFIG as _minitron_8b
from repro.configs.llama3_405b import CONFIG as _llama3_405b
from repro.configs.gemma3_12b import CONFIG as _gemma3_12b
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4_scout
from repro.configs.arctic_480b import CONFIG as _arctic_480b
from repro.configs.musicgen_large import CONFIG as _musicgen_large
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba_15_large
from repro.configs.llama_3_2_vision_11b import CONFIG as _llama_32_vision
from repro.configs.mamba2_2_7b import CONFIG as _mamba2_27b

REGISTRY: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _starcoder2_3b,
        _minitron_8b,
        _llama3_405b,
        _gemma3_12b,
        _llama4_scout,
        _arctic_480b,
        _musicgen_large,
        _jamba_15_large,
        _llama_32_vision,
        _mamba2_27b,
    ]
}


def get(name: str) -> ArchConfig:
    if name in REGISTRY:
        return REGISTRY[name]
    if name.endswith("-reduced") and name[: -len("-reduced")] in REGISTRY:
        return REGISTRY[name[: -len("-reduced")]].reduced()
    raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")


def list_archs() -> list[str]:
    return sorted(REGISTRY)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = [
    "ArchConfig",
    "LayerSpec",
    "ShapeConfig",
    "SHAPES",
    "REGISTRY",
    "get",
    "get_shape",
    "list_archs",
]
