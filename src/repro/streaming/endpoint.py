"""Cloud endpoints — the Redis-server stand-ins of the paper's Fig 2.

Each endpoint accepts framed stream records pushed by producer groups and
holds them in per-stream buffers (stream = one producer rank's trajectory,
exactly like the paper's per-MPI-process Redis streams).  Includes a simple
inbound-bandwidth model (for the Fig-7 throughput study), health/failure
injection (for failover tests), and drain APIs for the micro-batcher.
"""
from __future__ import annotations

import threading
from collections import defaultdict, deque

from repro.core.records import StreamRecord, decode_any, unwrap_seq
from repro.runtime.clock import Clock, ensure_clock
from repro.runtime.wal import SeqLedger


class Endpoint:
    def __init__(self, name: str = "ep0", *, inbound_bw: float | None = None,
                 port: int = 6379, clock: Clock | None = None,
                 ledger: SeqLedger | None = None):
        self.name = name
        self.port = port
        self.inbound_bw = inbound_bw          # bytes/s, None = unmetered
        self.clock = ensure_clock(clock)
        self._streams: dict[str, deque] = defaultdict(deque)
        self._lock = threading.Lock()
        self._healthy = True
        # cloud lifecycle (repro.cloud): draining = unhealthy to *senders*
        # (nothing new is routed here) but still accepting in-flight frames
        # and still alive to the failure detector; retired = deliberately
        # powered off — skipped by heartbeat pumps entirely
        self._draining = False
        self._retired = False
        self.bytes_in = 0
        self.records_in = 0
        self.frames_in = 0            # wire frames (batched: frames < records)
        # exactly-once receive side: a SeqLedger (shared by the whole
        # endpoint fleet) dedupes replayed frames on their WAL seq range
        self.ledger = ledger
        self.frames_deduped = 0       # wholly-duplicate frames skipped
        self.records_deduped = 0      # leading duplicate records skipped
        # fault injection: silently discard the next N accepted frames (the
        # scenario runner's lossy-transport model); counters make the loss
        # auditable so chaos tests can assert "no loss beyond what was
        # injected + what the drop policy allows"
        self._drop_frames = 0
        self.frames_dropped = 0
        self.records_dropped = 0
        self._bw_debt = 0.0
        self._bw_t = self.clock.now()
        # rolling ingest window for the telemetry bus: (t, n_records) per
        # push, trimmed to the rate window on read
        self._ingest_win: deque = deque(maxlen=4096)

    # ---- producer side --------------------------------------------------
    def healthy(self) -> bool:
        return self._healthy and not self._draining

    def fail(self):
        self._healthy = False

    def recover(self):
        self._healthy = True

    # ---- cloud lifecycle (drain-before-poweroff) -------------------------
    @property
    def draining(self) -> bool:
        return self._draining

    @property
    def retired(self) -> bool:
        return self._retired

    def begin_drain(self) -> None:
        """Stop being a routing target while the buffered backlog empties.
        ``push`` still accepts frames already in flight — drain is not
        failure, so nothing is lost on a deliberate scale-in."""
        self._draining = True

    def end_drain(self) -> None:
        self._draining = False

    def retire(self) -> None:
        """Deliberate power-off: unhealthy AND excluded from heartbeats."""
        self._draining = False
        self._healthy = False
        self._retired = True

    def drop_next_frames(self, n: int) -> None:
        """Fault injection: the next ``n`` accepted frames vanish after the
        ack — the sender believes they were delivered (this is silent loss,
        unlike ``fail()`` which the broker's retry path observes)."""
        with self._lock:
            self._drop_frames += int(n)

    def push(self, group_id: int, blob: bytes) -> None:
        if not self._healthy:
            raise ConnectionError(f"endpoint {self.name} down")
        if self.inbound_bw:
            # token-bucket style pacing: model the shared inbound link
            now = self.clock.now()
            self._bw_debt = max(0.0, self._bw_debt - (now - self._bw_t) * self.inbound_bw)
            self._bw_t = now
            self._bw_debt += len(blob)
            lag = self._bw_debt / self.inbound_bw
            if lag > 1e-4:
                self.clock.sleep(min(lag, 0.05))
        base, count, payload = unwrap_seq(blob)   # exactly-once seq header
        recs = decode_any(payload)    # single-record or aggregated frame
        with self._lock:
            if self._drop_frames > 0:
                self._drop_frames -= 1
                self.frames_dropped += 1
                self.records_dropped += len(recs)
                if base is not None and self.ledger is not None:
                    # the drop is silent: the frame acks upstream, so its
                    # seqs are consumed — replay must NOT resurrect injected
                    # loss, or it would stop being auditable as loss
                    self.ledger.mark_consumed(group_id, base, len(recs))
                return
            if base is not None and self.ledger is not None:
                skip = self.ledger.admit(group_id, base, len(recs))
                if skip:
                    if skip == len(recs):
                        self.frames_deduped += 1
                    self.records_deduped += skip
                    recs = recs[skip:]
                if not recs:
                    return            # whole frame was a replay duplicate
            for rec in recs:
                self._streams[rec.key()].append(rec)
            self.bytes_in += len(blob)
            self.records_in += len(recs)
            self.frames_in += 1
            self._ingest_win.append((self.clock.now(), len(recs)))

    # ---- consumer side (micro-batcher) -----------------------------------
    def stream_keys(self) -> list[str]:
        with self._lock:
            return list(self._streams.keys())

    def drain(self, key: str, max_records: int | None = None) -> list[StreamRecord]:
        with self._lock:
            dq = self._streams.get(key)
            if not dq:
                return []
            n = len(dq) if max_records is None else min(len(dq), max_records)
            return [dq.popleft() for _ in range(n)]

    def pending(self) -> int:
        with self._lock:
            return sum(len(d) for d in self._streams.values())

    # ---- telemetry -------------------------------------------------------
    def ingest_rate(self, window_s: float = 2.0) -> float:
        """Records/s over the trailing window (telemetry-bus feed)."""
        now = self.clock.now()
        with self._lock:
            while self._ingest_win and now - self._ingest_win[0][0] > window_s:
                self._ingest_win.popleft()
            return sum(n for _, n in self._ingest_win) / max(window_s, 1e-9)

    def telemetry(self) -> dict:
        """One control-plane sample: ingest rate, pending backlog, totals."""
        return {"name": self.name, "healthy": self.healthy(),
                "draining": self._draining,
                "pending": self.pending(), "records_in": self.records_in,
                "bytes_in": self.bytes_in, "frames_in": self.frames_in,
                "frames_dropped": self.frames_dropped,
                "records_dropped": self.records_dropped,
                "frames_deduped": self.frames_deduped,
                "records_deduped": self.records_deduped,
                "ingest_rate_rps": self.ingest_rate()}

    # ---- exactly-once checkpointing --------------------------------------
    _AUDIT_FIELDS = ("bytes_in", "records_in", "frames_in", "frames_dropped",
                     "records_dropped", "frames_deduped", "records_deduped")

    def audit_snapshot(self) -> dict:
        """The delivery-audit counters a Session checkpoint carries, so a
        restored run's loss accounting stays closed across the crash."""
        with self._lock:
            return {f: getattr(self, f) for f in self._AUDIT_FIELDS}

    def restore_audit(self, state: dict) -> None:
        with self._lock:
            for f in self._AUDIT_FIELDS:
                setattr(self, f, int(state.get(f, 0)))


def make_endpoint(i: int, *, inbound_bw: float | None = None,
                  base_port: int = 6379, transport: str = "inprocess",
                  clock: Clock | None = None,
                  ledger: SeqLedger | None = None):
    """One CloudEndpoint at fleet slot ``i``.

    Split out of :func:`make_endpoints` so the cloud capacity plane
    (repro.cloud) can attach endpoints to a *live* Session one at a time;
    pass the fleet's shared ``ledger`` so exactly-once dedupe spans
    dynamically provisioned endpoints too."""
    from repro.core.transport import (CloudEndpoint, LoopbackTransport,
                                      VirtualLoopbackTransport)
    clock = ensure_clock(clock)
    if ledger is None:
        ledger = SeqLedger()
    h = Endpoint(name=f"ep{i}", inbound_bw=inbound_bw, port=base_port,
                 clock=clock, ledger=ledger)
    if transport == "inprocess":
        return CloudEndpoint(service_ip=f"10.0.0.{i+1}",
                             service_port=base_port, handle=h)
    elif transport == "loopback":
        if clock.virtual:
            t = VirtualLoopbackTransport(h, clock=clock)
        else:
            t = LoopbackTransport(h)
        return CloudEndpoint(service_ip="127.0.0.1",
                             service_port=t.port, handle=h, transport=t)
    raise ValueError(f"unknown transport {transport!r} "
                     "(expected 'inprocess' or 'loopback')")


def make_endpoints(n: int, *, inbound_bw: float | None = None,
                   base_port: int = 6379, transport: str = "inprocess",
                   clock: Clock | None = None,
                   ledger: SeqLedger | None = None) -> list:
    """The paper's `struct CloudEndpoint endpoints[NUM_GROUPS]`.

    ``transport="inprocess"`` binds each CloudEndpoint straight to its
    Endpoint handle; ``"loopback"`` routes frames through a real localhost
    TCP socket (same semantics, proves the Transport seam).  Under a
    virtual ``clock`` the loopback flavor swaps in
    ``VirtualLoopbackTransport`` — the same frame protocol executed
    synchronously on simulated time, so chaos/replay scenarios also cover
    the TCP framing path.

    All endpoints of one fleet share one ``SeqLedger`` (created here when
    not supplied): exactly-once dedupe must recognize a frame replayed onto
    a *different* endpoint after failover."""
    clock = ensure_clock(clock)
    if ledger is None:
        ledger = SeqLedger()
    return [make_endpoint(i, inbound_bw=inbound_bw, base_port=base_port,
                          transport=transport, clock=clock, ledger=ledger)
            for i in range(n)]
