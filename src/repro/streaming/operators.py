"""Typed stream operators — the Cloud analysis layer as a real dataflow API.

The paper's Cloud side is a distributed stream-processing service (§4: Flink
jobs over broker streams), but the first DAG implementation here was a bare
``(stream_key, value) -> value`` callback graph with no notion of windows,
keys, or per-stage ordering — and the engine serialized every stage of every
stream behind one ordering ticket.  This module replaces that with a typed
operator model in the spirit of openPMD/ADIOS2 streaming pipelines and
Wilkins-style declarative in-situ graphs:

* :class:`Map` / :class:`Filter`   — per-element transforms,
* :class:`KeyBy`                   — re-key the stream (fan records of many
                                     producer streams into logical keys),
* :class:`TumblingWindow` / :class:`SlidingWindow`
                                   — event-time windows over
                                     ``StreamRecord.t_generated``, holding
                                     keyed state with snapshot/restore hooks,
* :class:`Aggregate`               — reduce a fired window pane to a value,
* :class:`Sink`                    — collect results (session-clock stamped).

Every operator declares an **ordering contract** — ``ordered`` (exact
per-stream arrival order), ``unordered`` (no cross-batch order), or ``keyed``
(per-key state consistency; event-time bucketing makes results insensitive
to processing order) — and a **parallelism hint**.  :meth:`OperatorPipeline.
compile` lowers the graph to an :class:`ExecutionPlan` the
``StreamEngine`` honors: the maximal order-insensitive prefix (every stage
``unordered``/``keyed`` with no ``ordered`` ancestor) runs *before and
without* the stream's ordering ticket, so micro-batches of ONE stream are
analyzed concurrently by many executors; the ordered suffix (if any) keeps
today's exactly-sequenced guarantee.  ``lower_dag`` compiles a legacy
:class:`repro.streaming.dag.AnalysisDAG` onto the same plan machinery (all
stages ordered, batch granularity), which is how the old ``Pipeline`` API
keeps working unchanged.

Window state lives in the plan (shared across executors, striped per-key
locks), NOT in any executor thread — so elasticity-driven steals,
``replace_executor``, and rebalances never drop a pane.  ``snapshot()`` /
``restore()`` serialize that state for migration across engines or
sessions, and ``accounting()`` closes the loss ledger:
``records_in == records into fired panes + records in open panes +
late_dropped`` for tumbling windows (per-pane identities for sliding).
"""
from __future__ import annotations

import copy
import threading
from dataclasses import dataclass
from typing import Any, Callable

from repro.core.grouping import partition_of
from repro.runtime.clock import Clock, ensure_clock

ORDERED = "ordered"
UNORDERED = "unordered"
KEYED = "keyed"
_CONTRACTS = (ORDERED, UNORDERED, KEYED)


@dataclass(frozen=True)
class Element:
    """One item flowing through the graph: a key, a value, and its event
    time (``StreamRecord.t_generated`` at the source; pane end for windows)."""

    key: str
    value: Any
    t_event: float


@dataclass(frozen=True)
class WindowPane:
    """One fired window: ``[start, end)`` in event time, values in arrival
    order (sort by your own criterion in the downstream Aggregate if the
    reduction is order-sensitive)."""

    key: str
    start: float
    end: float
    values: tuple

    @property
    def n(self) -> int:
        return len(self.values)


class Operator:
    """One typed stage.  Subclasses implement :meth:`process`; stateful
    operators also implement ``flush``/``snapshot``/``restore``.

    ``ordering`` is the stage's contract (see module docstring);
    ``parallelism`` is a hint capping how many executors the engine spreads
    this stage's partitions over (``None`` = no cap).
    """

    stateful = False

    def __init__(self, name: str, *, ordering: str, parallelism: int | None = None):
        if not name:
            raise ValueError("operator name must be non-empty")
        if ordering not in _CONTRACTS:
            raise ValueError(f"ordering must be one of {_CONTRACTS}, "
                             f"got {ordering!r}")
        if parallelism is not None and parallelism < 1:
            raise ValueError(f"parallelism hint must be >= 1, got {parallelism}")
        self.name = name
        self.ordering = ordering
        self.parallelism = parallelism
        self._plan: "ExecutionPlan | None" = None

    # plan wiring (clock + event hook access)
    def open(self, plan: "ExecutionPlan") -> None:
        self._plan = plan

    @property
    def clock(self) -> Clock:
        return self._plan.clock if self._plan is not None else ensure_clock(None)

    def process(self, elem: Element) -> list[Element]:
        raise NotImplementedError

    def flush(self) -> list[Element]:
        """Emit whatever the operator is still holding (drain path)."""
        return []

    def snapshot(self):
        return None

    def restore(self, state) -> None:
        pass

    def __repr__(self):
        return (f"{type(self).__name__}({self.name!r}, "
                f"ordering={self.ordering!r})")


class Map(Operator):
    """``fn(key, value) -> value | None`` (None filters the element)."""

    def __init__(self, name: str, fn: Callable[[str, Any], Any], *,
                 ordering: str = ORDERED, parallelism: int | None = None):
        super().__init__(name, ordering=ordering, parallelism=parallelism)
        self.fn = fn

    def process(self, elem: Element) -> list[Element]:
        out = self.fn(elem.key, elem.value)
        if out is None:
            return []
        return [Element(elem.key, out, elem.t_event)]


class Filter(Operator):
    """Keep elements where ``predicate(key, value)`` is truthy.  Stateless,
    hence ``unordered`` by default."""

    def __init__(self, name: str, predicate: Callable[[str, Any], bool], *,
                 ordering: str = UNORDERED, parallelism: int | None = None):
        super().__init__(name, ordering=ordering, parallelism=parallelism)
        self.predicate = predicate

    def process(self, elem: Element) -> list[Element]:
        return [elem] if self.predicate(elem.key, elem.value) else []


class KeyBy(Operator):
    """Re-key the stream: ``key_fn(key, value) -> new_key``.  Downstream
    keyed state (windows) buckets by the new key, so many producer streams
    can pool into one logical key (e.g. all ranks of a field)."""

    def __init__(self, name: str, key_fn: Callable[[str, Any], str], *,
                 parallelism: int | None = None):
        super().__init__(name, ordering=KEYED, parallelism=parallelism)
        self.key_fn = key_fn

    def process(self, elem: Element) -> list[Element]:
        return [Element(str(self.key_fn(elem.key, elem.value)), elem.value,
                        elem.t_event)]


class Aggregate(Operator):
    """Reduce a fired :class:`WindowPane` (or any iterable value) with
    ``fn(key, values) -> value``."""

    def __init__(self, name: str, fn: Callable[[str, list], Any], *,
                 ordering: str = KEYED, parallelism: int | None = None):
        super().__init__(name, ordering=ordering, parallelism=parallelism)
        self.fn = fn

    def process(self, elem: Element) -> list[Element]:
        v = elem.value
        values = list(v.values) if isinstance(v, WindowPane) else list(v)
        out = self.fn(elem.key, values)
        if out is None:
            return []
        return [Element(elem.key, out, elem.t_event)]


class BatchAggregate(Operator):
    """An :class:`Aggregate` that consumes **co-emitted elements in one
    call**: ``fn(items) -> outputs`` where ``items`` is a list of
    ``(key, values)`` pairs and ``outputs`` the same-length list of results
    (None filters that slot).  When an upstream window fires panes for many
    keys at the same watermark advance, the plan hands all of them to
    :meth:`process_many` at once — which is what lets a batched solver
    (e.g. ``analysis.dmd.batched_window_dmd``) collapse k per-pane device
    dispatches into one vmapped call.  ``process`` (single element) simply
    delegates, so the operator composes anywhere an Aggregate does.

    ``batch_stats()`` reports how much coalescing actually happened:
    ``batches`` (calls), ``items`` (elements across calls), ``max_batch``.
    """

    def __init__(self, name: str, fn: Callable[[list], list], *,
                 ordering: str = KEYED, parallelism: int | None = None):
        super().__init__(name, ordering=ordering, parallelism=parallelism)
        self.fn = fn
        self._stats_lock = threading.Lock()
        self.batches = 0
        self.items = 0
        self.max_batch = 0

    def process(self, elem: Element) -> list[Element]:
        return self.process_many([elem])

    def process_many(self, elems: list[Element]) -> list[Element]:
        if not elems:
            return []
        items = []
        for e in elems:
            v = e.value
            values = list(v.values) if isinstance(v, WindowPane) else list(v)
            items.append((e.key, values))
        outs = self.fn(items)
        if len(outs) != len(elems):
            raise ValueError(
                f"BatchAggregate {self.name!r}: fn returned {len(outs)} "
                f"results for {len(elems)} items")
        with self._stats_lock:
            self.batches += 1
            self.items += len(elems)
            self.max_batch = max(self.max_batch, len(elems))
        return [Element(e.key, o, e.t_event)
                for e, o in zip(elems, outs) if o is not None]

    def batch_stats(self) -> dict:
        with self._stats_lock:
            return {"batches": self.batches, "items": self.items,
                    "max_batch": self.max_batch}


class Sink(Operator):
    """Terminal collection point: appends ``(key, value, t)`` with the
    session clock's now() — never wall time — and passes the element through
    (sinks may sit mid-chain, like legacy DAG stage sinks)."""

    def __init__(self, name: str, *, ordering: str = UNORDERED):
        super().__init__(name, ordering=ordering)
        self._results: list[tuple[str, Any, float]] = []
        self._lock = threading.Lock()

    def process(self, elem: Element) -> list[Element]:
        t = self.clock.now()
        with self._lock:
            self._results.append((elem.key, elem.value, t))
        if self._plan is not None:
            self._plan.emit_event("sink", op=self.name, key=elem.key)
        return [elem]

    def results(self) -> list[tuple[str, Any, float]]:
        with self._lock:
            return list(self._results)

    def latest(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        for key, value, _t in self.results():
            out[key] = value
        return out

    # Sinks are checkpointed with the plan (exactly-once recovery restores
    # collected results alongside window panes) even though ``stateful``
    # stays False — that flag feeds the ordering contract, and a sink does
    # not need keyed ordering.
    def snapshot(self) -> dict:
        with self._lock:
            return {"results": list(self._results)}

    def restore(self, state: dict) -> None:
        with self._lock:
            self._results = list(state["results"])


_COUNTER_NAMES = ("records_in", "late_dropped", "assigned", "assignments",
                  "panes_fired", "fired_inserts")


class _Window(Operator):
    """Shared machinery for event-time windows: per-key panes under
    **striped** per-key locks, an operator-level watermark, loss ledger,
    and snapshot/restore.

    The watermark does NOT follow raw processing order.  Under plan-aware
    parallel dispatch, micro-batches of one stream run concurrently on many
    executors, so batch N+1 can be *processed* before batch N; if its
    (later) event times advanced the watermark directly, batch N's records
    would read as late and drop nondeterministically.  Instead, insertion
    (:meth:`ingest`, commutative) is decoupled from firing
    (:meth:`advance_watermark`), and the ExecutionPlan only advances the
    watermark along the per-stream **in-order commit frontier** — batch N+1
    contributes only after batches 0..N have finished inserting.  Producer
    event times are monotone per stream, so a record can never be late with
    respect to its own stream's frontier; records pooled across *different*
    streams (KeyBy) can still race each other's frontiers, which is what
    ``allowed_lateness_s`` is for.

    Locking: keys hash (stable crc32) onto ``stripes`` locks, so parallel
    keyed dispatch of different keys no longer serializes on one operator
    mutex — only same-stripe keys contend.  ``advance_watermark`` publishes
    the new watermark under ``_wmlock`` *before* popping each stripe under
    its stripe lock; because every pop and every insert for a stripe is
    totally ordered by that stripe's lock, an ingest that runs after the
    pop observes the already-raised watermark and classifies its element
    against it — a popped pane can never be re-created ("reborn") behind
    the watermark, and no pane fires twice.  Lock order everywhere is
    ``_wmlock`` then stripes ascending (snapshot/flush/accounting take all
    of them; the hot paths take exactly one)."""

    stateful = True

    def __init__(self, name: str, *, allowed_lateness_s: float = 0.0,
                 parallelism: int | None = None, stripes: int = 16):
        super().__init__(name, ordering=KEYED, parallelism=parallelism)
        if allowed_lateness_s < 0:
            raise ValueError("allowed_lateness_s must be >= 0")
        if stripes < 1:
            raise ValueError("stripes must be >= 1")
        self.allowed_lateness_s = float(allowed_lateness_s)
        self.n_stripes = int(stripes)
        self._wmlock = threading.Lock()
        self._watermark = float("-inf")
        self._stripe_locks = [threading.Lock() for _ in range(self.n_stripes)]
        # stripe -> key -> {(start, end): [values]}
        self._stripe_panes: list[dict[str, dict[tuple[float, float], list]]] \
            = [{} for _ in range(self.n_stripes)]
        # loss ledger, sharded per stripe (see accounting()); the public
        # ``records_in`` etc. read as summing properties below
        self._counters = [dict.fromkeys(_COUNTER_NAMES, 0)
                          for _ in range(self.n_stripes)]

    def _stripe_of(self, key: str) -> int:
        """Stable key -> stripe hash (crc32, not PYTHONHASHSEED-dependent
        ``hash``) so stripe layout — and with it any contention pattern —
        is deterministic across runs.  Same hash family as the shuffle
        stage's routing (:func:`repro.core.grouping.partition_of`), so a
        key's window state and its shuffled records agree on ownership."""
        return partition_of(key, self.n_stripes)

    def _counter_sum(self, name: str) -> int:
        return sum(c[name] for c in self._counters)

    records_in = property(lambda self: self._counter_sum("records_in"))
    late_dropped = property(lambda self: self._counter_sum("late_dropped"))
    assigned = property(lambda self: self._counter_sum("assigned"))
    assignments = property(lambda self: self._counter_sum("assignments"))
    panes_fired = property(lambda self: self._counter_sum("panes_fired"))
    fired_inserts = property(lambda self: self._counter_sum("fired_inserts"))

    # subclass: event time -> [(start, end), ...] pane memberships
    def _assign(self, t: float) -> list[tuple[float, float]]:
        raise NotImplementedError

    def ingest(self, elem: Element) -> None:
        """Insert-only half: bucket the element into its live panes (order-
        insensitive, safe to call from any executor at any time).  Takes
        only the element's stripe lock."""
        si = self._stripe_of(elem.key)
        ctr = self._counters[si]
        with self._stripe_locks[si]:
            ctr["records_in"] += 1
            # a pane is live until the watermark passes end + lateness;
            # the stripe lock orders this read against the stripe's pops
            wm = self._watermark
            live = [(s, e) for s, e in self._assign(elem.t_event)
                    if e + self.allowed_lateness_s > wm]
            if not live:
                ctr["late_dropped"] += 1
                if self._plan is not None:
                    self._plan.emit_event("late_drop", op=self.name,
                                          key=elem.key, t_event=elem.t_event)
                return
            ctr["assigned"] += 1
            panes = self._stripe_panes[si].setdefault(elem.key, {})
            for span in live:
                panes.setdefault(span, []).append(elem.value)
                ctr["assignments"] += 1

    def _pop_fired(self, si: int, threshold: float | None,
                   fired: list) -> None:
        """Pop every pane of stripe ``si`` past ``threshold`` (None = all)
        into ``fired``.  Caller holds the stripe lock."""
        ctr = self._counters[si]
        stripe = self._stripe_panes[si]
        for key in list(stripe):
            panes = stripe[key]
            for span in sorted(panes):
                if threshold is None or span[1] + self.allowed_lateness_s \
                        <= threshold:
                    values = panes.pop(span)
                    ctr["panes_fired"] += 1
                    ctr["fired_inserts"] += len(values)
                    fired.append((key, span[0], span[1], tuple(values)))

    def advance_watermark(self, t: float) -> list[Element]:
        """Firing half: move the watermark forward (monotone) and pop every
        pane it passed, emitted in (key, span) sorted order for determinism.
        Called by the plan with in-order frontier times only."""
        with self._wmlock:
            if t <= self._watermark:
                return []
            # publish BEFORE popping: any ingest that loses a stripe-lock
            # race to a pop will already see the raised watermark
            self._watermark = t
        fired: list[tuple[str, float, float, tuple]] = []
        for si in range(self.n_stripes):
            with self._stripe_locks[si]:
                self._pop_fired(si, t, fired)
        fired.sort()
        return [self._emit(k, s, e, v) for k, s, e, v in fired]

    def process(self, elem: Element) -> list[Element]:
        """In-order context (ordered suffix under the ticket, inline plan
        calls, flush-fed elements): insert and advance directly."""
        self.ingest(elem)
        return self.advance_watermark(elem.t_event)

    def _emit(self, key: str, start: float, end: float, values: tuple) -> Element:
        if self._plan is not None:
            self._plan.emit_event("window_fire", op=self.name, key=key,
                                  start=start, end=end, n=len(values))
        return Element(key, WindowPane(key, start, end, values), end)

    def flush(self) -> list[Element]:
        """Fire every open pane (drain path) in (key, span) sorted order
        so flush emission is deterministic."""
        fired: list[tuple[str, float, float, tuple]] = []
        with self._wmlock:
            for si in range(self.n_stripes):
                with self._stripe_locks[si]:
                    self._pop_fired(si, None, fired)
        fired.sort()
        return [self._emit(k, s, e, v) for k, s, e, v in fired]

    def _merged_panes(self) -> dict:
        """key -> {span: values} across stripes (callers hold all locks)."""
        merged: dict[str, dict[tuple[float, float], list]] = {}
        for stripe in self._stripe_panes:
            for key, panes in stripe.items():
                if panes:
                    merged[key] = panes
        return merged

    # ---- keyed-state migration hooks ------------------------------------
    def snapshot(self) -> dict:
        """Deep-copied keyed state + ledger — enough to rebuild the operator
        mid-window on another engine/session (elasticity migration).  The
        format is stripe-agnostic (one merged panes dict), so snapshots
        move between operators with different stripe counts."""
        with self._wmlock:
            for lk in self._stripe_locks:
                lk.acquire()
            try:
                return copy.deepcopy({
                    "watermark": self._watermark,
                    "panes": self._merged_panes(),
                    "counters": {n: self._counter_sum(n)
                                 for n in _COUNTER_NAMES}})
            finally:
                for lk in reversed(self._stripe_locks):
                    lk.release()

    def restore(self, state: dict) -> None:
        with self._wmlock:
            for lk in self._stripe_locks:
                lk.acquire()
            try:
                snap = copy.deepcopy(state)
                self._watermark = snap["watermark"]
                self._stripe_panes = [{} for _ in range(self.n_stripes)]
                for key, panes in snap["panes"].items():
                    self._stripe_panes[self._stripe_of(key)][key] = panes
                # ledger totals land on stripe 0 (only sums are observable)
                self._counters = [dict.fromkeys(_COUNTER_NAMES, 0)
                                  for _ in range(self.n_stripes)]
                self._counters[0].update(snap["counters"])
            finally:
                for lk in reversed(self._stripe_locks):
                    lk.release()

    def accounting(self) -> dict:
        """The loss ledger.  ``closed`` is the record-conservation identity:
        every record that entered either joined >= 1 pane or was counted as
        a late drop, and every pane insertion is either fired or still open."""
        with self._wmlock:
            for lk in self._stripe_locks:
                lk.acquire()
            try:
                open_inserts = sum(
                    len(v) for stripe in self._stripe_panes
                    for panes in stripe.values() for v in panes.values())
                open_panes = sum(len(panes) for stripe in self._stripe_panes
                                 for panes in stripe.values())
                c = {n: self._counter_sum(n) for n in _COUNTER_NAMES}
            finally:
                for lk in reversed(self._stripe_locks):
                    lk.release()
        return {**c,
                "open_inserts": open_inserts,
                "open_panes": open_panes,
                "closed": (c["records_in"]
                           == c["assigned"] + c["late_dropped"]
                           and c["assignments"]
                           == c["fired_inserts"] + open_inserts)}


class TumblingWindow(_Window):
    """Fixed event-time buckets of ``size_s``: record at t falls in exactly
    ``[floor(t/size)*size, +size)``."""

    def __init__(self, name: str, size_s: float, **kw):
        if size_s <= 0:
            raise ValueError("size_s must be > 0")
        super().__init__(name, **kw)
        self.size_s = float(size_s)

    def _assign(self, t: float) -> list[tuple[float, float]]:
        b = int(t // self.size_s)
        return [(b * self.size_s, (b + 1) * self.size_s)]


class SlidingWindow(_Window):
    """Overlapping panes of ``size_s`` every ``slide_s``: record at t joins
    every pane ``[k*slide, k*slide + size)`` containing t."""

    def __init__(self, name: str, size_s: float, slide_s: float, **kw):
        if size_s <= 0 or slide_s <= 0:
            raise ValueError("size_s and slide_s must be > 0")
        if slide_s > size_s:
            raise ValueError("slide_s must be <= size_s (gaps would drop "
                             "records; use a TumblingWindow instead)")
        super().__init__(name, **kw)
        self.size_s = float(size_s)
        self.slide_s = float(slide_s)

    def _assign(self, t: float) -> list[tuple[float, float]]:
        k_max = int(t // self.slide_s)
        k_min = int((t - self.size_s) // self.slide_s) + 1
        return [(k * self.slide_s, k * self.slide_s + self.size_s)
                for k in range(k_min, k_max + 1)]


# ---------------------------------------------------------------------------
# The compiled plan
# ---------------------------------------------------------------------------

class _PreOut:
    """Result of the order-insensitive prefix: elements parked at the
    pre/post phase boundary, plus the partition's primary value."""

    __slots__ = ("boundary", "primary")

    def __init__(self, boundary: list, primary):
        self.boundary = boundary
        self.primary = primary


class ExecutionPlan:
    """An operator graph lowered for the ``StreamEngine``.

    The compiler splits stages into two phases:

    * **pre**  — the maximal prefix where every stage is order-insensitive
      (``unordered``/``keyed``) and has no ``ordered`` ancestor.  The engine
      runs this *without* the per-stream ordering ticket, so micro-batches
      of one stream proceed concurrently on many executors.
    * **post** — everything from the first ``ordered`` stage on, run under
      the ticket in exact per-stream dispatch order.

    ``contract`` summarizes the plan ("ordered" if any post stage exists,
    else "keyed" if any keyed/stateful stage, else "unordered");
    ``parallel_dispatch`` tells the engine to spread a stream's partitions
    over executors instead of sticky-assigning them; ``parallelism`` is the
    tightest pre-stage hint (None = no cap).

    ``granularity`` selects what a source element is: ``"record"`` explodes
    a micro-batch into one element per ``StreamRecord`` (event time =
    ``t_generated``); ``"batch"`` feeds the whole records list as one
    element (the legacy ``AnalysisDAG`` semantics used by ``lower_dag``).
    """

    def __init__(self, ops: dict[str, Operator], downstream: dict[str, list[str]],
                 source: str, *, clock: Clock | None = None,
                 granularity: str = "record"):
        if source not in ops:
            raise ValueError(f"unknown source {source!r}")
        if granularity not in ("record", "batch"):
            raise ValueError(f"granularity must be 'record' or 'batch', "
                             f"got {granularity!r}")
        for name, downs in downstream.items():
            if name not in ops:
                raise ValueError(f"unknown stage {name!r} in downstream map")
            for d in downs:
                if d not in ops:
                    raise ValueError(f"unknown downstream stage {d!r}")
        self.ops = dict(ops)
        self.down = {n: list(downstream.get(n, [])) for n in ops}
        self.source = source
        self.clock = ensure_clock(clock)
        self.granularity = granularity
        self.on_event: Callable | None = None   # (kind, **detail) trace hook
        self._topo = self._toposort()
        self._pre, self._post = self._split_phases()
        # in-order commit frontier (see _Window docstring): per source
        # stream, batches contribute their max event time to the watermark
        # only once every earlier-seq batch of that stream has finished
        # inserting; the operator watermark is the max over stream frontiers
        self._flock = threading.Lock()
        self._frontier: dict[str, dict] = {}
        self._committed_max = float("-inf")
        # keyed shuffle (set by the engine via enable_shuffle()): when
        # active, micro-batches are key partitions, not producer streams,
        # and source elements carry each record's own stream key
        self._shuffle_n: int | None = None
        for op in self.ops.values():
            op.open(self)

    # ---- compilation ----------------------------------------------------
    def _toposort(self) -> list[str]:
        state: dict[str, int] = {}
        order: list[str] = []

        def visit(n: str, path: frozenset):
            if state.get(n) == 2:
                return
            if n in path:
                raise ValueError(f"cycle through {n!r}")
            for d in self.down[n]:
                visit(d, path | {n})
            state[n] = 2
            order.append(n)

        visit(self.source, frozenset())
        unreachable = set(self.ops) - set(order)
        if unreachable:
            raise ValueError(
                f"stages unreachable from source {self.source!r}: "
                f"{sorted(unreachable)}")
        order.reverse()
        return order

    def _split_phases(self) -> tuple[list[str], list[str]]:
        parents: dict[str, list[str]] = {n: [] for n in self.ops}
        for n, downs in self.down.items():
            for d in downs:
                parents[d].append(n)
        pre: list[str] = []
        pre_set: set[str] = set()
        for n in self._topo:                     # parents precede children
            op = self.ops[n]
            if op.ordering != ORDERED and all(p in pre_set for p in parents[n]):
                pre.append(n)
                pre_set.add(n)
        post = [n for n in self._topo if n not in pre_set]
        return pre, post

    @property
    def pre_stages(self) -> list[str]:
        return list(self._pre)

    @property
    def post_stages(self) -> list[str]:
        return list(self._post)

    @property
    def contract(self) -> str:
        if self._post:
            return ORDERED
        if any(op.stateful or op.ordering == KEYED for op in self.ops.values()):
            return KEYED
        return UNORDERED

    @property
    def parallel_dispatch(self) -> bool:
        """True when the engine should spread one stream's partitions across
        executors (there is order-insensitive work to parallelize)."""
        return bool(self._pre)

    @property
    def parallelism(self) -> int | None:
        hints = [self.ops[n].parallelism for n in self._pre
                 if self.ops[n].parallelism is not None]
        return min(hints) if hints else None

    @property
    def shuffle_op(self) -> "KeyBy | None":
        """The shuffle edge this plan compiles to: a record-granularity
        graph whose SOURCE is a :class:`KeyBy` re-partitions records across
        streams — the engine may dispatch by the KeyBy's output key instead
        of by producer stream.  None when the plan has no shuffle edge."""
        op = self.ops[self.source]
        if self.granularity == "record" and isinstance(op, KeyBy):
            return op
        return None

    @property
    def shuffled(self) -> bool:
        return self._shuffle_n is not None

    @property
    def shuffle_partitions(self) -> int | None:
        return self._shuffle_n

    def enable_shuffle(self, n_partitions: int) -> None:
        """Switch the plan to keyed-shuffle dispatch over ``n_partitions``
        partitions.  Engine-called at attach time; requires a shuffle edge."""
        if self.shuffle_op is None:
            raise ValueError(
                "plan has no shuffle edge (source must be a KeyBy on a "
                "record-granularity graph)")
        if n_partitions < 1:
            raise ValueError(f"need >= 1 partitions, got {n_partitions}")
        self._shuffle_n = int(n_partitions)

    def shuffle_partition(self, record) -> int:
        """Partition owning ``record`` under the shuffle edge: the KeyBy's
        output key hashed with the shared stable :func:`partition_of` —
        crc32, same family as the window stripe hash, so co-keyed records
        from different producer streams always land together."""
        kb = self.shuffle_op
        key = str(kb.key_fn(record.key(), record))
        return partition_of(key, self._shuffle_n)

    def bind_clock(self, clock: Clock | None) -> None:
        """Adopt the Session's clock (operators read it through the plan, so
        a rebind covers every sink/window timestamp)."""
        self.clock = ensure_clock(clock)

    def emit_event(self, kind: str, **detail) -> None:
        cb = self.on_event
        if cb is not None:
            cb(kind, **detail)

    # ---- execution -------------------------------------------------------
    def _source_elements(self, key: str, records: list) -> list[Element]:
        if self.granularity == "batch":
            tmin = min((r.t_generated for r in records),
                       default=self.clock.now())
            return [Element(key, records, tmin)]
        if self._shuffle_n is not None:
            # shuffled micro-batches pool records of many producer streams
            # under one partition key; each element keeps its own record's
            # stream key so the source KeyBy re-keys exactly as it would
            # have under producer-partitioned dispatch
            return [Element(r.key(), r, r.t_generated) for r in records]
        return [Element(key, r, r.t_generated) for r in records]

    def _feed(self, name: str, elem: Element, allowed: set | None,
              boundary: list | None, defer_fire: bool = False) -> None:
        """DFS one element through the graph.  Stages outside ``allowed``
        park the element at the phase boundary instead of running.  With
        ``defer_fire``, windows only ingest — firing waits for the in-order
        frontier commit (:meth:`run_pre`)."""
        if allowed is not None and name not in allowed:
            boundary.append((name, elem))
            return
        op = self.ops[name]
        if defer_fire and isinstance(op, _Window):
            op.ingest(elem)
            return
        self._fan_out(name, op.process(elem), allowed, boundary, defer_fire)

    def _fan_out(self, name: str, outs: list, allowed: set | None,
                 boundary: list | None, defer_fire: bool = False) -> None:
        """Feed one stage's output elements downstream.  When a stage emits
        several elements at once (a window firing panes across keys) and a
        downstream stage is a :class:`BatchAggregate`, all of them go down
        in ONE ``process_many`` call — the multi-key coalescing hook.  For
        every other downstream, elements flow one at a time in emission
        order, exactly as the plain DFS did."""
        if not outs:
            return
        for d in self.down[name]:
            dop = self.ops[d]
            if (len(outs) > 1 and isinstance(dop, BatchAggregate)
                    and (allowed is None or d in allowed)):
                self._fan_out(d, dop.process_many(outs), allowed, boundary,
                              defer_fire)
            else:
                for out in outs:
                    self._feed(d, out, allowed, boundary, defer_fire)

    def _commit(self, stream: str, seq: int | None, batch_max: float) -> float:
        """Record one batch's max event time on its stream's frontier.
        ``seq=None`` (inline callers) commits immediately; otherwise the
        frontier only advances over the contiguous seq prefix, so an
        out-of-order-processed batch never pushes the watermark past a
        still-inserting earlier batch.  A seq below the frontier (dispatched
        before this plan was attached mid-run) folds in directly.  Returns
        the new global watermark."""
        with self._flock:
            st = self._frontier.setdefault(
                stream, {"next": 0, "pending": {},
                         "committed": float("-inf")})
            if seq is None or seq < st["next"]:
                st["committed"] = max(st["committed"], batch_max)
            else:
                st["pending"][seq] = batch_max
                while st["next"] in st["pending"]:
                    st["committed"] = max(st["committed"],
                                          st["pending"].pop(st["next"]))
                    st["next"] += 1
            if st["committed"] > self._committed_max:
                self._committed_max = st["committed"]
            return self._committed_max

    def seed_frontier(self, stream_next_seq: dict[str, int]) -> None:
        """Align the frontier with an engine whose per-stream seq counters
        are already past zero (a plan attached mid-run): the next expected
        seq per stream is the engine's, and anything older folds straight
        into the committed watermark (see :meth:`_commit`)."""
        with self._flock:
            for stream, nxt in stream_next_seq.items():
                self._frontier.setdefault(
                    stream, {"next": int(nxt), "pending": {},
                             "committed": float("-inf")})

    def run_pre(self, key: str, records: list,
                seq: int | None = None) -> _PreOut:
        """The order-insensitive prefix (call WITHOUT the ordering ticket).
        Window insertion happens inline; window *firing* happens here too,
        but only up to the in-order frontier watermark.  The seq is
        committed even when a stage raises — a poisoned batch must not
        stall its stream's watermark forever."""
        boundary: list = []
        allowed = set(self._pre)
        elems = self._source_elements(key, records)
        primary = self._primary(key, records)
        try:
            for elem in elems:
                if self.granularity == "batch" and self.source in allowed:
                    primary = self._run_batch_source(
                        elem, allowed, boundary, defer_fire=True)
                else:
                    self._feed(self.source, elem, allowed, boundary,
                               defer_fire=True)
        finally:
            w = self._commit(
                key, seq,
                max((e.t_event for e in elems), default=float("-inf")))
        for name in self._pre:
            op = self.ops[name]
            if isinstance(op, _Window):
                self._fan_out(name, op.advance_watermark(w), allowed,
                              boundary, defer_fire=True)
        return _PreOut(boundary, primary)

    def run_post(self, key: str, pre_out: _PreOut | None, records: list):
        """The ordered suffix (call UNDER the ordering ticket).  With
        ``pre_out=None`` (no prefix ran) the whole graph runs here."""
        if pre_out is None:
            boundary = [(self.source, e)
                        for e in self._source_elements(key, records)]
        else:
            boundary = pre_out.boundary
        primary = self._primary(key, records)
        for name, elem in boundary:
            if self.granularity == "batch" and name == self.source:
                primary = self._run_batch_source(elem, None, None)
            else:
                self._feed(name, elem, None, None)
        return primary

    def _run_batch_source(self, elem: Element, allowed: set | None,
                          boundary: list | None, defer_fire: bool = False):
        """Batch-granularity source, capturing its output as the primary
        value (legacy ``AnalysisDAG.__call__`` returned exactly this) —
        in whichever phase the source landed."""
        op = self.ops[self.source]
        if defer_fire and isinstance(op, _Window):
            op.ingest(elem)
            return None              # a deferred window has no output yet
        outs = op.process(elem)
        for out in outs:
            for d in self.down[self.source]:
                self._feed(d, out, allowed, boundary, defer_fire)
        return outs[0].value if outs else None

    def _primary(self, key: str, records: list):
        """The engine ``Result.value`` for this partition: record count for
        record-granularity plans (the batch-source output overrides it in
        :meth:`run_post` for legacy plans)."""
        return len(records)

    def __call__(self, key: str, records: list):
        """Whole graph inline (both phases) — usable directly as an
        ``analyze_fn`` or for single-threaded tests."""
        if self._pre:
            pre_out = self.run_pre(key, records)
            if not self._post:
                return pre_out.primary
            return self.run_post(key, pre_out, records)
        return self.run_post(key, None, records)

    def flush(self) -> None:
        """Drain path (single-threaded, after executors stop): fire every
        open window pane through the rest of the graph, topo order.  Like
        the watermark path, co-fired panes coalesce into a downstream
        :class:`BatchAggregate`."""
        for name in self._topo:
            self._fan_out(name, self.ops[name].flush(), None, None)

    # ---- observability / state migration --------------------------------
    def sinks(self) -> list[str]:
        return [n for n, op in self.ops.items() if isinstance(op, Sink)]

    def results(self, name: str) -> list[tuple[str, Any, float]]:
        op = self.ops.get(name)
        if not isinstance(op, Sink):
            raise ValueError(f"{name!r} is not a Sink (sinks: {self.sinks()})")
        return op.results()

    def latest(self, name: str) -> dict[str, Any]:
        op = self.ops.get(name)
        if not isinstance(op, Sink):
            raise ValueError(f"{name!r} is not a Sink (sinks: {self.sinks()})")
        return op.latest()

    def snapshot(self) -> dict:
        """Keyed state of every stateful operator (windows), deep-copied,
        plus every sink's collected results (so an exactly-once restore
        resumes with pre-crash outputs intact)."""
        return {n: op.snapshot() for n, op in self.ops.items()
                if op.stateful or isinstance(op, Sink)}

    def restore(self, state: dict) -> None:
        for n, s in state.items():
            if n not in self.ops:
                raise ValueError(f"snapshot has unknown operator {n!r}")
            self.ops[n].restore(s)

    def frontier_snapshot(self) -> dict:
        """The per-stream in-order commit frontier (see :meth:`_commit`) —
        captured by ``Session.checkpoint()`` so a restored run resumes
        firing windows from the same watermark instead of re-waiting for
        each stream's seq 0."""
        with self._flock:
            return {"streams": {k: {"next": st["next"],
                                    "pending": dict(st["pending"]),
                                    "committed": st["committed"]}
                                for k, st in self._frontier.items()},
                    "committed_max": self._committed_max}

    def restore_frontier(self, state: dict) -> None:
        with self._flock:
            self._frontier = {k: {"next": int(st["next"]),
                                  "pending": dict(st["pending"]),
                                  "committed": st["committed"]}
                              for k, st in state["streams"].items()}
            self._committed_max = state["committed_max"]

    def accounting(self) -> dict:
        """Per-window loss ledgers plus the global ``closed`` flag."""
        per_op = {n: op.accounting() for n, op in self.ops.items()
                  if isinstance(op, _Window)}
        return {"windows": per_op,
                "closed": all(a["closed"] for a in per_op.values())}

    def batch_stats(self) -> dict:
        """Coalescing scoreboard: per-BatchAggregate call/item/max-batch
        counts (how many device dispatches the multi-key fast path saved)."""
        return {n: op.batch_stats() for n, op in self.ops.items()
                if isinstance(op, BatchAggregate)}

    def __repr__(self):
        return (f"ExecutionPlan(contract={self.contract!r}, "
                f"pre={self._pre}, post={self._post}, "
                f"granularity={self.granularity!r})")


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------

class OperatorPipeline:
    """Fluent builder for operator graphs (the successor of the legacy
    ``workflow.Pipeline`` stage/then/branch verbs):

        pipe = (OperatorPipeline()
                .key_by("by_field", lambda k, r: k.split("/")[0])
                .tumbling_window("win", size_s=1.0)
                .aggregate("dmd", window_dmd)
                .map("alert", alert_fn, ordering="ordered")
                .sink("alerts"))

    Each verb appends downstream of the cursor and advances it; ``after=``
    attaches anywhere (fan-out), ``at()`` repositions the cursor.  The graph
    is acyclic by construction; ``compile()`` validates and returns the
    :class:`ExecutionPlan`.

    ``granularity="record"`` (default) feeds the source one element per
    ``StreamRecord``; ``"batch"`` feeds the whole micro-batch records list
    as one element — for stages that are inherently per-batch (e.g. a
    stateful StreamingDMD update).

    Note the compiled plan owns the *live* operator instances: compiling
    the same builder twice yields plans SHARING sink/window state.  Build a
    fresh pipeline per Session (scenario factories do exactly this).
    """

    def __init__(self, granularity: str = "record"):
        if granularity not in ("record", "batch"):
            raise ValueError(f"granularity must be 'record' or 'batch', "
                             f"got {granularity!r}")
        self.granularity = granularity
        self._ops: dict[str, Operator] = {}
        self._down: dict[str, list[str]] = {}
        self._source: str | None = None
        self._cursor: str | None = None

    def add(self, op: Operator, *, after: str | None = None) -> "OperatorPipeline":
        """Attach ``op`` downstream of ``after`` (default: the cursor) and
        move the cursor to it.  The first operator becomes the source."""
        if op.name in self._ops:
            raise ValueError(f"duplicate operator {op.name!r}")
        if self._source is None:
            if after is not None:
                raise ValueError("the first operator is the source; it has "
                                 "no upstream to attach after")
        else:
            parent = self._cursor if after is None else after
            if parent not in self._ops:
                raise ValueError(f"unknown operator {parent!r}")
            self._down[parent].append(op.name)
        self._ops[op.name] = op
        self._down[op.name] = []
        if self._source is None:
            self._source = op.name
        self._cursor = op.name
        return self

    def at(self, name: str) -> "OperatorPipeline":
        """Move the cursor to an existing operator (fan-out topologies)."""
        if name not in self._ops:
            raise ValueError(f"unknown operator {name!r}")
        self._cursor = name
        return self

    # ---- typed conveniences ---------------------------------------------
    def map(self, name: str, fn, *, ordering: str = ORDERED,
            parallelism: int | None = None, after: str | None = None):
        return self.add(Map(name, fn, ordering=ordering,
                            parallelism=parallelism), after=after)

    def filter(self, name: str, predicate, *, ordering: str = UNORDERED,
               parallelism: int | None = None, after: str | None = None):
        return self.add(Filter(name, predicate, ordering=ordering,
                               parallelism=parallelism), after=after)

    def key_by(self, name: str, key_fn, *, after: str | None = None):
        return self.add(KeyBy(name, key_fn), after=after)

    def tumbling_window(self, name: str, size_s: float, *,
                        allowed_lateness_s: float = 0.0, stripes: int = 16,
                        after: str | None = None):
        return self.add(TumblingWindow(name, size_s,
                                       allowed_lateness_s=allowed_lateness_s,
                                       stripes=stripes),
                        after=after)

    def sliding_window(self, name: str, size_s: float, slide_s: float, *,
                       allowed_lateness_s: float = 0.0, stripes: int = 16,
                       after: str | None = None):
        return self.add(SlidingWindow(name, size_s, slide_s,
                                      allowed_lateness_s=allowed_lateness_s,
                                      stripes=stripes),
                        after=after)

    def aggregate(self, name: str, fn, *, ordering: str = KEYED,
                  after: str | None = None):
        return self.add(Aggregate(name, fn, ordering=ordering), after=after)

    def batch_aggregate(self, name: str, fn, *, ordering: str = KEYED,
                        after: str | None = None):
        return self.add(BatchAggregate(name, fn, ordering=ordering),
                        after=after)

    def sink(self, name: str, *, ordering: str = UNORDERED,
             after: str | None = None):
        return self.add(Sink(name, ordering=ordering), after=after)

    # ---- introspection / compilation ------------------------------------
    def edges(self) -> list[tuple[str, str]]:
        return [(p, c) for p, downs in self._down.items() for c in downs]

    def compile(self, clock: Clock | None = None,
                granularity: str | None = None) -> ExecutionPlan:
        if self._source is None:
            raise ValueError("empty pipeline: add at least one operator")
        return ExecutionPlan(self._ops, self._down, self._source, clock=clock,
                             granularity=granularity or self.granularity)


# ---------------------------------------------------------------------------
# Legacy lowering
# ---------------------------------------------------------------------------

class _DagStageOp(Operator):
    """One legacy ``AnalysisDAG`` stage as an (ordered, batch-granularity)
    operator: run the callback, record non-None output in the DAG's own sink
    (so ``dag.results()`` keeps working), fan out."""

    def __init__(self, name: str, fn, dag):
        super().__init__(name, ordering=ORDERED)
        self.fn = fn
        self.dag = dag

    def process(self, elem: Element) -> list[Element]:
        out = self.fn(elem.key, elem.value)
        if out is None:
            return []
        self.dag.record(self.name, elem.key, out)
        return [Element(elem.key, out, elem.t_event)]


def lower_dag(dag, clock: Clock | None = None) -> ExecutionPlan:
    """Compile a legacy :class:`repro.streaming.dag.AnalysisDAG` onto the
    operator machinery: every stage ordered, whole-micro-batch elements,
    sink values landing in the DAG's own per-stage sinks — byte-identical
    stage results, same sticky per-stream scheduling."""
    ops = {name: _DagStageOp(name, stage.fn, dag)
           for name, stage in dag.stages.items()}
    down = {name: list(stage.downstream) for name, stage in dag.stages.items()}
    return ExecutionPlan(ops, down, dag.source, clock=clock,
                         granularity="batch")
