"""In-situ analysis DAGs — the paper's §6 future work, implemented.

"In our future work, we plan to extend ElasticBroker to support in-situ
workflows with more complex directed acyclic graphs (DAG)."

A :class:`Stage` transforms one stream's value; edges fan results out to
downstream stages; terminal results are collected per stage.  The DAG
executes inside the stream engine's executors (one partition = one stream's
micro-batch traversing the whole graph), so work stealing / elasticity /
failure handling apply unchanged.

Example (tests/test_dag.py):

    records ──► dmd ──► stability ──► alert     (threshold -> alarm sink)
                   └──► trend                   (windowed slope sink)
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.runtime.clock import Clock, ensure_clock


@dataclass
class Stage:
    name: str
    fn: Callable[[str, Any], Any]        # (stream_key, value) -> value|None
    downstream: list[str] = field(default_factory=list)


class AnalysisDAG:
    def __init__(self, stages: list[Stage], source: str, *,
                 clock: Clock | None = None):
        names = [s.name for s in stages]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate stage names {dupes}")
        self.stages = {s.name: s for s in stages}
        assert source in self.stages, f"unknown source {source}"
        self.source = source
        self._validate_acyclic()
        self.sinks: dict[str, list[tuple[str, Any, float]]] = {
            s.name: [] for s in stages}
        self._lock = threading.Lock()
        # sink timestamps come from here, NOT time.time(): under a Session's
        # VirtualClock a wall-time read would stamp ~1.7e9 s into traces
        self._clock = ensure_clock(clock)

    def bind_clock(self, clock: Clock | None) -> None:
        """Adopt the owning Session's clock (attach_pipeline does this)."""
        self._clock = ensure_clock(clock)

    def _validate_acyclic(self):
        state: dict[str, int] = {}

        def visit(n, path):
            if state.get(n) == 2:
                return
            if n in path:
                raise ValueError(f"cycle through {n}")
            for d in self.stages[n].downstream:
                if d not in self.stages:
                    raise ValueError(f"unknown downstream stage {d}")
                visit(d, path | {n})
            state[n] = 2

        visit(self.source, set())

    # the engine's analyze_fn
    def __call__(self, stream_key: str, records):
        return self._run(self.source, stream_key, records)

    def _run(self, name: str, key: str, value):
        stage = self.stages[name]
        out = stage.fn(key, value)
        if out is None:
            return None
        self.record(name, key, out)
        for d in stage.downstream:
            self._run(d, key, out)
        return out

    def record(self, stage: str, key: str, value) -> None:
        """Append one sink entry, clock-stamped (shared by the legacy
        traversal above and the operator-compiled path — see
        ``repro.streaming.operators.lower_dag``)."""
        with self._lock:
            self.sinks[stage].append((key, value, self._clock.now()))

    def results(self, stage: str) -> list[tuple[str, Any, float]]:
        with self._lock:
            return list(self.sinks[stage])

    def latest(self, stage: str) -> dict[str, Any]:
        """Most recent sink value per stream key (dashboards/panels)."""
        out: dict[str, Any] = {}
        for key, value, _t in self.results(stage):
            out[key] = value
        return out
