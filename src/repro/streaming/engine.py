"""Distributed stream-processing engine — the Spark-Streaming stand-in.

Implements the paper's Cloud pipeline (Fig 2/3): endpoints feed per-stream
micro-batches (trigger-interval windows, like Spark DStreams); micro-batches
of one stream form partitions of an RDD-like unit of work; a fixed subset of
executors owns each endpoint's partitions (the paper's 16:1:16 mapping) and
pipes each partition to the analysis function exactly once (rdd.pipe); a
collector gathers results (rdd.collect) with generation->analysis latency.

Beyond the paper (Spark gave these for free; we implement them):
  * work stealing   — idle executors steal queued partitions (straggler
                      mitigation),
  * elastic scaling — add/remove executors at runtime,
  * failure handling — a dead executor's queued partitions are reassigned.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.records import StreamRecord


@dataclass
class MicroBatch:
    stream_key: str
    records: list[StreamRecord]
    t_created: float = field(default_factory=time.time)

    @property
    def steps(self) -> list[int]:
        return [r.step for r in self.records]


@dataclass
class Result:
    stream_key: str
    value: Any
    n_records: int
    t_generated_min: float
    t_analyzed: float
    executor: int

    @property
    def latency(self) -> float:
        """Paper §4.3 metric: data generated -> data analyzed."""
        return self.t_analyzed - self.t_generated_min


class _Executor(threading.Thread):
    def __init__(self, idx: int, engine: "StreamEngine"):
        super().__init__(daemon=True, name=f"executor-{idx}")
        self.idx = idx
        self.engine = engine
        self.q: queue.Queue = queue.Queue()
        self.alive = True
        self.processed = 0
        self.stolen = 0
        self.slowdown = 0.0            # straggler injection (tests/benches)

    def run(self):
        eng = self.engine
        while self.alive:
            try:
                mb = self.q.get(timeout=0.02)
            except queue.Empty:
                mb = eng._steal(self.idx)
                if mb is None:
                    continue
                self.stolen += 1
            if mb is _POISON:
                break
            if self.slowdown:
                time.sleep(self.slowdown)
            try:
                value = eng.analyze_fn(mb.stream_key, mb.records)
            except Exception as e:  # analysis failure != engine failure
                value = e
            tmin = min((r.t_generated for r in mb.records), default=mb.t_created)
            eng._collect(Result(stream_key=mb.stream_key, value=value,
                                n_records=len(mb.records),
                                t_generated_min=tmin,
                                t_analyzed=time.time(), executor=self.idx))
            self.processed += 1

    def kill(self):
        """Simulated hard failure: drop the thread, orphan its queue."""
        self.alive = False


_POISON = MicroBatch(stream_key="__poison__", records=[])


class StreamEngine:
    def __init__(self, endpoints: list, analyze_fn: Callable,
                 n_executors: int, *, trigger_interval: float = 3.0,
                 min_batch: int = 2):
        """endpoints: Endpoint handles (drain API).  analyze_fn(key, records).

        ``min_batch``: a stream's drained records are held until at least
        this many accumulate (so the analyze path sees real micro-batches —
        one device call per batch, not per record) or until a trigger
        interval has passed since the first held record, whichever first;
        ``drain_and_stop`` force-flushes the remainder."""
        self.endpoints = endpoints
        self.analyze_fn = analyze_fn
        self.trigger_interval = trigger_interval
        self.min_batch = min_batch
        self.results: list[Result] = []
        self._rlock = threading.Lock()
        self._elock = threading.Lock()
        self._tlock = threading.Lock()         # trigger_once reentrancy
        self._hold: dict[str, list[StreamRecord]] = {}
        self._hold_t: dict[str, float] = {}    # first-held time per stream
        self.executors: list[_Executor] = []
        self._stop = threading.Event()
        self._assign: dict[str, int] = {}      # stream -> executor idx
        for _ in range(n_executors):
            self._add_executor_locked()
        self._driver = threading.Thread(target=self._drive, daemon=True,
                                        name="stream-driver")
        self._driver.start()

    @classmethod
    def from_config(cls, cfg, endpoints: list, analyze_fn: Callable, *,
                    plan=None) -> "StreamEngine":
        """Build from a ``repro.workflow.WorkflowConfig`` (duck-typed here to
        keep streaming← workflow import-free).  ``n_executors=None`` falls
        back to the plan's groups × executors_per_group — the paper's
        16:1:16 operating point."""
        n_exec = cfg.n_executors
        if n_exec is None:
            n_exec = plan.n_executors if plan is not None \
                else max(1, len(endpoints)) * cfg.executors_per_group
        return cls(endpoints, analyze_fn, n_executors=n_exec,
                   trigger_interval=cfg.trigger_interval,
                   min_batch=cfg.min_batch)

    def attach_dag(self, dag: Callable) -> None:
        """Session-driven rewiring: route every micro-batch through an
        ``AnalysisDAG`` (or any ``(stream_key, records) -> value`` callable).
        Takes effect for the next dispatched partition — executors look up
        ``analyze_fn`` per call."""
        self.analyze_fn = dag

    # ---- executor lifecycle (elasticity + failure) ----------------------
    def _add_executor_locked(self):
        ex = _Executor(len(self.executors), self)
        self.executors.append(ex)
        ex.start()
        return ex

    def add_executor(self):
        with self._elock:
            return self._add_executor_locked()

    def remove_executor(self):
        with self._elock:
            for ex in reversed(self.executors):
                if ex.alive:
                    ex.alive = False
                    ex.q.put(_POISON)
                    self._reassign(ex)
                    return ex.idx
        return None

    def kill_executor(self, idx: int):
        """Hard failure; queued partitions are reassigned to survivors."""
        ex = self.executors[idx]
        ex.kill()
        self._reassign(ex)

    def _reassign(self, dead: _Executor):
        moved = 0
        while True:
            try:
                mb = dead.q.get_nowait()
            except queue.Empty:
                break
            if mb is _POISON:
                continue
            tgt = self._pick_executor(mb.stream_key, exclude=dead.idx)
            if tgt is not None:
                tgt.q.put(mb)
                moved += 1
        for k, v in list(self._assign.items()):
            if v == dead.idx:
                del self._assign[k]
        return moved

    def _alive(self) -> list[_Executor]:
        return [e for e in self.executors if e.alive]

    def _pick_executor(self, stream_key: str, exclude: int | None = None):
        alive = [e for e in self._alive() if e.idx != exclude]
        if not alive:
            return None
        if stream_key in self._assign:
            idx = self._assign[stream_key]
            for e in alive:
                if e.idx == idx:
                    return e
        # sticky partition->executor mapping (paper: fixed subset per stream)
        e = min(alive, key=lambda e: e.q.qsize())
        self._assign[stream_key] = e.idx
        return e

    # ---- work stealing ---------------------------------------------------
    def _steal(self, thief_idx: int):
        victims = [e for e in self._alive() if e.idx != thief_idx and e.q.qsize() > 1]
        if not victims:
            return None
        victim = max(victims, key=lambda e: e.q.qsize())
        try:
            return victim.q.get_nowait()
        except queue.Empty:
            return None

    # ---- driver: trigger-interval micro-batching -------------------------
    def _drive(self):
        while not self._stop.is_set():
            t0 = time.time()
            self.trigger_once()
            dt = time.time() - t0
            self._stop.wait(max(0.0, self.trigger_interval - dt))

    def trigger_once(self, force: bool = False) -> int:
        """Drain endpoints into per-stream hold buffers and dispatch every
        stream that is ripe: >= min_batch records held, the first held
        record is older than one trigger interval, or ``force``."""
        n = 0
        now = time.time()
        with self._tlock:
            for ep in self.endpoints:
                for key in ep.stream_keys():
                    recs = ep.drain(key)
                    if recs:
                        self._hold.setdefault(key, []).extend(recs)
                        self._hold_t.setdefault(key, now)
            for key in list(self._hold):
                held = self._hold[key]
                ripe = (force or len(held) >= self.min_batch
                        or now - self._hold_t[key] >= self.trigger_interval)
                if not ripe:
                    continue
                ex = self._pick_executor(key)
                if ex is None:
                    continue
                ex.q.put(MicroBatch(stream_key=key, records=held))
                del self._hold[key], self._hold_t[key]
                n += 1
        return n

    def held(self) -> int:
        with self._tlock:
            return sum(len(v) for v in self._hold.values())

    def _collect(self, r: Result):
        with self._rlock:
            self.results.append(r)

    # ---- public ----------------------------------------------------------
    def collect(self, clear: bool = False) -> list[Result]:
        with self._rlock:
            out = list(self.results)
            if clear:
                self.results.clear()
            return out

    def latency_stats(self) -> dict:
        lats = [r.latency for r in self.collect()]
        if not lats:
            return {"n": 0}
        lats.sort()
        return {"n": len(lats),
                "mean": sum(lats) / len(lats),
                "p50": lats[len(lats) // 2],
                "p99": lats[min(len(lats) - 1, int(len(lats) * 0.99))],
                "max": lats[-1]}

    def drain_and_stop(self, timeout: float = 30.0):
        deadline = time.time() + timeout
        while time.time() < deadline:
            pending = sum(ep.pending() for ep in self.endpoints)
            queued = sum(e.q.qsize() for e in self._alive())
            if pending == 0 and queued == 0 and self.held() == 0:
                break
            self.trigger_once(force=True)
            time.sleep(0.05)
        self._stop.set()
        survivors = self._alive()
        for e in survivors:
            e.alive = False
            e.q.put(_POISON)
        for e in survivors:          # results must be collected before return
            e.join(timeout=5.0)
