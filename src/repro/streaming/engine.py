"""Distributed stream-processing engine — the Spark-Streaming stand-in.

Implements the paper's Cloud pipeline (Fig 2/3): endpoints feed per-stream
micro-batches (trigger-interval windows, like Spark DStreams); micro-batches
of one stream form partitions of an RDD-like unit of work; a fixed subset of
executors owns each endpoint's partitions (the paper's 16:1:16 mapping) and
pipes each partition to the analysis function exactly once (rdd.pipe); a
collector gathers results (rdd.collect) with generation->analysis latency.

Beyond the paper (Spark gave these for free; we implement them):
  * work stealing   — idle executors steal queued partitions (straggler
                      mitigation).  Steals migrate the stream's sticky
                      assignment to the thief, and per-stream sequence
                      tickets guarantee a stolen micro-batch is never
                      analyzed concurrently with — or ahead of — an earlier
                      micro-batch of the same stream,
  * elastic scaling — add/remove/replace executors at runtime; every scale
                      event triggers ``rebalance()`` so stream→executor
                      stickiness is recomputed against the new fleet,
  * failure handling — a dead executor's queued partitions are reassigned,
  * observability   — ``metrics()`` returns a thread-safe control-plane
                      snapshot (per-executor queues, rolling latency
                      percentiles, executor-seconds) consumed by
                      ``repro.runtime.telemetry``,
  * plan-aware dispatch — ``attach_plan`` installs a compiled operator
                      ``ExecutionPlan`` (repro.streaming.operators): its
                      order-insensitive prefix runs before/without the
                      per-stream ordering ticket with partitions spread
                      across executors (intra-stream parallelism), while
                      the ordered suffix keeps the exact-sequence
                      guarantee; ``drain_and_stop`` fires still-open
                      window panes once every partition has completed.
"""
from __future__ import annotations

import queue
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.records import StreamRecord
from repro.runtime.clock import Clock, ensure_clock

# A waiting executor proceeds out-of-order after this long rather than stall
# the pipeline if its stream's ticket chain broke (a dropped partition with
# no surviving executor); counted in metrics()["order_timeouts"].
_ORDER_WAIT_S = 5.0

# metrics() latency percentiles cover at most this much trailing wall time,
# so a past breach episode ages out of the QoS signal instead of pinning
# the controller's p99 reading high through a quiet period.
_LATENCY_WINDOW_S = 30.0


def percentile_sorted(sorted_vals: list, p: float) -> float:
    """Nearest-rank percentile over an ASCENDING-sorted list; NaN if empty.
    The one definition shared by latency_stats(), metrics(), and the
    elasticity benchmark, so the controller's QoS signal and the bench's
    pass/fail gate measure the same quantity."""
    if not sorted_vals:
        return float("nan")
    return sorted_vals[min(len(sorted_vals) - 1, int(len(sorted_vals) * p))]


@dataclass
class MicroBatch:
    stream_key: str
    records: list[StreamRecord]
    # 0.0, not wall time: the engine stamps this explicitly from its clock
    # at dispatch (trigger_once); a wall-epoch default would leak ~1.7e9s
    # timestamps into virtual-time runs from directly-constructed batches
    t_created: float = 0.0
    seq: int = 0                 # per-stream dispatch sequence (ordering)

    @property
    def steps(self) -> list[int]:
        return [r.step for r in self.records]


@dataclass
class Result:
    stream_key: str
    value: Any
    n_records: int
    t_generated_min: float
    t_analyzed: float
    executor: int
    # per-tenant share of this batch: tenant -> (n_records, min t_generated);
    # the QoS plane's per-tenant latency is t_analyzed - that tenant's min
    tenants: dict = field(default_factory=dict)

    @property
    def latency(self) -> float:
        """Paper §4.3 metric: data generated -> data analyzed."""
        return self.t_analyzed - self.t_generated_min

    def tenant_latency(self, name: str) -> float | None:
        ent = self.tenants.get(name)
        return None if ent is None else self.t_analyzed - ent[1]


class _Executor(threading.Thread):
    def __init__(self, idx: int, engine: "StreamEngine"):
        super().__init__(daemon=True, name=f"executor-{idx}")
        self.idx = idx
        self.engine = engine
        self.q: queue.Queue = queue.Queue()
        self.alive = True
        self.processed = 0
        self.stolen = 0
        self.slowdown = 0.0            # straggler injection (tests/benches)
        self.current_key: str | None = None    # stream being analyzed now
        self.t_busy_since = 0.0        # when the current analysis started
        self.waiting = False           # blocked on an ordering ticket

    def run(self):
        eng = self.engine
        clock = eng.clock
        while self.alive:
            mb = clock.queue_get(self.q, timeout=0.02)
            if mb is None:
                mb = eng._steal(self.idx)
                if mb is None:
                    continue
                self.stolen += 1
            if mb is _POISON:
                break
            self.current_key = mb.stream_key
            plan = eng.plan
            if plan is None:
                self.waiting = True
                eng._await_turn(mb)    # per-stream order even across steals
                self.waiting = False
                self.t_busy_since = clock.now()
                if self.slowdown:
                    clock.sleep(self.slowdown)
                try:
                    value = eng.analyze_fn(mb.stream_key, mb.records)
                except Exception as e:  # analysis failure != engine failure
                    value = e
            else:
                value = self._run_plan(plan, mb, clock)
            tmin = min((r.t_generated for r in mb.records), default=mb.t_created)
            by_tenant: dict[str, tuple[int, float]] = {}
            for r in mb.records:
                ent = by_tenant.get(r.tenant)
                by_tenant[r.tenant] = (1, r.t_generated) if ent is None else \
                    (ent[0] + 1, min(ent[1], r.t_generated))
            eng._collect(Result(stream_key=mb.stream_key, value=value,
                                n_records=len(mb.records),
                                t_generated_min=tmin,
                                t_analyzed=clock.now(), executor=self.idx,
                                tenants=by_tenant))
            self.processed += 1
            self.current_key = None
            eng._release_turn(mb)
        # hand back anything still queued: a partition can land here AFTER
        # _reassign drained this queue (e.g. this thread was mid-_steal when
        # it was replaced and put the stolen run into its own dead queue)
        eng._reassign(self)
        clock.detach()     # exit the schedule without a watchdog stall

    def _run_plan(self, plan, mb: MicroBatch, clock) -> Any:
        """Plan-aware execution: the order-insensitive prefix runs BEFORE
        (and without) the stream's ordering ticket — that's what lets
        micro-batches of ONE stream proceed concurrently on many executors —
        then the ordered suffix (if any) under the ticket, exactly
        sequenced.  A plan with no ordered stages never takes the ticket."""
        eng = self.engine
        self.t_busy_since = clock.now()
        if self.slowdown:
            clock.sleep(self.slowdown)
        pre_out = None
        if plan.pre_stages:
            try:
                # seq feeds the plan's in-order frontier: window watermarks
                # advance only over the contiguous per-stream prefix, so
                # concurrent out-of-order batches can't induce late drops
                pre_out = plan.run_pre(mb.stream_key, mb.records, seq=mb.seq)
            except Exception as e:     # analysis failure != engine failure
                if plan.post_stages:
                    # the failed batch must still take its ordering turn:
                    # the caller's _release_turn is a max-jump, so releasing
                    # out of sequence would unblock every in-flight earlier
                    # batch at once and break the ordered suffix's contract
                    self.waiting = True
                    eng._await_turn(mb)
                    self.waiting = False
                return e
            if not plan.post_stages:
                return pre_out.primary
        self.waiting = True
        eng._await_turn(mb)
        self.waiting = False
        self.t_busy_since = clock.now()
        try:
            return plan.run_post(mb.stream_key, pre_out, mb.records)
        except Exception as e:
            return e

    def kill(self):
        """Simulated hard failure: drop the thread, orphan its queue."""
        self.alive = False


_POISON = MicroBatch(stream_key="__poison__", records=[])


class StreamEngine:
    def __init__(self, endpoints: list, analyze_fn: Callable,
                 n_executors: int, *, trigger_interval: float = 3.0,
                 min_batch: int = 2, clock: Clock | None = None,
                 order_wait_s: float = _ORDER_WAIT_S,
                 shuffle_partitions: int | None = None):
        """endpoints: Endpoint handles (drain API).  analyze_fn(key, records).

        ``min_batch``: a stream's drained records are held until at least
        this many accumulate (so the analyze path sees real micro-batches —
        one device call per batch, not per record) or until a trigger
        interval has passed since the first held record, whichever first;
        ``drain_and_stop`` force-flushes the remainder.

        ``clock``: every timestamp, sleep, and blocking wait goes through it
        (default wall time); a ``VirtualClock`` makes the whole engine —
        driver, executors, ordering waits, latency accounting — run on
        deterministic simulated time.

        ``shuffle_partitions``: when set and the attached plan compiles to
        a shuffle edge (source ``KeyBy`` at record granularity), dispatch
        re-partitions records ACROSS producer streams by the KeyBy's output
        key: micro-batches become key partitions (``part:NNNN``), sticky
        partition->executor ownership replaces producer-stream ownership,
        and ordering tickets are issued per partition."""
        self.endpoints = endpoints
        self.analyze_fn = analyze_fn
        self.plan = None               # compiled operator ExecutionPlan
        self.trigger_interval = trigger_interval
        self.min_batch = min_batch
        self.order_wait_s = order_wait_s
        self.shuffle_partitions = shuffle_partitions
        self.clock = ensure_clock(clock)
        self.results: list[Result] = []
        self._recent_lat: deque = deque(maxlen=512)  # rolling latency window
        # per-tenant rolling latency + analyzed totals (QoS plane rollups)
        self._tenant_lat: dict[str, deque] = {}
        self._tenant_analyzed: dict[str, int] = {}
        self._rlock = threading.Lock()
        self._elock = threading.Lock()
        # trigger_once reentrancy + hold/assign/seq state (RLock: _reassign
        # and _pick_executor may be reached both under it and bare)
        self._tlock = threading.RLock()
        self._hold: dict[str, list[StreamRecord]] = {}
        self._hold_t: dict[str, float] = {}    # first-held time per stream
        self.executors: list[_Executor] = []
        self._stop = threading.Event()
        self._assign: dict[str, int] = {}      # stream -> executor idx
        self._next_seq: dict[str, int] = {}    # stream -> next dispatch seq
        self._done_cv = threading.Condition()
        self._done_seq: dict[str, int] = {}    # stream -> completed prefix
        self.order_timeouts = 0                # broken-chain escapes (rare)
        self.rebalances = 0
        # executor-seconds integral (elasticity cost accounting)
        self._exec_secs = 0.0
        self._exec_t = self.clock.now()
        for _ in range(n_executors):
            self._add_executor_locked()
        self._driver = threading.Thread(target=self._drive, daemon=True,
                                        name="stream-driver")
        self.clock.thread_started(self._driver)
        self._driver.start()

    @classmethod
    def from_config(cls, cfg, endpoints: list, analyze_fn: Callable, *,
                    plan=None, clock: Clock | None = None) -> "StreamEngine":
        """Build from a ``repro.workflow.WorkflowConfig`` (duck-typed here to
        keep streaming← workflow import-free).  ``n_executors=None`` falls
        back to the plan's groups × executors_per_group — the paper's
        16:1:16 operating point."""
        n_exec = cfg.n_executors
        if n_exec is None:
            n_exec = plan.n_executors if plan is not None \
                else max(1, len(endpoints)) * cfg.executors_per_group
        return cls(endpoints, analyze_fn, n_executors=n_exec,
                   trigger_interval=cfg.trigger_interval,
                   min_batch=cfg.min_batch, clock=clock,
                   order_wait_s=getattr(cfg, "order_wait_s", _ORDER_WAIT_S),
                   shuffle_partitions=getattr(cfg, "shuffle_partitions",
                                              None))

    def attach_dag(self, dag: Callable) -> None:
        """Session-driven rewiring: route every micro-batch through an
        ``AnalysisDAG`` (or any ``(stream_key, records) -> value`` callable).
        Takes effect for the next dispatched partition — executors look up
        ``analyze_fn`` per call."""
        self.plan = None
        self.analyze_fn = dag

    def attach_plan(self, plan) -> None:
        """Route every micro-batch through a compiled operator
        ``ExecutionPlan`` (see ``repro.streaming.operators``).  Dispatch
        becomes plan-aware: plans with an order-insensitive prefix get their
        partitions spread across executors (intra-stream parallelism, capped
        by the plan's parallelism hint) instead of sticky-assigned, and the
        ordering ticket is only taken for the plan's ordered suffix.

        Attaching mid-run aligns the plan's watermark frontier with the
        engine's continuing per-stream seq counters — a fresh frontier
        expecting seq 0 would park every future batch as pending and stall
        window firing until drain.

        With ``shuffle_partitions`` configured, a plan that compiles to a
        shuffle edge (source KeyBy, record granularity) switches to keyed-
        shuffle dispatch; plans without one keep producer partitioning."""
        enable = getattr(plan, "enable_shuffle", None)
        if (self.shuffle_partitions is not None and enable is not None
                and getattr(plan, "shuffle_op", None) is not None):
            enable(self.shuffle_partitions)
        seed = getattr(plan, "seed_frontier", None)
        if seed is not None:
            with self._tlock:
                seed(dict(self._next_seq))
        self.plan = plan

    # ---- per-stream ordering tickets ------------------------------------
    def _await_turn(self, mb: MicroBatch) -> bool:
        """Block until every earlier micro-batch of this stream has been
        analyzed.  Sequence tickets are issued at dispatch, so order holds
        across steals, reassignment, and rebalance.  Returns False on the
        (pathological) broken-chain timeout."""
        if self.clock.wait_cv(
                self._done_cv,
                lambda: self._done_seq.get(mb.stream_key, 0) >= mb.seq,
                timeout=self.order_wait_s):
            return True
        self.order_timeouts += 1
        return False

    def _release_turn(self, mb: MicroBatch) -> None:
        with self._done_cv:
            if mb.seq + 1 > self._done_seq.get(mb.stream_key, 0):
                self._done_seq[mb.stream_key] = mb.seq + 1
            self._done_cv.notify_all()

    # ---- executor lifecycle (elasticity + failure) ----------------------
    def _account_locked(self, now: float | None = None) -> None:
        """Advance the executor-seconds integral (call under _elock)."""
        now = self.clock.now() if now is None else now
        alive = sum(1 for e in self.executors if e.alive)
        self._exec_secs += alive * (now - self._exec_t)
        self._exec_t = now

    def executor_seconds(self) -> float:
        """∫ alive-executor-count dt since engine start — the provisioning
        cost the elasticity benchmark compares against static peak."""
        with self._elock:
            self._account_locked()
            return self._exec_secs

    def _add_executor_locked(self):
        self._account_locked()
        ex = _Executor(len(self.executors), self)
        self.executors.append(ex)
        self.clock.thread_started(ex)
        ex.start()
        return ex

    def add_executor(self):
        with self._elock:
            ex = self._add_executor_locked()
        self.rebalance()
        return ex

    def remove_executor(self, idx: int | None = None):
        """Graceful scale-in.  ``idx=None`` retires the newest alive
        executor; an explicit ``idx`` retires that one (the cloud capacity
        plane drains a *specific* node's executors before poweroff).
        Queued partitions are reassigned to survivors either way."""
        with self._elock:
            removed = None
            cands = (reversed(self.executors) if idx is None
                     else [self.executors[idx]])
            for ex in cands:
                if ex.alive:
                    self._account_locked()
                    ex.alive = False
                    ex.q.put(_POISON)
                    self._reassign(ex)
                    removed = ex.idx
                    break
        if removed is not None:
            self.rebalance()
        return removed

    def attach_endpoint(self, handle) -> None:
        """Start draining a freshly provisioned endpoint's streams (cloud
        capacity plane: list append is atomic, pollers see it next cycle)."""
        self.endpoints.append(handle)

    def kill_executor(self, idx: int):
        """Hard failure; queued partitions are reassigned to survivors."""
        ex = self.executors[idx]
        with self._elock:
            self._account_locked()
            ex.kill()
            self._reassign(ex)
        self.rebalance()

    def replace_executor(self, idx: int):
        """Straggler/failure remediation: retire executor ``idx`` (its queue
        is reassigned) and bring up a fresh one.  Returns the replacement."""
        ex = self.executors[idx]
        with self._elock:
            self._account_locked()
            if ex.alive:
                ex.alive = False
                ex.q.put(_POISON)
            self._reassign(ex)
            new = self._add_executor_locked()
        self.rebalance()
        return new

    def rebalance(self) -> int:
        """Recompute stream→executor stickiness against the current fleet
        (called on every scale/failure event).  Only streams with NO
        dispatched-but-unfinished micro-batches are released — a backlogged
        stream must keep its assignment so new dispatches queue behind the
        backlog in order; *stealing* is what migrates a backlog to a new
        executor (oldest batch first, assignment moved with it).  Returns
        the number of stream assignments released."""
        with self._done_cv:
            done = dict(self._done_seq)
        n = 0
        with self._tlock:
            for key in list(self._assign):
                if done.get(key, 0) >= self._next_seq.get(key, 0):
                    del self._assign[key]
                    n += 1
        self.rebalances += 1
        return n

    @staticmethod
    def _enqueue_in_seq_order(tgt: _Executor, mb: MicroBatch) -> None:
        """Insert a reassigned partition BEFORE any later-seq partition of
        the same stream already queued on the target (the driver may have
        dispatched newer batches to the new sticky executor while the dead
        one's queue was still being drained); plain append would make the
        target block on its own queue and then analyze out of order."""
        with tgt.q.mutex:
            dq = tgt.q.queue
            pos = next((i for i, x in enumerate(dq)
                        if isinstance(x, MicroBatch) and x is not _POISON
                        and x.stream_key == mb.stream_key
                        and x.seq > mb.seq), None)
            if pos is None:
                dq.append(mb)
            else:
                dq.insert(pos, mb)
            tgt.q.not_empty.notify()

    def _reassign(self, dead: _Executor):
        moved = 0
        while True:
            try:
                mb = dead.q.get_nowait()
            except queue.Empty:
                break
            if mb is _POISON:
                continue
            tgt = self._pick_executor(mb.stream_key, exclude=dead.idx)
            if tgt is not None:
                self._enqueue_in_seq_order(tgt, mb)
                moved += 1
            else:
                # no survivor: release the ticket so later batches of this
                # stream (none can exist yet without executors, but a scale-up
                # may follow) don't wait on a batch nobody holds
                self._release_turn(mb)
        with self._tlock:
            for k, v in list(self._assign.items()):
                if v == dead.idx:
                    del self._assign[k]
        return moved

    def _alive(self) -> list[_Executor]:
        return [e for e in self.executors if e.alive]

    def _pick_executor(self, stream_key: str, exclude: int | None = None):
        alive = [e for e in self._alive() if e.idx != exclude]
        if not alive:
            return None
        with self._tlock:
            if stream_key in self._assign:
                idx = self._assign[stream_key]
                for e in alive:
                    if e.idx == idx:
                        return e
            # sticky partition->executor mapping (paper: fixed subset per
            # stream), least-loaded at (re)assignment time
            e = min(alive, key=lambda e: e.q.qsize())
            self._assign[stream_key] = e.idx
            return e

    def _pick_parallel(self):
        """Plan-aware dispatch for order-insensitive work: NO stickiness —
        each partition goes to the least-loaded alive executor, so one
        stream's micro-batches spread across the fleet.  A parallelism hint
        caps the *candidates per dispatch* to the hint least-loaded
        executors (not a fixed low-index subset — scale-ups must stay
        usable).  Per-stream queues stay seq-ascending because dispatch
        itself is in seq order."""
        alive = self._alive()
        if not alive:
            return None
        hint = self.plan.parallelism if self.plan is not None else None
        if hint is not None and hint < len(alive):
            alive = sorted(alive, key=lambda e: e.q.qsize())[:hint]
        return min(alive, key=lambda e: e.q.qsize())

    # ---- work stealing ---------------------------------------------------
    @staticmethod
    def _peek_key(ex: _Executor) -> str | None:
        with ex.q.mutex:
            head = ex.q.queue[0] if ex.q.queue else None
        return head.stream_key if isinstance(head, MicroBatch) else None

    def _steal(self, thief_idx: int):
        """Steal the oldest queued partition from the deepest victim — and
        migrate the WHOLE stream with it: every later queued partition of
        that stream moves to the thief (in order) and the sticky assignment
        follows, so the thief owns the stream's run end-to-end instead of
        blocking on ordering tickets behind the victim's queue.  Prefer
        victims whose head partition is NOT the stream the victim is
        analyzing right now (that ticket would make the thief wait out the
        victim's in-flight batch); tickets keep order correct either way."""
        victims = sorted(
            (e for e in self._alive()
             if e.idx != thief_idx and e.q.qsize() > 1),
            key=lambda e: e.q.qsize(), reverse=True)
        if not victims:
            return None
        preferred = [v for v in victims
                     if self._peek_key(v) != v.current_key] or victims
        for victim in preferred:
            try:
                mb = victim.q.get_nowait()
            except queue.Empty:
                continue
            if mb is _POISON:          # dying executor: hand it back
                victim.q.put(_POISON)
                continue
            if (self.plan is not None and self.plan.parallel_dispatch
                    and not getattr(self.plan, "shuffled", False)):
                # parallel-dispatch plans have no sticky run to migrate:
                # batches of one stream are already spread, so steal just
                # the head partition.  Shuffled plans DO have sticky runs
                # (partition ownership) and fall through to run migration.
                return mb
            key = mb.stream_key
            # extract the rest of this stream's queued run, preserving order
            with victim.q.mutex:
                rest = [x for x in victim.q.queue
                        if isinstance(x, MicroBatch) and x is not _POISON
                        and x.stream_key == key]
                for x in rest:
                    victim.q.queue.remove(x)
            with self._tlock:
                if self._assign.get(key) == victim.idx:
                    self._assign[key] = thief_idx
            thief = self.executors[thief_idx]
            for x in rest:
                thief.q.put(x)
            return mb
        return None

    # ---- driver: trigger-interval micro-batching -------------------------
    def _drive(self):
        while not self._stop.is_set():
            t0 = self.clock.now()
            self.trigger_once()
            dt = self.clock.now() - t0
            self.clock.wait_event(self._stop,
                                  timeout=max(0.0, self.trigger_interval - dt))
        self.clock.detach()    # exit the schedule without a watchdog stall

    def trigger_once(self, force: bool = False) -> int:
        """Drain endpoints into hold buffers and dispatch every buffer that
        is ripe: >= min_batch records held, the first held record is older
        than one trigger interval, or ``force``.

        Hold buffers are per producer stream by default.  Under keyed
        shuffle (``plan.shuffled``) they are per key **partition**: each
        drained record is routed to ``part:NNNN`` by the plan's shuffle
        edge, pooling co-keyed records from many streams into one partition
        and spreading one hot stream's keys over all partitions.  Shuffled
        partitions dispatch with sticky partition->executor ownership (the
        partition, not the producer stream, is the unit the fleet owns),
        and seq tickets are issued per partition."""
        n = 0
        now = self.clock.now()
        plan = self.plan
        shuffled = plan is not None and getattr(plan, "shuffled", False)
        with self._tlock:
            for ep in self.endpoints:
                for key in ep.stream_keys():
                    recs = ep.drain(key)
                    if not recs:
                        continue
                    if shuffled:
                        for r in recs:
                            pkey = f"part:{plan.shuffle_partition(r):04d}"
                            self._hold.setdefault(pkey, []).append(r)
                            self._hold_t.setdefault(pkey, now)
                    else:
                        self._hold.setdefault(key, []).extend(recs)
                        self._hold_t.setdefault(key, now)
            for key in list(self._hold):
                held = self._hold[key]
                ripe = (force or len(held) >= self.min_batch
                        or now - self._hold_t[key] >= self.trigger_interval)
                if not ripe:
                    continue
                parallel = (not shuffled and plan is not None
                            and plan.parallel_dispatch)
                ex = self._pick_parallel() if parallel \
                    else self._pick_executor(key)
                if ex is None:
                    continue
                seq = self._next_seq.get(key, 0)
                self._next_seq[key] = seq + 1
                ex.q.put(MicroBatch(stream_key=key, records=held, seq=seq,
                                    t_created=now))
                del self._hold[key], self._hold_t[key]
                n += 1
        return n

    def held(self) -> int:
        with self._tlock:
            return sum(len(v) for v in self._hold.values())

    def _collect(self, r: Result):
        with self._rlock:
            self.results.append(r)
            self._recent_lat.append((r.t_analyzed, r.latency))
            for name, (n, tmin) in r.tenants.items():
                self._tenant_analyzed[name] = \
                    self._tenant_analyzed.get(name, 0) + n
                self._tenant_lat.setdefault(name, deque(maxlen=512)).append(
                    (r.t_analyzed, r.t_analyzed - tmin))

    # ---- public ----------------------------------------------------------
    def collect(self, clear: bool = False) -> list[Result]:
        with self._rlock:
            out = list(self.results)
            if clear:
                self.results.clear()
            return out

    def latency_stats(self) -> dict:
        lats = [r.latency for r in self.collect()]
        if not lats:
            return {"n": 0}
        lats.sort()
        return {"n": len(lats),
                "mean": sum(lats) / len(lats),
                "p50": percentile_sorted(lats, 0.50),
                "p99": percentile_sorted(lats, 0.99),
                "max": lats[-1]}

    def metrics(self) -> dict:
        """Thread-safe control-plane snapshot: per-executor queue depth /
        steal counts, hold-buffer backlog, rolling (windowed) latency
        percentiles, and the executor-seconds integral.  This is the
        engine's feed into ``runtime.telemetry.TelemetryBus``."""
        def _qrecords(ex: _Executor) -> int:
            with ex.q.mutex:
                return sum(len(x.records) for x in ex.q.queue
                           if isinstance(x, MicroBatch))
        with self._elock:
            self._account_locked()
            execs = [{"idx": e.idx, "alive": e.alive,
                      "queue_depth": e.q.qsize(),
                      "queued_records": _qrecords(e),
                      "processed": e.processed,
                      "stolen": e.stolen, "current_key": e.current_key,
                      "waiting": e.waiting}
                     for e in self.executors]
            exec_secs = self._exec_secs
        with self._tlock:
            held = sum(len(v) for v in self._hold.values())
            n_streams = len(self._next_seq)
        cut = self.clock.now() - _LATENCY_WINDOW_S
        with self._rlock:
            lats = sorted(lat for t, lat in self._recent_lat if t >= cut)
            n_results = len(self.results)
            tenants = {}
            for name, analyzed in self._tenant_analyzed.items():
                tl = sorted(lat for t, lat in self._tenant_lat.get(name, ())
                            if t >= cut)
                tenants[name] = {
                    "analyzed": analyzed,
                    "latency_window_n": len(tl),
                    "latency_p50": percentile_sorted(tl, 0.50),
                    "latency_p99": percentile_sorted(tl, 0.99)}
        batch_agg = self.plan.batch_stats() if self.plan is not None else {}
        shuffle_n = self.plan.shuffle_partitions \
            if self.plan is not None and getattr(self.plan, "shuffled", False) \
            else None
        return {"executors": execs,
                "tenants": tenants,
                "shuffle_partitions": shuffle_n,
                "alive_executors": sum(1 for e in execs if e["alive"]),
                "batch_agg": batch_agg,
                "queued": sum(e["queue_depth"] for e in execs if e["alive"]),
                "queued_records": sum(e["queued_records"] for e in execs),
                "held_records": held,
                "n_streams": n_streams,
                "n_results": n_results,
                "latency_window_n": len(lats),
                "latency_p50": percentile_sorted(lats, 0.50),
                "latency_p99": percentile_sorted(lats, 0.99),
                "executor_seconds": exec_secs,
                "order_timeouts": self.order_timeouts,
                "rebalances": self.rebalances}

    def drain_and_stop(self, timeout: float = 30.0):
        deadline = self.clock.now() + timeout
        while self.clock.now() < deadline:
            # partitions stranded on dead executors (dispatch/steal raced a
            # kill) go back to survivors before we test for emptiness
            for e in self.executors:
                if not e.alive and e.q.qsize() and self._alive():
                    self._reassign(e)
            pending = sum(ep.pending() for ep in self.endpoints)
            queued = sum(e.q.qsize() for e in self._alive())
            stranded = sum(e.q.qsize() for e in self.executors if not e.alive)
            if pending == 0 and queued == 0 and self.held() == 0 \
                    and (stranded == 0 or not self._alive()):
                break
            self.trigger_once(force=True)
            self.clock.sleep(0.05)
        self._stop.set()
        with self._elock:
            self._account_locked()
        survivors = self._alive()
        for e in survivors:
            e.alive = False
            e.q.put(_POISON)
        for e in survivors:          # results must be collected before return
            self.clock.join(e, timeout=5.0)
        if self.plan is not None:
            # every partition is done: fire still-open window panes through
            # the rest of the graph (single-threaded, deterministic order)
            self.plan.flush()

    # ---- exactly-once recovery -------------------------------------------
    def kill(self) -> None:
        """Simulated hard crash: driver and executors stop immediately,
        queued and held micro-batches are discarded (the replacement
        session replays them from the broker WAL).  Contrast
        :meth:`drain_and_stop`, which completes all in-flight work."""
        self._stop.set()
        with self._elock:
            self._account_locked()
            for e in self.executors:
                e.alive = False
                with e.q.mutex:
                    e.q.queue.clear()
                    e.q.not_empty.notify_all()
                e.q.put(_POISON)
        with self._tlock:
            self._hold.clear()
            self._hold_t.clear()
        with self._done_cv:
            self._done_cv.notify_all()
        self.clock.join(self._driver, timeout=5.0)
        for e in self.executors:
            self.clock.join(e, timeout=5.0)

    def state_snapshot(self) -> dict:
        """Dispatch/ordering counters plus collected results — the engine's
        share of a Session checkpoint.  Callers quiesce the pipeline first
        (``Session.checkpoint`` does), so the snapshot is a consistent cut."""
        with self._tlock:
            next_seq = dict(self._next_seq)
        with self._done_cv:
            done_seq = dict(self._done_seq)
        with self._rlock:
            results = list(self.results)
        return {"next_seq": next_seq, "done_seq": done_seq,
                "results": results}

    def restore_state(self, state: dict) -> None:
        """Install a checkpointed :meth:`state_snapshot` into a fresh
        engine: per-stream seq counters resume where the dead engine
        stopped (keeping the plan's commit frontier consistent) and
        pre-crash results survive."""
        with self._tlock:
            self._next_seq = dict(state["next_seq"])
        with self._done_cv:
            self._done_seq = dict(state["done_seq"])
            self._done_cv.notify_all()
        with self._rlock:
            self.results = list(state["results"])
            # rebuild per-tenant analyzed totals from the restored results so
            # QoS rollups stay exact across a session restore (the rolling
            # latency windows restart — they are time-local by design)
            self._tenant_analyzed = {}
            for r in self.results:
                for name, (n, _) in getattr(r, "tenants", {}).items():
                    self._tenant_analyzed[name] = \
                        self._tenant_analyzed.get(name, 0) + n
