"""CLUES-style async CloudProvisioner.

The provisioner is passive: it owns a pending-task queue (power_on /
power_off requests) and advances it only when the ElasticController
calls :meth:`process_pending_tasks` at the top of each tick.  That keeps
every transition on the controller thread, so the whole capacity plane
is deterministic under ``VirtualClock`` — cold-start jitter and failure
draws come from one seeded RNG consumed in queue order.

Lifecycle, mirroring the CLUES powermanager shape::

    request_node()      -> PENDING   (power_on task queued)
    power_on ok         -> BOOTING   (billing opens; boot deadline set)
    boot deadline hit   -> READY     (fabric attaches endpoint + executors)
    request_poweroff()  -> DRAINING  (fabric reroutes groups, removes
                                      executors; poweroff task polls drain)
    fully drained       -> OFF       (billing closes, transport detached)
    retries exhausted   -> FAILED    (``recover()`` requeues)
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from random import Random

from repro.cloud.ledger import CostLedger
from repro.cloud.nodes import (
    BOOTING,
    DEFAULT_CATALOG,
    DRAINING,
    FAILED,
    OFF,
    PENDING,
    READY,
    CloudNode,
    NodeClass,
)
from repro.runtime.clock import ensure_clock


def pack_nodes(want: int, classes: list[NodeClass]) -> list[NodeClass]:
    """Greedy heterogeneous bin-packing of ``want`` executor slots.

    Big classes first (ties broken by cheaper cost_rate, then name) absorb
    the bulk of the deficit; the remainder is covered by the smallest class
    that still covers it — so a 5-slot spike over {large:4, small:1} packs
    as ``[large, small]`` instead of two larges.  Deterministic: same
    inputs, same pack.  The caller clamps to available room; this function
    only decides the mix.  Returns [] for want <= 0 or an empty catalog
    slice."""
    if want <= 0 or not classes:
        return []
    order = sorted(classes,
                   key=lambda c: (-c.executors, c.cost_rate, c.name))
    picked: list[NodeClass] = []
    rem = int(want)
    for cls in order:
        while rem >= cls.executors:
            picked.append(cls)
            rem -= cls.executors
    if rem > 0:
        # smallest class that covers the remainder (least overshoot)
        trim = min((c for c in order), key=lambda c: (c.executors, c.cost_rate,
                                                      c.name))
        picked.append(trim)
    return picked


@dataclass
class _Task:
    kind: str                 # "power_on" | "power_off"
    node: CloudNode
    attempts: int = 0
    not_before: float = 0.0   # retry backoff gate


@dataclass
class _Counters:
    requests: int = 0
    provision_failures: int = 0
    retries: int = 0
    nodes_ready: int = 0
    nodes_failed: int = 0
    nodes_off: int = 0
    recovered: int = 0
    extra: dict = field(default_factory=dict)


class CloudProvisioner:
    """Async provision/teardown driven through a pending-task queue."""

    def __init__(
        self,
        fabric,
        *,
        catalog: dict[str, NodeClass] | None = None,
        clock=None,
        seed: int = 0,
        retry_limit: int = 3,
        backoff_s: float = 0.5,
        ledger: CostLedger | None = None,
    ) -> None:
        if retry_limit < 1:
            raise ValueError("retry_limit must be >= 1")
        if backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")
        self.fabric = fabric
        self.catalog = dict(DEFAULT_CATALOG if catalog is None else catalog)
        self.clock = ensure_clock(clock)
        self.retry_limit = int(retry_limit)
        self.backoff_s = float(backoff_s)
        self.ledger = ledger if ledger is not None else CostLedger()
        self.nodes: list[CloudNode] = []
        self.events: list[tuple[float, dict]] = []
        self._rng = Random(seed)
        self._lock = threading.Lock()
        self._tasks: deque[_Task] = deque()
        self._next_id = 0
        self._c = _Counters()
        # fault injection (scenario hooks)
        self._fail_next = 0       # force the next N power_on attempts to fail
        self._stall_extra_s = 0.0  # one-shot extra cold-start time

    # ------------------------------------------------------------------
    # catalog / introspection

    def node_class(self, name: str) -> NodeClass:
        try:
            return self.catalog[name]
        except KeyError:
            raise KeyError(
                f"unknown node class {name!r}; catalog has {sorted(self.catalog)}"
            ) from None

    def expected_ready_s(self, class_name: str) -> float:
        """Worst-case cold start for a class (predictive horizon floor)."""
        return self.node_class(class_name).expected_ready_s()

    def capacity_in_flight(self) -> int:
        """Executor slots already requested but not READY yet.

        Scale-up decisions subtract this so a slow boot doesn't trigger a
        second wave of provisioning for the same breach (flap suppression).
        """
        with self._lock:
            return sum(
                n.node_class.executors
                for n in self.nodes
                if n.state in (PENDING, BOOTING)
            )

    def nodes_in_state(self, state: str) -> list[CloudNode]:
        with self._lock:
            return [n for n in self.nodes if n.state == state]

    # ------------------------------------------------------------------
    # requests

    def request_node(self, class_name: str) -> CloudNode:
        """Queue an async provision request; returns the PENDING node."""
        cls = self.node_class(class_name)
        with self._lock:
            now = self.clock.now()
            node = CloudNode(node_id=self._next_id, node_class=cls,
                             t_requested=now)
            self._next_id += 1
            self.nodes.append(node)
            self._tasks.append(_Task("power_on", node))
            self._c.requests += 1
            self._event(now, "requested", node)
            return node

    def request_poweroff(self, node: CloudNode) -> None:
        """Begin drain-before-poweroff for a READY node."""
        with self._lock:
            if node.state != READY:
                raise ValueError(
                    f"can only power off READY nodes, {node.name} is {node.state}"
                )
            now = self.clock.now()
            node.state = DRAINING
            node.t_drain = now
            self._tasks.append(_Task("power_off", node))
            self._event(now, "drain", node)
            # Reroute groups away and retire the node's executors; frames
            # already in flight still land (drain != dead) and are consumed
            # by the surviving fleet before the poweroff task completes.
            self.fabric.begin_drain(node)

    def pick_poweroff(self, can_release) -> CloudNode | None:
        """Best READY node to release, or None if `can_release` vetoes all.

        Smallest node class first (scale-in is a *trim*: shedding a small
        node keeps more of the fleet's bulk capacity than shedding a big
        one), newest within a class — so homogeneous fleets keep the
        classic newest-READY-first behavior.  Never returns a booting or
        draining node — scale-in must not race a cold start or
        double-drain.
        """
        with self._lock:
            ready = [n for n in self.nodes if n.state == READY]
        for node in sorted(ready,
                           key=lambda n: (n.node_class.executors, -n.node_id)):
            if can_release(node):
                return node
        return None

    def fail_node(self, node: CloudNode) -> None:
        """Hard-fail a READY node (chaos ``kill_node``): its endpoint and
        executors die atomically via the fabric, its billing record closes
        (a crashed node stops costing money the instant it dies — the cost
        books must still balance), and the node lands in FAILED so
        :meth:`recover` can requeue replacement capacity."""
        with self._lock:
            if node.state != READY:
                raise ValueError(
                    f"can only kill READY nodes, {node.name} is {node.state}")
            now = self.clock.now()
            node.state = FAILED
            node.t_off = now
            self.ledger.power_off(node, now)
            self._c.nodes_failed += 1
            self.fabric.fail_node(node)
            self._event(now, "node_failed", node,
                        node_seconds=round(now - node.t_power_on, 9))

    def recover(self) -> int:
        """Requeue FAILED nodes for another round of power_on attempts."""
        with self._lock:
            now = self.clock.now()
            n = 0
            for node in self.nodes:
                if node.state == FAILED:
                    node.state = PENDING
                    self._tasks.append(_Task("power_on", node))
                    self._event(now, "recover", node)
                    n += 1
            self._c.recovered += n
            return n

    # ------------------------------------------------------------------
    # fault injection (driven by sim.scenario)

    def inject_provision_failures(self, n: int) -> None:
        """Force the next `n` power_on attempts to fail."""
        with self._lock:
            self._fail_next += max(0, int(n))

    def inject_boot_stall(self, extra_s: float) -> None:
        """Stretch cold starts: extends nodes currently BOOTING, and the
        next boot if nothing is booting yet."""
        extra = max(0.0, float(extra_s))
        with self._lock:
            booting = [n for n in self.nodes if n.state == BOOTING]
            if booting:
                for node in booting:
                    node.t_ready_at += extra
                    self._event(self.clock.now(), "boot_stall", node,
                                extra_s=round(extra, 9))
            else:
                self._stall_extra_s += extra

    # ------------------------------------------------------------------
    # the pending-task pump

    def process_pending_tasks(self) -> None:
        """Advance the queue: attempt power_ons, complete boots, poll drains.

        Called by the ElasticController at the start of every tick (and
        safe to call from tests directly).  All transitions happen here,
        in queue order, on the caller's thread.
        """
        with self._lock:
            now = self.clock.now()
            self._complete_boots_locked(now)
            remaining: deque[_Task] = deque()
            while self._tasks:
                task = self._tasks.popleft()
                if task.not_before > now:
                    remaining.append(task)
                    continue
                if task.kind == "power_on":
                    self._power_on_locked(task, remaining, now)
                elif task.kind == "power_off":
                    self._power_off_locked(task, remaining, now)
            self._tasks = remaining

    def _complete_boots_locked(self, now: float) -> None:
        for node in self.nodes:
            if node.state == BOOTING and now >= node.t_ready_at:
                node.state = READY
                node.t_ready = now
                node.endpoint_idx, node.executor_idxs = self.fabric.attach_node(node)
                self._c.nodes_ready += 1
                self._event(now, "ready", node,
                            cold_start_s=round(now - node.t_power_on, 9))

    def _power_on_locked(self, task: _Task, remaining: deque, now: float) -> None:
        node = task.node
        if node.state != PENDING:  # superseded (e.g. recovered elsewhere)
            return
        failed = False
        if self._fail_next > 0:
            self._fail_next -= 1
            failed = True
        elif node.node_class.provision_fail_prob > 0.0:
            failed = self._rng.random() < node.node_class.provision_fail_prob
        if failed:
            task.attempts += 1
            node.attempts += 1
            self._c.provision_failures += 1
            if task.attempts > self.retry_limit:
                node.state = FAILED
                self._c.nodes_failed += 1
                self._event(now, "provision_failed", node,
                            attempts=task.attempts)
            else:
                task.not_before = now + self.backoff_s * (2 ** (task.attempts - 1))
                remaining.append(task)
                self._c.retries += 1
                self._event(now, "provision_retry", node,
                            attempts=task.attempts,
                            retry_at=round(task.not_before, 9))
            return
        node.state = BOOTING
        node.t_power_on = now
        cold = (node.node_class.cold_start_s
                + node.node_class.cold_start_jitter_s * self._rng.random()
                + self._stall_extra_s)
        self._stall_extra_s = 0.0
        node.t_ready_at = now + cold
        self.ledger.power_on(node, now)
        self._event(now, "power_on", node, boot_s=round(cold, 9))

    def _power_off_locked(self, task: _Task, remaining: deque, now: float) -> None:
        node = task.node
        if node.state != DRAINING:
            return
        if not self.fabric.node_drained(node):
            remaining.append(task)  # poll again next tick
            return
        self.fabric.finish_poweroff(node)
        node.state = OFF
        node.t_off = now
        self.ledger.power_off(node, now)
        self._c.nodes_off += 1
        self._event(now, "power_off", node,
                    node_seconds=round(now - node.t_power_on, 9))

    # ------------------------------------------------------------------
    # teardown / reporting

    def shutdown(self) -> None:
        """Close the books at session teardown: every node that ever
        powered on gets its ledger record closed."""
        with self._lock:
            now = self.clock.now()
            for node in self.nodes:
                if node.state in (BOOTING, READY, DRAINING):
                    node.state = OFF
                    node.t_off = now
                    self.ledger.power_off(node, now)
                    self._c.nodes_off += 1
                    self._event(now, "shutdown_off", node)
            self._tasks.clear()

    def _event(self, t: float, event: str, node: CloudNode, **extra) -> None:
        d = {"event": event, **node.describe()}
        d.update(extra)
        self.events.append((round(t, 9), d))

    def summary(self) -> dict:
        with self._lock:
            states: dict[str, int] = {}
            for node in self.nodes:
                states[node.state] = states.get(node.state, 0) + 1
            c = self._c
            out = {
                "nodes": len(self.nodes),
                "states": dict(sorted(states.items())),
                "requests": c.requests,
                "nodes_ready": c.nodes_ready,
                "provision_failures": c.provision_failures,
                "retries": c.retries,
                "nodes_failed": c.nodes_failed,
                "nodes_off": c.nodes_off,
                "recovered": c.recovered,
                "pending_tasks": len(self._tasks),
            }
        out["ledger"] = self.ledger.summary()
        return out
