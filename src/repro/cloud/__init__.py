"""Cloud capacity plane: node catalog, async provisioner, cost ledger.

ElasticBroker's cloud side is elastic only if capacity can actually come
and go.  This package models the resource layer the controller drives:

- :class:`~repro.cloud.nodes.NodeClass` — a catalog entry (executor
  capacity, cold-start distribution, cost rate, failure probability).
- :class:`~repro.cloud.ledger.CostLedger` — node-seconds accounting from
  ``power_on`` to ``power_off``, per class, next to the engine's
  executor-seconds integral.
- :class:`~repro.cloud.provisioner.CloudProvisioner` — CLUES-style
  pending-task queue with retry/backoff and ``recover``; nodes move
  ``pending -> booting -> ready -> draining -> off``.
- :class:`~repro.cloud.fabric.SessionFabric` — bridges lifecycle
  transitions onto a live Session (dynamic endpoint attach, executor
  add/remove, drain-before-poweroff through broker reroute).

Everything runs on the injectable Clock, so provisioning studies are
deterministic under ``VirtualClock``.
"""

from repro.cloud.fabric import SessionFabric
from repro.cloud.ledger import CostLedger
from repro.cloud.nodes import (
    DEFAULT_CATALOG,
    BOOTING,
    DRAINING,
    FAILED,
    OFF,
    PENDING,
    READY,
    CloudNode,
    NodeClass,
)
from repro.cloud.provisioner import CloudProvisioner

__all__ = [
    "BOOTING",
    "CloudNode",
    "CloudProvisioner",
    "CostLedger",
    "DEFAULT_CATALOG",
    "DRAINING",
    "FAILED",
    "NodeClass",
    "OFF",
    "PENDING",
    "READY",
    "SessionFabric",
]
