"""Node-seconds cost ledger for the cloud capacity plane.

Billing is cloud-honest: a record opens when the node powers on (boot
time is paid for even though no work runs yet) and closes at power-off.
The ledger "closes" when every opened record has been closed — the
provisioning benchmark gates on this, so a node lost across a
drain-before-poweroff scale-in shows up as an open record.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass


@dataclass
class _Record:
    node_id: int
    node_class: str
    cost_rate: float
    t_on: float
    t_off: float | None = None


class CostLedger:
    """Accounts node-seconds per node class from power_on to power_off."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._records: list[_Record] = []

    def power_on(self, node, t: float) -> None:
        with self._lock:
            self._records.append(
                _Record(node.node_id, node.node_class.name,
                        node.node_class.cost_rate, float(t))
            )

    def power_off(self, node, t: float) -> None:
        """Close the node's open record; idempotent if already closed."""
        with self._lock:
            for rec in reversed(self._records):
                if rec.node_id == node.node_id and rec.t_off is None:
                    rec.t_off = float(t)
                    return

    @property
    def open_count(self) -> int:
        with self._lock:
            return sum(1 for r in self._records if r.t_off is None)

    @property
    def closed(self) -> bool:
        """True when every power_on has a matching power_off."""
        return self.open_count == 0

    def node_seconds(self) -> dict[str, float]:
        """Closed node-seconds per class (open records excluded)."""
        with self._lock:
            out: dict[str, float] = {}
            for r in self._records:
                if r.t_off is None:
                    continue
                out[r.node_class] = out.get(r.node_class, 0.0) + (r.t_off - r.t_on)
            return {k: round(v, 9) for k, v in sorted(out.items())}

    def total_node_seconds(self) -> float:
        return round(sum(self.node_seconds().values()), 9)

    def total_cost(self) -> float:
        with self._lock:
            cost = sum(
                r.cost_rate * (r.t_off - r.t_on)
                for r in self._records
                if r.t_off is not None
            )
        return round(cost, 9)

    def attribute(self, shares: dict[str, float]) -> dict[str, float]:
        """Split total closed cost across tenants proportionally to
        ``shares`` (e.g. records analyzed per tenant).  The split is exact:
        the rounded per-tenant costs are adjusted so they sum back to
        :meth:`total_cost` — cost attribution must close like the loss
        ledger does.  All-zero shares split evenly (cost happened; someone
        owns it)."""
        cost = self.total_cost()
        names = sorted(shares)
        if not names:
            return {}
        total_share = float(sum(max(0.0, shares[n]) for n in names))
        out: dict[str, float] = {}
        if total_share <= 0.0:
            frac = 1.0 / len(names)
            out = {n: round(cost * frac, 9) for n in names}
        else:
            out = {n: round(cost * max(0.0, shares[n]) / total_share, 9)
                   for n in names}
        drift = round(cost - sum(out.values()), 9)
        if drift and names:
            out[names[-1]] = round(out[names[-1]] + drift, 9)
        return out

    def summary(self) -> dict:
        with self._lock:
            n = len(self._records)
            open_n = sum(1 for r in self._records if r.t_off is None)
        return {
            "records": n,
            "open": open_n,
            "closed": open_n == 0,
            "node_seconds": self.node_seconds(),
            "total_node_seconds": self.total_node_seconds(),
            "total_cost": self.total_cost(),
        }
