"""Bridges CloudProvisioner lifecycle transitions onto a live Session.

The provisioner stays testable with a fake fabric; this is the real one.
It owns the mechanics of dynamic attach/detach:

- ready:  build a fresh endpoint on the session transport, register it
  with broker/engine/telemetry, and add the node's executors.
- drain:  mark the endpoint draining (senders stop selecting it, but
  in-flight frames still land), reroute every group whose primary points
  at it, and retire the node's executors gracefully.
- off:    once no group targets the endpoint and its queue is empty,
  retire the handle, detach the transport and deregister from the
  failure detector.  Endpoint slots are tombstoned, never removed, so
  indices stay stable.
"""

from __future__ import annotations


class SessionFabric:
    """Duck-typed adapter: the provisioner only sees these four methods."""

    def __init__(self, session) -> None:
        self.session = session

    def attach_node(self, node) -> tuple[int, list[int]]:
        sess = self.session
        idx = sess.attach_endpoint()
        execs = [
            sess.engine.add_executor().idx
            for _ in range(node.node_class.executors)
        ]
        return idx, execs

    def begin_drain(self, node) -> None:
        sess = self.session
        ep = sess.endpoints[node.endpoint_idx]
        ep.handle.begin_drain()
        sess.broker.reroute_from_endpoint(node.endpoint_idx)
        for ex_idx in node.executor_idxs:
            sess.engine.remove_executor(ex_idx)

    def fail_node(self, node) -> None:
        """Hard node death (no drain): the endpoint starts refusing pushes
        — in-flight frames error and reroute/replay onto survivors — and
        the node's executors die with their queues (the engine reassigns
        their runs).  One atomic step so no window exists where the dead
        node's executors keep pulling from a dead endpoint."""
        sess = self.session
        ep = sess.endpoints[node.endpoint_idx]
        ep.handle.fail()
        sess.broker.reroute_from_endpoint(node.endpoint_idx)
        for ex_idx in node.executor_idxs:
            sess.engine.kill_executor(ex_idx)

    def node_drained(self, node) -> bool:
        sess = self.session
        ep = sess.endpoints[node.endpoint_idx]
        return (
            sess.broker.groups_on_endpoint(node.endpoint_idx) == 0
            and ep.handle.pending() == 0
        )

    def finish_poweroff(self, node) -> None:
        sess = self.session
        ep = sess.endpoints[node.endpoint_idx]
        ep.handle.retire()
        ep.detach()
        detector = getattr(sess, "detector", None)
        if detector is not None:
            detector.remove(ep.handle.name)
