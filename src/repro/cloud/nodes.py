"""Node classes and node lifecycle state for the cloud capacity plane.

A :class:`NodeClass` is a catalog entry describing what one cloud node
gives you (executor slots), what it costs (per node-second), and how it
behaves while provisioning (cold-start distribution, failure
probability).  A :class:`CloudNode` is one provisioned instance moving
through the lifecycle::

    pending -> booting -> ready -> draining -> off
                  \\-> failed (retry budget exhausted; `recover` requeues)
"""

from __future__ import annotations

from dataclasses import dataclass, field

# Lifecycle states (strings so they serialize directly into traces).
PENDING = "pending"
BOOTING = "booting"
READY = "ready"
DRAINING = "draining"
OFF = "off"
FAILED = "failed"

STATES = (PENDING, BOOTING, READY, DRAINING, OFF, FAILED)


@dataclass(frozen=True)
class NodeClass:
    """Catalog entry: capacity, cold-start distribution, cost, failure."""

    name: str
    executors: int = 1
    cold_start_s: float = 1.0       # deterministic floor of the cold start
    cold_start_jitter_s: float = 0.0  # uniform extra on top, seeded per run
    cost_rate: float = 1.0          # cost units per node-second
    provision_fail_prob: float = 0.0  # chance one power_on attempt fails

    def validate(self) -> None:
        if not self.name:
            raise ValueError("NodeClass.name must be non-empty")
        if self.executors < 1:
            raise ValueError("NodeClass.executors must be >= 1")
        if self.cold_start_s < 0 or self.cold_start_jitter_s < 0:
            raise ValueError("cold-start times must be >= 0")
        if self.cost_rate < 0:
            raise ValueError("cost_rate must be >= 0")
        if not 0.0 <= self.provision_fail_prob < 1.0:
            raise ValueError("provision_fail_prob must be in [0, 1)")

    def expected_ready_s(self) -> float:
        """Worst-case cold start: the horizon a predictive policy must beat."""
        return self.cold_start_s + self.cold_start_jitter_s


DEFAULT_CATALOG: dict[str, NodeClass] = {
    c.name: c
    for c in (
        NodeClass("small", executors=1, cold_start_s=0.6,
                  cold_start_jitter_s=0.2, cost_rate=1.0),
        NodeClass("standard", executors=2, cold_start_s=1.2,
                  cold_start_jitter_s=0.4, cost_rate=1.8),
        NodeClass("large", executors=4, cold_start_s=2.5,
                  cold_start_jitter_s=0.8, cost_rate=3.2),
    )
}
for _c in DEFAULT_CATALOG.values():
    _c.validate()
del _c


@dataclass
class CloudNode:
    """One provisioned instance of a NodeClass."""

    node_id: int
    node_class: NodeClass
    state: str = PENDING
    t_requested: float = 0.0
    t_power_on: float | None = None   # boot started (billing opens here)
    t_ready_at: float | None = None   # boot deadline while BOOTING
    t_ready: float | None = None
    t_drain: float | None = None
    t_off: float | None = None
    attempts: int = 0                 # power_on attempts so far
    endpoint_idx: int | None = None   # session endpoint slot once attached
    executor_idxs: list[int] = field(default_factory=list)

    @property
    def name(self) -> str:
        return f"node-{self.node_id}-{self.node_class.name}"

    def describe(self) -> dict:
        return {
            "node": self.name,
            "class": self.node_class.name,
            "state": self.state,
            "executors": self.node_class.executors,
            "endpoint_idx": self.endpoint_idx,
        }
