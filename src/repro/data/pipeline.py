"""Deterministic, sharded, resumable synthetic token pipeline.

Step-indexed PRNG => batch(step) is a pure function: restart-from-checkpoint
is bitwise deterministic, and every data-parallel host can materialize exactly
its addressable shard without coordination (the production pattern for
fault-tolerant input pipelines).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 17
    # markov-ish synthetic text: tokens depend on previous token (so the LM
    # has learnable structure and loss decreases measurably)
    order_bias: float = 0.85


class TokenPipeline:
    def __init__(self, cfg: ArchConfig, batch: int, seq: int,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.data_cfg = data_cfg

    def batch_at(self, step: int) -> dict:
        """Pure function of (seed, step)."""
        key = jax.random.key(self.data_cfg.seed + step)
        V = self.cfg.vocab_size
        k1, k2 = jax.random.split(key)
        base = jax.random.randint(k1, (self.batch, self.seq), 0, V)
        # inject sequential structure: with p=order_bias, token = prev+1 mod V
        keep = jax.random.bernoulli(k2, self.data_cfg.order_bias,
                                    (self.batch, self.seq))
        idx = jnp.arange(self.seq)[None, :]
        structured = (base[:, :1] + idx) % V
        tokens = jnp.where(keep, structured, base).astype(jnp.int32)
        labels = jnp.concatenate(
            [tokens[:, 1:], -jnp.ones((self.batch, 1), jnp.int32)], axis=1)
        out = {"labels": labels}
        if self.cfg.frontend == "audio":
            ekey = jax.random.key(self.data_cfg.seed * 7 + step)
            out["frames"] = (jax.random.normal(
                ekey, (self.batch, self.seq, self.cfg.d_model)) * 0.02
            ).astype(self.cfg.dtype)
        else:
            out["tokens"] = tokens
        if self.cfg.frontend == "vision":
            vkey = jax.random.key(self.data_cfg.seed * 13 + step)
            out["frontend"] = (jax.random.normal(
                vkey, (self.batch, self.cfg.n_frontend_tokens,
                       self.cfg.d_model)) * 0.02).astype(self.cfg.dtype)
        return out
