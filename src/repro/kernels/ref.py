"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  scale: float | None = None):
    """Naive full-matrix attention.  q: (B,S,H,D); k/v: (B,T,H,D) (pre-expanded
    KV heads).  Returns (B,S,H,D) in q.dtype."""
    B, S, H, D = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(F32), k.astype(F32)) * scale
    qpos = jnp.arange(S)[:, None]
    tpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= tpos <= qpos
    if window is not None:
        mask &= tpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p, v.astype(F32))
    return o.astype(q.dtype)


def gram_ref(x: jax.Array, g: jax.Array | None = None) -> jax.Array:
    """G += XᵀX.  x: (n, d) snapshot block; g: (d, d) running Gram or None."""
    upd = jnp.dot(x.T.astype(F32), x.astype(F32))
    return upd if g is None else g.astype(F32) + upd


def gram_pair_ref(x: jax.Array, y: jax.Array, g: jax.Array | None = None,
                  a: jax.Array | None = None):
    """Fused online-DMD update: (G += XᵀX, A += YᵀX).  x, y: (n, d) paired
    snapshot blocks; g, a: (d, d) running Gram / cross-Gram or None."""
    xf, yf = x.astype(F32), y.astype(F32)
    gu = jnp.dot(xf.T, xf)
    au = jnp.dot(yf.T, xf)
    return (gu if g is None else g.astype(F32) + gu,
            au if a is None else a.astype(F32) + au)


def ssd_intra_ref(cb, cum, bmat, xdt):
    """Oracle for kernels/ssd.py — the formulas from models/mamba.py.

    cb: (G,L,L); cum: (G,L,H); bmat: (G,L,N); xdt: (G,L,H,P)."""
    decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (G,i,j,H)
    L = cb.shape[1]
    mask = jnp.arange(L)[:, None] >= jnp.arange(L)[None, :]
    m = cb[..., None] * decay * mask[None, :, :, None]
    y = jnp.einsum("gijh,gjhp->gihp", m, xdt)
    seg = jnp.exp(cum[:, -1:, :] - cum)                        # (G,L,H)
    s = jnp.einsum("gjn,gjh,gjhp->ghnp", bmat, seg, xdt)
    return y, s


def quant_ref(x: jax.Array):
    """Blockwise int8 over rows.  x: (nb, q) f32 -> (int8 (nb,q), f32 (nb,))."""
    scale = (jnp.maximum(jnp.max(jnp.abs(x.astype(F32)), axis=1), 1e-20)
             * (1.0 / 127.0))
    data = jnp.clip(jnp.round(x.astype(F32) / scale[:, None]), -127, 127)
    return data.astype(jnp.int8), scale


def dequant_ref(data: jax.Array, scale: jax.Array) -> jax.Array:
    return data.astype(F32) * scale[:, None]


def window_eigs_ref(snaps: jax.Array, n_valid: int, rank: int) -> jax.Array:
    """Oracle for ``analysis.dmd._masked_window_eigs``: SVD-route exact DMD
    on the *valid slice* of a zero-padded (d, m) pane, eigenvalues sorted
    by descending magnitude.  Host-side only (``n_valid`` must be concrete;
    the masked solve exists precisely to avoid this dynamic slice)."""
    X = snaps[:, : n_valid - 1].astype(F32)
    Y = snaps[:, 1:n_valid].astype(F32)
    U, S, Vt = jnp.linalg.svd(X, full_matrices=False)
    r = min(rank, S.shape[0])
    U, S, Vt = U[:, :r], S[:r], Vt[:r]
    good = S > 1e-7 * jnp.maximum(S[0], 1e-30)
    Sinv = jnp.where(good, 1.0 / jnp.maximum(S, 1e-30), 0.0)
    Atilde = (U.T @ Y @ Vt.T * Sinv[None, :]) * good[:, None] * good[None, :]
    eigs = jnp.linalg.eigvals(Atilde)
    return eigs[jnp.argsort(-jnp.abs(eigs))]
