"""Pallas TPU Gram-accumulation kernel: G += XᵀX over snapshot blocks.

The streaming-DMD hot loop (analysis/dmd.py): every micro-batch of n
snapshots rank-updates the d x d Gram matrix.  Tiled (bd x bd) output blocks
with the snapshot axis innermost in the grid; an f32 VMEM scratch accumulates
across n-blocks, and the running G tile is added once at the end — one HBM
read + one write of G per call regardless of n.

MXU alignment: bd=128, bn=128 tiles (bf16/f32 both land on 128-lane vregs).
VMEM per step: 2*(bn*bd) + bd*bd + bd*bd floats ≈ 256 KB at defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _gram_kernel(xi_ref, xj_ref, g_ref, out_ref, acc_scr, *, n_n: int):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    xi = xi_ref[...].astype(F32)                       # (bn, bd)
    xj = xj_ref[...].astype(F32)                       # (bn, bd)
    acc_scr[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(ni == n_n - 1)
    def _finish():
        out_ref[...] = (g_ref[...].astype(F32) + acc_scr[...]).astype(out_ref.dtype)


def gram_accumulate(x: jax.Array, g: jax.Array, *, block_d: int = 128,
                    block_n: int = 128, interpret: bool = False) -> jax.Array:
    """x: (n, d) snapshots; g: (d, d) running Gram.  Returns g + xᵀx."""
    n, d = x.shape
    block_d = min(block_d, d)
    block_n = min(block_n, n)
    nd = pl.cdiv(d, block_d)
    nn = pl.cdiv(n, block_n)
    dp, np_ = nd * block_d, nn * block_n
    if dp != d or np_ != n:
        x = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
        g = jnp.pad(g, ((0, dp - d), (0, dp - d)))

    kernel = functools.partial(_gram_kernel, n_n=nn)
    out = pl.pallas_call(
        kernel,
        grid=(nd, nd, nn),
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, dp), g.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, block_d), F32)],
        interpret=interpret,
    )(x, x, g)
    return out[:d, :d]
