"""Pallas TPU Gram-accumulation kernels over snapshot blocks.

Two entry points serve the streaming-DMD hot loop (analysis/dmd.py):

* ``gram_accumulate(x, g)`` — G += XᵀX for a single snapshot block.
* ``gram_pair_accumulate(x, y, g, a)`` — the **fused** online-DMD update
  G += XᵀX, A += YᵀX in one ``pallas_call``.  X tiles are shared between
  both products (the (k, j) tile feeds the MXU twice), two f32 VMEM
  scratch accumulators run across the n-blocks, and the running G/A tiles
  are each read+written exactly once per call regardless of n.  This is
  what ``StreamingDMD.update_batch`` dispatches per micro-batch on TPU —
  one device call for the whole batch instead of two matmuls per snapshot.

Tiled (bd x bd) output blocks with the snapshot axis innermost in the grid.
MXU alignment: bd=128, bn=128 tiles (bf16/f32 both land on 128-lane vregs).
VMEM per step (fused): 3 input n-tiles + 4 d-tiles (g/a in+out) + 2 f32
scratch accumulators = 3*(bn*bd) + 6*(bd*bd) floats ≈ 576 KB at defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _gram_kernel(xi_ref, xj_ref, g_ref, out_ref, acc_scr, *, n_n: int):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    xi = xi_ref[...].astype(F32)                       # (bn, bd)
    xj = xj_ref[...].astype(F32)                       # (bn, bd)
    acc_scr[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(ni == n_n - 1)
    def _finish():
        out_ref[...] = (g_ref[...].astype(F32) + acc_scr[...]).astype(out_ref.dtype)


def gram_accumulate(x: jax.Array, g: jax.Array, *, block_d: int = 128,
                    block_n: int = 128, interpret: bool = False) -> jax.Array:
    """x: (n, d) snapshots; g: (d, d) running Gram.  Returns g + xᵀx."""
    n, d = x.shape
    block_d = min(block_d, d)
    block_n = min(block_n, n)
    nd = pl.cdiv(d, block_d)
    nn = pl.cdiv(n, block_n)
    dp, np_ = nd * block_d, nn * block_n
    if dp != d or np_ != n:
        x = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
        g = jnp.pad(g, ((0, dp - d), (0, dp - d)))

    kernel = functools.partial(_gram_kernel, n_n=nn)
    out = pl.pallas_call(
        kernel,
        grid=(nd, nd, nn),
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, dp), g.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, block_d), F32)],
        interpret=interpret,
    )(x, x, g)
    return out[:d, :d]


def _gram_pair_kernel(xi_ref, xj_ref, yi_ref, g_ref, a_ref, g_out, a_out,
                      g_acc, a_acc, *, n_n: int):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        a_acc[...] = jnp.zeros_like(a_acc)

    xi = xi_ref[...].astype(F32)                       # (bn, bd) X cols-i
    xj = xj_ref[...].astype(F32)                       # (bn, bd) X cols-j
    yi = yi_ref[...].astype(F32)                       # (bn, bd) Y cols-i
    dims = (((0,), (0,)), ((), ()))
    g_acc[...] += jax.lax.dot_general(xi, xj, dims, preferred_element_type=F32)
    a_acc[...] += jax.lax.dot_general(yi, xj, dims, preferred_element_type=F32)

    @pl.when(ni == n_n - 1)
    def _finish():
        g_out[...] = (g_ref[...].astype(F32) + g_acc[...]).astype(g_out.dtype)
        a_out[...] = (a_ref[...].astype(F32) + a_acc[...]).astype(a_out.dtype)


def gram_pair_accumulate(x: jax.Array, y: jax.Array, g: jax.Array,
                         a: jax.Array, *, block_d: int = 128,
                         block_n: int = 128, interpret: bool = False):
    """Fused online-DMD update: returns (g + xᵀx, a + yᵀx).

    x, y: (n, d) paired snapshot blocks (rows are (x_t, x_{t+1}) pairs);
    g, a: (d, d) running Gram / cross-Gram.  The X tiles are loaded once per
    grid step and feed both MXU products."""
    n, d = x.shape
    assert y.shape == x.shape, (x.shape, y.shape)
    block_d = min(block_d, d)
    block_n = min(block_n, n)
    nd = pl.cdiv(d, block_d)
    nn = pl.cdiv(n, block_n)
    dp, np_ = nd * block_d, nn * block_n
    if dp != d or np_ != n:
        x = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
        y = jnp.pad(y, ((0, np_ - n), (0, dp - d)))
        g = jnp.pad(g, ((0, dp - d), (0, dp - d)))
        a = jnp.pad(a, ((0, dp - d), (0, dp - d)))

    kernel = functools.partial(_gram_pair_kernel, n_n=nn)
    out_g, out_a = pl.pallas_call(
        kernel,
        grid=(nd, nd, nn),
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
            pl.BlockSpec((block_d, block_d), lambda i, j, k: (i, j)),
        ],
        out_shape=[jax.ShapeDtypeStruct((dp, dp), g.dtype),
                   jax.ShapeDtypeStruct((dp, dp), a.dtype)],
        scratch_shapes=[pltpu.VMEM((block_d, block_d), F32),
                        pltpu.VMEM((block_d, block_d), F32)],
        interpret=interpret,
    )(x, x, y, g, a)
    return out_g[:d, :d], out_a[:d, :d]
