"""Pallas TPU flash attention — blocked online-softmax with causal skipping.

Layout: q/k/v as (B, H, S, D); grid (B, H, nq, nk) with the kv-block axis
innermost.  Per (b, h, qi): f32 scratch (m, l, acc) lives in VMEM across the
nk iterations; fully-masked kv blocks are *skipped* (@pl.when), so causal
attention does ~half the FLOPs of the masked-dense portable path and sliding
windows do ~window/S of it — this is the kernel's roofline win over
``models.layers.flash_attention`` (see EXPERIMENTS.md §Perf).

Block sizes default to 128 (MXU-aligned: lanes=128, bf16 sublanes=16).
VMEM working set per step ≈ (bq*D + bk*D + bq*bk + bq*D) * 4B — for
bq=bk=128, D=128: ~260 KB, comfortably inside the ~16 MB/core budget with
double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, n_k: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # reachability: skip blocks fully outside the causal/window band
    reachable = True
    if causal:
        reachable = jnp.logical_and(
            k_start <= q_start + block_q - 1, True)
    if window is not None:
        reachable = jnp.logical_and(
            reachable, k_start + block_k - 1 > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(F32) * scale            # (bq, D)
        k = k_ref[0, 0].astype(F32)                    # (bk, D)
        v = v_ref[0, 0].astype(F32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_blk = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(m_new[:, None] > NEG_INF / 2, p, 0.0)
        alpha = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=F32))
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: int | None = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q/k/v: (B, H, S, D), KV heads pre-expanded.  Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    T = k.shape[2]
    scale = 1.0 / (D ** 0.5)
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    nq = pl.cdiv(S, block_q)
    nk = pl.cdiv(T, block_k)
    Sp, Tp = nq * block_q, nk * block_k
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=nk, seq_len=T)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q, D), F32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]
