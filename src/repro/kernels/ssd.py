"""Pallas TPU kernel for the Mamba2/SSD intra-chunk recurrence.

The chunked SSD algorithm (models/mamba.py) expands the within-chunk
recurrence into masked decay "attention":

    M[i,j,h] = (C_i·B_j) * exp(cum_i[h] - cum_j[h]) * [i >= j]
    y[i,h]   = sum_j M[i,j,h] * xdt[j,h]           (intra-chunk output)
    S[h]     = sum_j B_j ⊗ (exp(cum_L - cum_j) xdt[j,h])   (chunk state)

The (L,L,H) decay/M tensors are the HBM hot spot of the portable path
(marked ``kernel_ssd_intra``); here they live in VMEM only.  Grid is
(batch*chunks, heads); CB = C·Bᵀ is a clean standalone MXU matmul and is
computed outside (it is head-independent — recomputing it per head would be
H× wasted FLOPs).

VMEM per step (L=128, N=128, P=64, f32): CB 64 KB + M 64 KB + xdt 32 KB +
outputs ≈ 200 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _ssd_intra_kernel(cb_ref, cum_ref, b_ref, xdt_ref, y_ref, s_ref, *,
                      L: int, N: int, P: int):
    cb = cb_ref[0].astype(F32)                     # (L, L)
    cum = cum_ref[0, :, 0].astype(F32)             # (L,)
    bmat = b_ref[0].astype(F32)                    # (L, N)
    xdt = xdt_ref[0, :, 0].astype(F32)             # (L, P)

    decay = jnp.exp(cum[:, None] - cum[None, :])   # (L, L)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    m = jnp.where(mask, cb * decay, 0.0)
    y_ref[0, :, 0] = jax.lax.dot_general(
        m, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=F32).astype(y_ref.dtype)

    seg = jnp.exp(cum[-1] - cum)                   # (L,)
    s_ref[0, 0] = jax.lax.dot_general(
        bmat * seg[:, None], xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=F32).astype(s_ref.dtype)  # (N, P)


def ssd_intra_chunk(cb: jax.Array, cum: jax.Array, bmat: jax.Array,
                    xdt: jax.Array, *, interpret: bool = False):
    """cb: (G, L, L) = C·Bᵀ per (batch*chunk) group; cum: (G, L, H);
    bmat: (G, L, N); xdt: (G, L, H, P).

    Returns (y_intra (G, L, H, P), states (G, H, N, P))."""
    G, L, H = cum.shape
    N = bmat.shape[-1]
    P = xdt.shape[-1]
    kernel = functools.partial(_ssd_intra_kernel, L=L, N=N, P=P)
    y, s = pl.pallas_call(
        kernel,
        grid=(G, H),
        in_specs=[
            pl.BlockSpec((1, L, L), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, L, 1), lambda g, h: (g, 0, h)),
            pl.BlockSpec((1, L, N), lambda g, h: (g, 0, 0)),
            pl.BlockSpec((1, L, 1, P), lambda g, h: (g, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda g, h: (g, 0, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda g, h: (g, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, L, H, P), F32),
            jax.ShapeDtypeStruct((G, H, N, P), F32),
        ],
        interpret=interpret,
    )(cb, cum, bmat, xdt)
    return y, s
