"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; on the CPU host (this container, CI)
they execute in ``interpret=True`` mode so every test exercises the *same*
kernel bodies.  ``attention`` also handles the model-side layout:
(B,S,H,D) <-> (B,H,S,D) and GQA head expansion.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash, gram, quant, ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              block_q: int = 128, block_k: int = 128):
    """Flash attention, model layout: q (B,S,H,D), k/v (B,T,Kh,D), Kh | H."""
    H, Kh = q.shape[2], k.shape[2]
    if Kh != H:
        k = jnp.repeat(k, H // Kh, axis=2)
        v = jnp.repeat(v, H // Kh, axis=2)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    ot = flash.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                    block_q=block_q, block_k=block_k,
                                    interpret=_interpret())
    return jnp.transpose(ot, (0, 2, 1, 3))


@partial(jax.jit, static_argnames=("block_d", "block_n"))
def gram_accumulate(x, g, *, block_d: int = 128, block_n: int = 128):
    """G += XᵀX.  x: (n, d); g: (d, d)."""
    return gram.gram_accumulate(x, g, block_d=block_d, block_n=block_n,
                                interpret=_interpret())


@partial(jax.jit, static_argnames=("block_d", "block_n"))
def gram_pair_accumulate(x, y, g, a, *, block_d: int = 128,
                         block_n: int = 128):
    """Fused G += XᵀX, A += YᵀX in one kernel.  x, y: (n, d); g, a: (d, d)."""
    return gram.gram_pair_accumulate(x, y, g, a, block_d=block_d,
                                     block_n=block_n, interpret=_interpret())


@partial(jax.jit, static_argnames=("block_rows",))
def quantize(x, *, block_rows: int = 256):
    return quant.quantize(x, block_rows=block_rows, interpret=_interpret())


@partial(jax.jit, static_argnames=("block_rows",))
def dequantize(q, s, *, block_rows: int = 256):
    return quant.dequantize(q, s, block_rows=block_rows,
                            interpret=_interpret())


@jax.jit
def ssd_intra_chunk(cb, cum, bmat, xdt):
    """Mamba2/SSD intra-chunk masked-decay matmuls, VMEM-resident."""
    return ssd.ssd_intra_chunk(cb, cum, bmat, xdt, interpret=_interpret())
