"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; on the CPU host (this container, CI)
they execute in ``interpret=True`` mode so every test exercises the *same*
kernel bodies.  ``attention`` also handles the model-side layout:
(B,S,H,D) <-> (B,H,S,D) and GQA head expansion.

Block sizes resolve through a per-backend **config registry** so native
TPU/GPU deployments can retune tiling without touching call sites:
``get_block_config(op)`` returns the active sizes, ``set_block_config``
overrides them, and ``autotune(op, candidates, make_args)`` times the
candidates on the current backend and installs the winner.  Explicit
keyword arguments at a call site always beat the registry.  Interpret
mode stays the CI oracle — autotune on CPU just picks among interpreted
runs, which is why CI pins the defaults instead of autotuning.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash, gram, quant, ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# --- block-size config registry -------------------------------------------
# op -> {param: size}.  Defaults match the shipped kernels; native backends
# override via set_block_config / autotune at process start.
_DEFAULT_BLOCKS: dict[str, dict[str, int]] = {
    "gram_pair": {"block_d": 128, "block_n": 128},
    "quant": {"block_rows": 256},
    "attention": {"block_q": 128, "block_k": 128},
}
_BLOCKS: dict[str, dict[str, int]] = {k: dict(v)
                                      for k, v in _DEFAULT_BLOCKS.items()}


def get_block_config(op: str) -> dict[str, int]:
    """Active block sizes for ``op`` ('gram_pair', 'quant', 'attention')."""
    return dict(_BLOCKS[op])


def set_block_config(op: str, **sizes: int) -> None:
    """Override block sizes for ``op`` (unknown params rejected).  Pass no
    sizes to reset the op to its shipped defaults."""
    if op not in _BLOCKS:
        raise KeyError(f"unknown op {op!r}; have {sorted(_BLOCKS)}")
    if not sizes:
        _BLOCKS[op] = dict(_DEFAULT_BLOCKS[op])
        return
    bad = set(sizes) - set(_BLOCKS[op])
    if bad:
        raise KeyError(f"unknown block params {sorted(bad)} for op {op!r}")
    _BLOCKS[op].update({k: int(v) for k, v in sizes.items()})


def autotune(op: str, candidates, make_args, *, repeats: int = 3) -> dict:
    """Time ``candidates`` (iterable of block-size dicts) for ``op`` on the
    current backend and install the fastest via ``set_block_config``.

    ``make_args`` builds the positional argument tuple for one call (fresh
    per candidate, so donation-style aliasing can't skew timings).  Returns
    ``{"op", "best", "timings_us"}``.  On CPU this times interpret-mode
    runs — useful for smoke-testing the hook, not for picking TPU tiles.
    """
    runner = {"gram_pair": gram_pair_accumulate,
              "quant": quantize,
              "attention": attention}[op]
    timings: list[tuple[float, dict]] = []
    for cand in candidates:
        args = make_args()
        jax.block_until_ready(runner(*args, **cand))   # compile + warm
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = runner(*args, **cand)
        jax.block_until_ready(out)
        timings.append(((time.perf_counter() - t0) / repeats * 1e6,
                        dict(cand)))
    timings.sort(key=lambda t: t[0])
    best = timings[0][1]
    set_block_config(op, **best)
    return {"op": op, "best": best,
            "timings_us": [{"us": round(us, 1), **c} for us, c in timings]}


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def _attention(q, k, v, *, causal, window, block_q, block_k):
    H, Kh = q.shape[2], k.shape[2]
    if Kh != H:
        k = jnp.repeat(k, H // Kh, axis=2)
        v = jnp.repeat(v, H // Kh, axis=2)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    ot = flash.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                    block_q=block_q, block_k=block_k,
                                    interpret=_interpret())
    return jnp.transpose(ot, (0, 2, 1, 3))


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              block_q: int | None = None, block_k: int | None = None):
    """Flash attention, model layout: q (B,S,H,D), k/v (B,T,Kh,D), Kh | H."""
    cfg = _BLOCKS["attention"]
    return _attention(q, k, v, causal=causal, window=window,
                      block_q=block_q or cfg["block_q"],
                      block_k=block_k or cfg["block_k"])


@partial(jax.jit, static_argnames=("block_d", "block_n"))
def gram_accumulate(x, g, *, block_d: int = 128, block_n: int = 128):
    """G += XᵀX.  x: (n, d); g: (d, d)."""
    return gram.gram_accumulate(x, g, block_d=block_d, block_n=block_n,
                                interpret=_interpret())


def _gram_pair_raw(x, y, g, a, *, block_d, block_n):
    return gram.gram_pair_accumulate(x, y, g, a, block_d=block_d,
                                     block_n=block_n, interpret=_interpret())


_gram_pair_jit = jax.jit(_gram_pair_raw,
                         static_argnames=("block_d", "block_n"))
# donated flavor for the streaming hot loop: g/a buffers are reused for
# the outputs, so the per-micro-batch (d, d) pair allocation disappears.
# Callers must rebind to the results and drop the donated references
# (StreamingDMD does).
_gram_pair_jit_donated = jax.jit(_gram_pair_raw,
                                 static_argnames=("block_d", "block_n"),
                                 donate_argnums=(2, 3))


def gram_pair_accumulate(x, y, g, a, *, block_d: int | None = None,
                         block_n: int | None = None):
    """Fused G += XᵀX, A += YᵀX in one kernel.  x, y: (n, d); g, a: (d, d)."""
    cfg = _BLOCKS["gram_pair"]
    return _gram_pair_jit(x, y, g, a, block_d=block_d or cfg["block_d"],
                          block_n=block_n or cfg["block_n"])


def gram_pair_accumulate_donated(x, y, g, a, *, block_d: int | None = None,
                                 block_n: int | None = None):
    """``gram_pair_accumulate`` with g/a donated (in-place accumulate)."""
    cfg = _BLOCKS["gram_pair"]
    return _gram_pair_jit_donated(x, y, g, a,
                                  block_d=block_d or cfg["block_d"],
                                  block_n=block_n or cfg["block_n"])


@partial(jax.jit, static_argnames=("block_rows",))
def _quantize(x, *, block_rows):
    return quant.quantize(x, block_rows=block_rows, interpret=_interpret())


@partial(jax.jit, static_argnames=("block_rows",))
def _dequantize(q, s, *, block_rows):
    return quant.dequantize(q, s, block_rows=block_rows,
                            interpret=_interpret())


def quantize(x, *, block_rows: int | None = None):
    return _quantize(x, block_rows=block_rows
                     or _BLOCKS["quant"]["block_rows"])


def dequantize(q, s, *, block_rows: int | None = None):
    return _dequantize(q, s, block_rows=block_rows
                       or _BLOCKS["quant"]["block_rows"])


@jax.jit
def ssd_intra_chunk(cb, cum, bmat, xdt):
    """Mamba2/SSD intra-chunk masked-decay matmuls, VMEM-resident."""
    return ssd.ssd_intra_chunk(cb, cum, bmat, xdt, interpret=_interpret())
