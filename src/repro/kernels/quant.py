"""Pallas TPU int8 blockwise quantize/dequantize.

One codec, three users: stream-record compression (core.records), cross-pod
gradient compression, and 8-bit optimizer moments (optim.adamw) — int8 data +
one f32 scale per row of Q elements.  Row-parallel grid; each kernel step
reduces |x| over its rows (VPU), scales, rounds, and writes int8 (the cast is
the memory win: 4x less HBM traffic on every moment read/write).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(F32)                       # (bn, Q)
    # explicit multiply by 1/127: XLA rewrites division-by-constant into
    # multiply-by-reciprocal anyway, and the host codec (core.records)
    # must share the exact form for byte-identical wire frames
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1), 1e-20) * (1.0 / 127.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(F32) * s_ref[...][:, None]


def quantize(x: jax.Array, *, block_rows: int = 256,
             interpret: bool = False):
    """x: (nb, Q) f32 -> (int8 (nb, Q), f32 scales (nb,))."""
    nb, Q = x.shape
    block_rows = min(block_rows, nb)
    g = pl.cdiv(nb, block_rows)
    nbp = g * block_rows
    if nbp != nb:
        x = jnp.pad(x, ((0, nbp - nb), (0, 0)))
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(g,),
        in_specs=[pl.BlockSpec((block_rows, Q), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, Q), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, Q), jnp.int8),
            jax.ShapeDtypeStruct((nbp,), F32),
        ],
        interpret=interpret,
    )(x)
    return q[:nb], s[:nb]


def dequantize(q: jax.Array, s: jax.Array, *, block_rows: int = 256,
               interpret: bool = False) -> jax.Array:
    nb, Q = q.shape
    block_rows = min(block_rows, nb)
    g = pl.cdiv(nb, block_rows)
    nbp = g * block_rows
    if nbp != nb:
        q = jnp.pad(q, ((0, nbp - nb), (0, 0)))
        s = jnp.pad(s, ((0, nbp - nb),))
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((block_rows, Q), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows, Q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, Q), F32),
        interpret=interpret,
    )(q, s)
    return x[:nb]
