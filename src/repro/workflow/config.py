"""One validated config for the whole HPC→Cloud workflow.

The seed wired three separately-configured knob sets at every call site:
``BrokerConfig`` (wire/queue), ``make_endpoints`` arguments (bandwidth,
port), and the engine's constructor (``trigger_interval``/``min_batch``/
``n_executors``).  :class:`WorkflowConfig` unifies them into a single
declarative description of the deployment — the paper's
producers : endpoints : executors topology plus every tuning knob — with a
lossless ``to_dict``/``from_dict`` round-trip so deployments can live in
JSON/YAML next to the job script.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, fields

from repro.core.broker import BrokerConfig
from repro.core.grouping import GroupPlan, plan_groups
from repro.runtime.controller import ElasticityConfig
from repro.tenancy import TenantRegistry, TenantSpec

_BACKPRESSURE = ("block", "drop_oldest", "sample")
_COMPRESS = ("none", "zstd", "int8", "int8+zstd")
_TRANSPORT = ("inprocess", "loopback")
_CLOCK = ("wall", "virtual")
_DELIVERY = ("at-most-once", "exactly-once")


@dataclass(frozen=True)
class WorkflowConfig:
    # -- topology (paper Fig 1: producers -> groups -> endpoints) ---------
    n_producers: int = 4
    n_groups: int | None = None        # None: bandwidth planner (plan_groups)
    executors_per_group: int = 4
    # -- endpoints --------------------------------------------------------
    n_endpoints: int | None = None     # None: one per group
    inbound_bw: float | None = None    # bytes/s per endpoint, None = unmetered
    base_port: int = 6379
    transport: str = "inprocess"       # inprocess | loopback
    # -- broker (wire + queueing) -----------------------------------------
    compress: str = "int8+zstd"
    queue_capacity: int = 256
    backpressure: str = "drop_oldest"
    sample_keep: int = 2
    flush_timeout_s: float = 10.0
    retry_limit: int = 3
    max_batch_records: int = 32
    delta_encode: bool = False
    # -- delivery guarantee -----------------------------------------------
    # "exactly-once" puts a bounded write-ahead segment (runtime.wal) under
    # every group sender: records are logged before they ship, endpoints
    # dedupe replayed frames on their seq range, and unacked tails replay
    # across endpoint failover, broker restarts (Session.restart_broker)
    # and whole-session crashes (Session.checkpoint / Session.restore).
    # Requires backpressure="block" (a drop policy contradicts the
    # guarantee) and delta_encode=False (replayed frames must decode
    # independently of their neighbors).
    delivery: str = "at-most-once"     # at-most-once | exactly-once
    wal_capacity_bytes: int = 16 << 20 # per-group WAL byte bound
    # -- sharded data plane ------------------------------------------------
    # broker_shards > 1 splits the broker into that many group-owning
    # shards behind a thin routing layer (group g -> shard g % n): per-shard
    # endpoint rings, WAL segments, sender stats, and TelemetrySnapshot
    # rows.  Clamped to the effective group count.  1 = the paper's single
    # fan-in.
    broker_shards: int = 1
    # shuffle_partitions re-partitions records ACROSS producer streams at
    # dispatch when the attached plan compiles to a shuffle edge (source
    # KeyBy at record granularity): micro-batches become key partitions
    # (part:NNNN via the stable crc32 partition_of), owned sticky by
    # executors, with per-partition ordering tickets.  None keeps
    # producer-stream partitioning.
    shuffle_partitions: int | None = None
    # Directory for a disk-backed WAL (runtime.wal.FileWalStore): segments
    # sync on every checkpoint and at close, and a Session built over the
    # same directory adopts the surviving log — exactly-once across host
    # crashes, not just in-process ones.  None keeps the WAL memory-only.
    wal_dir: str | None = None
    # -- engine (micro-batching + executors) ------------------------------
    trigger_interval: float = 1.0
    min_batch: int = 2
    n_executors: int | None = None     # None: plan.n_executors
    # how long an executor waits on a stream's ordering ticket before
    # proceeding out of order (broken-chain escape hatch; counted in
    # engine.metrics()["order_timeouts"])
    order_wait_s: float = 5.0
    # -- control plane (telemetry bus + ElasticController) ----------------
    # ``elasticity.enabled=True`` makes the Session own a TelemetryBus, a
    # FailureDetector, and an ElasticController for the engine's lifetime.
    elasticity: ElasticityConfig = ElasticityConfig()
    # -- multi-tenant QoS (repro.tenancy) ---------------------------------
    # Declaring tenants threads tenant identity through the whole pipeline:
    # tenant-tagged records, priority admission with parking/eviction in
    # the broker (at-most-once modes), per-tenant TelemetrySnapshot
    # rollups, and — with ``elasticity.slo_debt`` — debt-weighted scaling.
    # Entries are TenantSpec objects or plain dicts (JSON-friendly); a
    # "default" spec is always present.  () keeps the single-tenant
    # behavior byte-identical.
    tenants: tuple = ()
    # QoS admission tuning (active only with a tenant registry): parking of
    # best-effort traffic starts when a shard's queued records cross
    # qos_high_water × capacity, re-admission at qos_low_water × capacity;
    # qos_park_capacity bounds each sender's park (None: queue_capacity),
    # overflow evicts oldest-parked into the loss ledger
    qos_high_water: float = 0.75
    qos_low_water: float = 0.25
    qos_park_capacity: int | None = None
    # -- time source -------------------------------------------------------
    # ``clock="virtual"`` runs the whole Session — broker senders, engine
    # driver/executors, telemetry, controller, failure detector — on
    # deterministic simulated time (repro.runtime.clock.VirtualClock seeded
    # with ``clock_seed``): sleeps cost nothing real and same-seed runs
    # replay identically.  transport="loopback" under a virtual clock uses
    # VirtualLoopbackTransport (same framing, no sockets).  The default
    # "wall" keeps production behavior byte-identical to the pre-clock code.
    clock: str = "wall"                # wall | virtual
    clock_seed: int = 0                # VirtualClock wakeup tie-break seed

    # ---- validation -----------------------------------------------------
    def validate(self) -> "WorkflowConfig":
        if self.n_producers < 1:
            raise ValueError(f"n_producers must be >= 1, got {self.n_producers}")
        if self.n_groups is not None and not (1 <= self.n_groups <= self.n_producers):
            raise ValueError(
                f"n_groups must be in [1, n_producers={self.n_producers}], "
                f"got {self.n_groups}")
        if self.executors_per_group < 1:
            raise ValueError("executors_per_group must be >= 1")
        if self.n_endpoints is not None \
                and self.n_endpoints < self.group_plan().n_groups:
            raise ValueError(
                f"{self.group_plan().n_groups} groups (explicit or "
                f"auto-planned) need >= that many endpoints, "
                f"config declares {self.n_endpoints}")
        if self.sample_keep < 1:
            raise ValueError("sample_keep must be >= 1")
        if self.backpressure not in _BACKPRESSURE:
            raise ValueError(f"backpressure must be one of {_BACKPRESSURE}, "
                             f"got {self.backpressure!r}")
        if self.compress not in _COMPRESS:
            raise ValueError(f"compress must be one of {_COMPRESS}, "
                             f"got {self.compress!r}")
        if self.transport not in _TRANSPORT:
            raise ValueError(f"transport must be one of {_TRANSPORT}, "
                             f"got {self.transport!r}")
        if self.queue_capacity < 1 or self.max_batch_records < 1:
            raise ValueError("queue_capacity and max_batch_records must be >= 1")
        if self.retry_limit < 1:
            raise ValueError("retry_limit must be >= 1")
        if self.trigger_interval <= 0 or self.flush_timeout_s <= 0:
            raise ValueError("trigger_interval and flush_timeout_s must be > 0")
        if self.min_batch < 1:
            raise ValueError("min_batch must be >= 1")
        if self.order_wait_s <= 0:
            raise ValueError("order_wait_s must be > 0")
        if self.n_executors is not None and self.n_executors < 1:
            raise ValueError("n_executors must be >= 1")
        if self.clock not in _CLOCK:
            raise ValueError(f"clock must be one of {_CLOCK}, "
                             f"got {self.clock!r}")
        if self.delivery not in _DELIVERY:
            raise ValueError(f"delivery must be one of {_DELIVERY}, "
                             f"got {self.delivery!r}")
        if self.delivery == "exactly-once":
            if self.backpressure != "block":
                raise ValueError(
                    "delivery='exactly-once' requires backpressure='block' "
                    "(a drop policy contradicts the guarantee)")
            if self.delta_encode:
                raise ValueError(
                    "delivery='exactly-once' requires delta_encode=False "
                    "(replayed frames must decode independently)")
        if self.wal_dir is not None and self.delivery != "exactly-once":
            raise ValueError("wal_dir requires delivery='exactly-once' "
                             "(only the WAL path persists anything)")
        if self.wal_capacity_bytes < (1 << 12):
            raise ValueError("wal_capacity_bytes must be >= 4096")
        if self.broker_shards < 1:
            raise ValueError(f"broker_shards must be >= 1, "
                             f"got {self.broker_shards}")
        if self.shuffle_partitions is not None and self.shuffle_partitions < 1:
            raise ValueError(f"shuffle_partitions must be >= 1 (or None), "
                             f"got {self.shuffle_partitions}")
        if not (0.0 < self.qos_high_water <= 1.0) \
                or not (0.0 <= self.qos_low_water <= self.qos_high_water):
            raise ValueError("need 0 < qos_high_water <= 1 and "
                             "0 <= qos_low_water <= qos_high_water")
        if self.qos_park_capacity is not None and self.qos_park_capacity < 1:
            raise ValueError("qos_park_capacity must be >= 1 (or None)")
        reg = self.tenant_registry()       # raises on bad/duplicate specs
        if self.elasticity.slo_debt and reg is None:
            raise ValueError("elasticity.slo_debt requires "
                             "WorkflowConfig.tenants (the debt policy "
                             "weighs per-tenant SLO targets)")
        self.elasticity.validate()
        return self

    # ---- derived sub-configs -------------------------------------------
    def group_plan(self) -> GroupPlan:
        if self.n_groups is None:
            auto = plan_groups(self.n_producers,
                               executors_per_group=self.executors_per_group)
            n_groups = min(auto.n_groups, self.n_producers)
        else:
            n_groups = self.n_groups
        return GroupPlan(n_producers=self.n_producers, n_groups=n_groups,
                         executors_per_group=self.executors_per_group)

    def broker_config(self) -> BrokerConfig:
        return BrokerConfig(compress=self.compress,
                            queue_capacity=self.queue_capacity,
                            backpressure=self.backpressure,
                            sample_keep=self.sample_keep,
                            flush_timeout_s=self.flush_timeout_s,
                            retry_limit=self.retry_limit,
                            max_batch_records=self.max_batch_records,
                            delta_encode=self.delta_encode,
                            delivery=self.delivery,
                            wal_capacity_bytes=self.wal_capacity_bytes,
                            n_shards=self.broker_shards,
                            high_water_frac=self.qos_high_water,
                            low_water_frac=self.qos_low_water,
                            park_capacity=self.qos_park_capacity)

    def tenant_registry(self) -> TenantRegistry | None:
        """The validated TenantRegistry, or None without declared tenants
        (single-tenant deployments never pay the QoS plane)."""
        if not self.tenants:
            return None
        specs = [t if isinstance(t, TenantSpec) else TenantSpec(**t)
                 for t in self.tenants]
        return TenantRegistry(specs)

    @property
    def endpoint_count(self) -> int:
        return self.n_endpoints if self.n_endpoints is not None \
            else self.group_plan().n_groups

    def make_clock(self):
        """Instantiate the configured time source (one per Session)."""
        from repro.runtime.clock import VirtualClock, WallClock
        return WallClock() if self.clock == "wall" \
            else VirtualClock(seed=self.clock_seed)

    # ---- (de)serialization ---------------------------------------------
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "WorkflowConfig":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown WorkflowConfig keys: {sorted(unknown)}")
        if d.get("tenants"):
            d = dict(d, tenants=tuple(
                t if isinstance(t, TenantSpec) else TenantSpec(**t)
                for t in d["tenants"]))
        if isinstance(d.get("elasticity"), dict):
            el = dict(d["elasticity"])
            el_known = {f.name for f in fields(ElasticityConfig)}
            el_unknown = set(el) - el_known
            if el_unknown:
                raise ValueError(
                    f"unknown ElasticityConfig keys: {sorted(el_unknown)}")
            d = dict(d, elasticity=ElasticityConfig(**el))
        return cls(**d).validate()

    @classmethod
    def from_broker_config(cls, bcfg: BrokerConfig, plan: GroupPlan,
                           **overrides) -> "WorkflowConfig":
        """Lift the seed-era (BrokerConfig, GroupPlan) pair into a workflow
        config — the compat shim's bridge."""
        return cls(n_producers=plan.n_producers, n_groups=plan.n_groups,
                   executors_per_group=plan.executors_per_group,
                   compress=bcfg.compress, queue_capacity=bcfg.queue_capacity,
                   backpressure=bcfg.backpressure, sample_keep=bcfg.sample_keep,
                   flush_timeout_s=bcfg.flush_timeout_s,
                   retry_limit=bcfg.retry_limit,
                   max_batch_records=bcfg.max_batch_records,
                   delta_encode=bcfg.delta_encode, delivery=bcfg.delivery,
                   wal_capacity_bytes=bcfg.wal_capacity_bytes,
                   **overrides).validate()
