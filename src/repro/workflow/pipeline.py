"""Fluent builder for in-situ analysis DAGs — **deprecated**.

This is the legacy bare-callback builder.  New code should use the typed
stream-operator API (:class:`repro.streaming.operators.OperatorPipeline`):
it adds event-time windows, keyed state, and per-stage ordering contracts,
and ``Session.attach_pipeline`` compiles THIS builder's output onto those
same operators (with a DeprecationWarning), so both run on one engine path.
Migration is mechanical: ``stage(n, f)``/``then(n, f)`` → ``.map(n, f)``,
``branch(n, f)`` → ``.map(n, f, after=parent)``, sinks are explicit
``.sink(n)`` operators instead of every stage recording implicitly.

The paper's §6 future work ("more complex DAGs") is implemented by
:class:`repro.streaming.dag.AnalysisDAG`; this builder is the workflow-level
front door that composes one without hand-assembling ``Stage`` lists:

    pipe = (Pipeline()
            .stage("dmd", dmd_fn)            # source: consumes micro-batches
            .then("stability", stab_fn)      # downstream of the cursor
            .branch("trend", trend_fn))      # sibling: same parent as cursor

``stage`` declares the source (exactly once), ``then`` appends downstream of
the cursor and advances it, ``branch`` attaches a sibling of the cursor
(fan-out from the cursor's parent) without moving it, and ``at`` repositions
the cursor for deeper topologies.  ``compile`` materializes the (validated,
acyclic by construction) graph as an ``AnalysisDAG`` ready for
``Session.attach_pipeline`` / ``StreamEngine.attach_dag``.
"""
from __future__ import annotations

from typing import Any, Callable

from repro.streaming.dag import AnalysisDAG, Stage

StageFn = Callable[[str, Any], Any]


class Pipeline:
    def __init__(self):
        self._fns: dict[str, StageFn] = {}
        self._parent: dict[str, str | None] = {}
        self._source: str | None = None
        self._cursor: str | None = None

    # ---- construction ---------------------------------------------------
    def _add(self, name: str, fn: StageFn, parent: str | None) -> None:
        if not name:
            raise ValueError("stage name must be non-empty")
        if name in self._fns:
            raise ValueError(f"duplicate stage {name!r}")
        self._fns[name] = fn
        self._parent[name] = parent

    def stage(self, name: str, fn: StageFn) -> "Pipeline":
        """Declare the source stage (receives the raw micro-batch records)."""
        if self._source is not None:
            raise ValueError(
                f"source {self._source!r} already declared; use then()/branch()")
        self._add(name, fn, parent=None)
        self._source = self._cursor = name
        return self

    def then(self, name: str, fn: StageFn) -> "Pipeline":
        """Append ``name`` downstream of the cursor and move the cursor."""
        if self._cursor is None:
            raise ValueError("call stage() before then()")
        self._add(name, fn, parent=self._cursor)
        self._cursor = name
        return self

    def branch(self, name: str, fn: StageFn) -> "Pipeline":
        """Attach ``name`` as a sibling of the cursor (fan-out from the
        cursor's parent); the cursor stays put."""
        if self._cursor is None:
            raise ValueError("call stage() before branch()")
        parent = self._parent[self._cursor]
        if parent is None:
            raise ValueError(
                "branch() needs a prior then(); the source has no parent to "
                "fan out from")
        self._add(name, fn, parent=parent)
        return self

    def at(self, name: str) -> "Pipeline":
        """Move the cursor to an existing stage (for multi-arm topologies)."""
        if name not in self._fns:
            raise ValueError(f"unknown stage {name!r}")
        self._cursor = name
        return self

    # ---- introspection / compilation ------------------------------------
    def edges(self) -> list[tuple[str, str]]:
        return [(p, c) for c, p in self._parent.items() if p is not None]

    def compile(self) -> AnalysisDAG:
        if self._source is None:
            raise ValueError("empty pipeline: declare a source with stage()")
        downstream: dict[str, list[str]] = {n: [] for n in self._fns}
        for parent, child in self.edges():
            downstream[parent].append(child)
        stages = [Stage(name=n, fn=fn, downstream=downstream[n])
                  for n, fn in self._fns.items()]
        return AnalysisDAG(stages, source=self._source)
