"""The composable Session owning the whole HPC→Cloud pipeline.

One object replaces the seed's four hand-wired call sites:

    with Session(WorkflowConfig(n_producers=8, n_groups=2),
                 analyze=my_analyzer) as sess:
        vel = sess.open_field("velocity", shape=(256,))
        for s in range(steps):
            vel.write(s, field, rank=r)            # or write_batch(...)
    panel = sess.results()                         # after ordered teardown

``Session`` owns endpoint creation (per the config's transport), broker
construction, engine + DAG lifecycle, and ordered teardown —
``broker.finalize()`` (drain producer queues onto the endpoints) then
``engine.drain_and_stop()`` (drain endpoints through the analyzers) then
transport close.  :class:`FieldHandle` is the typed producer-side handle the
paper's free-floating ``broker_ctx`` grew into: dtype-coercing,
shape-checking, and batch-aware (``write_batch`` ships all regions of a
field as one aggregated queue item per group ⇒ ≤ one wire frame per
(field, group)).
"""
from __future__ import annotations

import threading
import warnings

import numpy as np

from repro.core.broker import Broker, BrokerStats
from repro.core.records import FieldSchema
from repro.runtime.clock import Clock, ensure_clock
from repro.runtime.controller import ElasticController
from repro.runtime.fault import FailureDetector
from repro.runtime.telemetry import TelemetryBus
from repro.streaming.dag import AnalysisDAG
from repro.streaming.endpoint import make_endpoints
from repro.streaming.engine import StreamEngine
from repro.streaming.operators import (ExecutionPlan, OperatorPipeline,
                                       lower_dag)
from repro.workflow.config import WorkflowConfig
from repro.workflow.pipeline import Pipeline


class FieldHandle:
    """Typed handle for one streamed field (all ranks of the job).

    ``shape=()`` means "unchecked" (the paper's ``void* data``); a concrete
    shape makes every write validate the payload's size.  Arrays are coerced
    to the declared dtype before they hit the wire — except with
    ``coerce_dtype=False`` (the paper-API compat path), where the declared
    dtype is schema metadata only and payloads keep their input dtype, as
    the original ``broker_write`` did.
    """

    def __init__(self, broker: Broker, name: str, shape=(),
                 dtype: str = "float32", rank: int = 0, *,
                 coerce_dtype: bool = True):
        self.broker = broker
        self.name = name
        self.shape = tuple(shape)
        self.dtype = dtype
        self.coerce_dtype = coerce_dtype
        self.rank = rank                    # default rank for write()
        for g in range(broker.plan.n_groups):
            broker.register(FieldSchema(field_name=name, shape=self.shape,
                                        dtype=dtype, group_id=g))

    def _coerce(self, arr) -> np.ndarray:
        out = np.asarray(arr, dtype=self.dtype if self.coerce_dtype else None)
        if self.shape and out.size != int(np.prod(self.shape)):
            raise ValueError(
                f"field {self.name!r} declared shape {self.shape} "
                f"({int(np.prod(self.shape))} elems) but payload has shape "
                f"{out.shape} ({out.size} elems)")
        return out

    def write(self, step: int, arr, *, rank: int | None = None) -> bool:
        """Enqueue one snapshot; returns False if backpressure dropped it."""
        r = self.rank if rank is None else rank
        return self.broker.write(self.name, r, step, self._coerce(arr))

    def write_batch(self, steps, arrs, *, ranks=None) -> int:
        """Enqueue many snapshots as one aggregated batch.

        ``steps`` is a scalar (broadcast) or a sequence aligned with
        ``arrs``; ``ranks`` likewise (default: the handle's rank).  Records
        are grouped by destination and each group receives ONE queue item,
        so the batch leaves as at most one wire frame per (field, group).
        Returns #records accepted.
        """
        arrs = [self._coerce(a) for a in arrs]
        n = len(arrs)
        if np.isscalar(steps):
            steps = [int(steps)] * n
        if ranks is None:
            ranks = [self.rank] * n
        elif np.isscalar(ranks):
            ranks = [int(ranks)] * n
        if not (len(steps) == len(ranks) == n):
            raise ValueError(
                f"write_batch needs aligned sequences: {len(steps)} steps, "
                f"{len(ranks)} ranks, {n} payloads")
        return self.broker.write_batch(self.name, list(ranks), list(steps), arrs)

    def __repr__(self):
        return (f"FieldHandle({self.name!r}, shape={self.shape}, "
                f"dtype={self.dtype!r})")


class Session:
    """Context manager owning broker → endpoint → engine → DAG wiring."""

    def __init__(self, config: WorkflowConfig | None = None, *,
                 endpoints: list | None = None, analyze=None, pipeline=None,
                 clock: Clock | None = None):
        self.config = (config or WorkflowConfig()).validate()
        self.plan = self.config.group_plan()
        # one time source for every layer: an explicit ``clock`` wins,
        # otherwise the config's clock knob ("wall" | "virtual") decides
        self.clock = ensure_clock(clock) if clock is not None \
            else self.config.make_clock()
        self._attached_thread = None
        if self.clock.virtual:
            # the building thread is the schedule's driver: register it
            # before any component thread starts, so virtual time cannot
            # advance while construction is still in flight.  Remembered so
            # close() detaches THIS thread even when called from another —
            # detaching the closer would strand the builder in the
            # runnable set and freeze the schedule.
            self._attached_thread = threading.current_thread()
            self.clock.attach(self._attached_thread)
        if endpoints is not None:
            self.endpoints = list(endpoints)
            self._owns_endpoints = False
        else:
            # endpoint_count >= plan.n_groups is enforced by validate()
            self.endpoints = make_endpoints(
                self.config.endpoint_count,
                inbound_bw=self.config.inbound_bw,
                base_port=self.config.base_port,
                transport=self.config.transport,
                clock=self.clock)
            self._owns_endpoints = True
        self.broker = Broker(self.plan, self.endpoints,
                             self.config.broker_config(), clock=self.clock)
        self.engine: StreamEngine | None = None
        self.dag: AnalysisDAG | None = None
        self.exec_plan: ExecutionPlan | None = None   # compiled operator plan
        # control plane (built lazily with the engine when elasticity is on)
        self.telemetry: TelemetryBus | None = None
        self.detector: FailureDetector | None = None
        self.controller: ElasticController | None = None
        self._fields: dict[tuple, FieldHandle] = {}
        self._closed = False
        try:
            if pipeline is not None:
                self.attach_pipeline(pipeline)
            elif analyze is not None:
                self.attach_analyzer(analyze)
        except Exception:   # don't leak sender threads / loopback sockets
            self.close()
            raise

    # ---- consumer-side wiring -------------------------------------------
    def _handles(self) -> list:
        return [e.handle for e in self.endpoints]

    def attach_analyzer(self, fn) -> StreamEngine:
        """Point the engine at ``fn(stream_key, records)`` (created lazily
        on first attach; swapped in place afterwards)."""
        if self.engine is None:
            self.engine = StreamEngine.from_config(
                self.config, self._handles(), fn, plan=self.plan,
                clock=self.clock)
            self._start_control_plane()
        else:
            self.engine.attach_dag(fn)      # also detaches any operator plan
        self.exec_plan = None               # stale sinks must not shadow fn
        self.dag = None
        return self.engine

    def attach_pipeline(self, pipeline):
        """Route every micro-batch through an analysis pipeline.

        Accepts the stream-operator API — an :class:`OperatorPipeline`
        (compiled here against the Session clock) or a prebuilt
        :class:`ExecutionPlan` — and, deprecated, the legacy
        :class:`Pipeline` / :class:`AnalysisDAG`, which are lowered onto the
        same operator machinery (``lower_dag``): identical stage results,
        ``dag.results()`` keeps working, sink timestamps come from the
        Session clock.  Returns the legacy DAG for legacy inputs (API
        compatibility), the compiled plan otherwise."""
        legacy = None
        if isinstance(pipeline, OperatorPipeline):
            plan = pipeline.compile(clock=self.clock)
            self.dag = None                 # drop any stale legacy sinks
        elif isinstance(pipeline, ExecutionPlan):
            plan = pipeline
            plan.bind_clock(self.clock)
            self.dag = None
        else:
            warnings.warn(
                "Pipeline/AnalysisDAG are deprecated: build an "
                "OperatorPipeline (repro.streaming.operators) with typed "
                "operators and per-stage ordering contracts instead",
                DeprecationWarning, stacklevel=2)
            legacy = pipeline.compile() if isinstance(pipeline, Pipeline) \
                else pipeline
            legacy.bind_clock(self.clock)
            plan = lower_dag(legacy, clock=self.clock)
            self.dag = legacy
        if self.engine is None:
            self.engine = StreamEngine.from_config(
                self.config, self._handles(), plan, plan=self.plan,
                clock=self.clock)
            self._start_control_plane()
        self.engine.attach_plan(plan)
        self.exec_plan = plan
        return legacy if legacy is not None else plan

    def _start_control_plane(self) -> None:
        """With ``elasticity.enabled``, the Session owns the closed loop:
        a TelemetryBus over its broker/endpoints/engine, a FailureDetector,
        and the ElasticController thread (started here, stopped FIRST in
        :meth:`close` so no actuator races the ordered teardown)."""
        el = self.config.elasticity
        if not el.enabled or self.controller is not None \
                or self.engine is None:
            return
        self.telemetry = TelemetryBus(broker=self.broker,
                                      endpoints=self._handles(),
                                      engine=self.engine, clock=self.clock)
        self.detector = FailureDetector(
            timeout_s=el.heartbeat_timeout_s,
            straggler_factor=el.straggler_factor, clock=self.clock)
        self.controller = ElasticController(
            self.telemetry, el, engine=self.engine, broker=self.broker,
            detector=self.detector, clock=self.clock)
        self.controller.start()

    # ---- producer-side API ----------------------------------------------
    def open_field(self, name: str, shape=(), dtype: str = "float32") -> FieldHandle:
        """Register a field and return its (cached) typed handle."""
        key = (name, tuple(shape), dtype)
        if key not in self._fields:
            self._fields[key] = FieldHandle(self.broker, name, shape=shape,
                                            dtype=dtype)
        return self._fields[key]

    # ---- observability ---------------------------------------------------
    @property
    def stats(self) -> BrokerStats:
        return self.broker.stats

    def results(self, stage: str | None = None) -> list:
        """Engine results; with ``stage``, a legacy DAG stage's sink or an
        operator plan's :class:`Sink` results."""
        if stage is not None:
            if self.dag is not None:
                # legacy pipeline: every stage has a DAG sink, and an
                # unknown stage raises KeyError exactly as the old API did
                return self.dag.results(stage)
            if self.exec_plan is not None:
                return self.exec_plan.results(stage)
            raise ValueError("no pipeline attached; results(stage=...) "
                             "needs attach_pipeline()")
        return self.engine.collect() if self.engine is not None else []

    def latency_stats(self) -> dict:
        return self.engine.latency_stats() if self.engine is not None else {"n": 0}

    def flush(self, timeout: float | None = None) -> None:
        self.broker.flush(timeout=timeout)

    # ---- lifecycle --------------------------------------------------------
    def close(self) -> BrokerStats:
        """Ordered teardown: controller.stop() (quiesce the control plane so
        no scale/reroute action races the drain) → broker.finalize() →
        engine.drain_and_stop() → transport close.  Idempotent; returns the
        final broker stats."""
        if self._closed:
            return self.broker.stats
        self._closed = True
        if self.controller is not None:
            self.controller.stop()
        stats = self.broker.finalize()
        if self.engine is not None:
            self.engine.drain_and_stop()
        if self._owns_endpoints:
            for ep in self.endpoints:
                close = getattr(ep, "close", None)
                if close is not None:
                    close()
        # leave the virtual schedule: every component thread is joined by
        # now.  Detach the thread __init__ attached (not necessarily the
        # closer) so a cross-thread close can't strand the builder as a
        # permanently-runnable participant.
        self.clock.detach(self._attached_thread)
        return stats

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def __repr__(self):
        state = "closed" if self._closed else "open"
        return (f"Session({state}, plan={self.plan.n_producers}p/"
                f"{self.plan.n_groups}g, transport={self.config.transport!r}, "
                f"fields={sorted({k[0] for k in self._fields})})")
