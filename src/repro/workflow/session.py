"""The composable Session owning the whole HPC→Cloud pipeline.

One object replaces the seed's four hand-wired call sites:

    with Session(WorkflowConfig(n_producers=8, n_groups=2),
                 analyze=my_analyzer) as sess:
        vel = sess.open_field("velocity", shape=(256,))
        for s in range(steps):
            vel.write(s, field, rank=r)            # or write_batch(...)
    panel = sess.results()                         # after ordered teardown

``Session`` owns endpoint creation (per the config's transport), broker
construction, engine + DAG lifecycle, and ordered teardown —
``broker.finalize()`` (drain producer queues onto the endpoints) then
``engine.drain_and_stop()`` (drain endpoints through the analyzers) then
transport close.  :class:`FieldHandle` is the typed producer-side handle the
paper's free-floating ``broker_ctx`` grew into: dtype-coercing,
shape-checking, and batch-aware (``write_batch`` ships all regions of a
field as one aggregated queue item per group ⇒ ≤ one wire frame per
(field, group)).
"""
from __future__ import annotations

import threading
import warnings

import numpy as np

from repro.core.broker import _COUNTER_FIELDS, Broker, BrokerStats
from repro.core.records import FieldSchema
from repro.runtime.clock import Clock, ensure_clock
from repro.runtime.controller import ElasticController
from repro.runtime.fault import FailureDetector
from repro.runtime.recovery import RecoverySupervisor
from repro.runtime.telemetry import TelemetryBus
from repro.runtime.wal import FileWalStore, SeqLedger, WalStore
from repro.streaming.dag import AnalysisDAG
from repro.streaming.endpoint import make_endpoint, make_endpoints
from repro.streaming.engine import StreamEngine
from repro.streaming.operators import (ExecutionPlan, OperatorPipeline,
                                       lower_dag)
from repro.tenancy import merge_counts
from repro.workflow.config import WorkflowConfig
from repro.workflow.pipeline import Pipeline


class RestoreTopologyError(ValueError):
    """A checkpoint's topology disagrees with the live ``WorkflowConfig``
    handed to :meth:`Session.restore`.

    Per-group WAL segments, the receive-side seq ledger, and the endpoint
    audit counters are all keyed by the checkpointed group/endpoint layout;
    silently rebuilding them under a different ``n_groups``/endpoint count
    would map replayed records to the wrong groups (or truncate the
    endpoint state zip) and corrupt the exactly-once guarantee.  Restore
    with a matching topology, or omit ``config`` to adopt the
    checkpointed one."""


def _check_restore_topology(ckpt_cfg: WorkflowConfig,
                            live_cfg: WorkflowConfig) -> None:
    """Raise :class:`RestoreTopologyError` on any mismatch that changes how
    checkpointed per-group/per-endpoint state maps onto the new session."""
    old_plan, new_plan = ckpt_cfg.group_plan(), live_cfg.group_plan()
    mismatches = []
    if old_plan.n_producers != new_plan.n_producers:
        mismatches.append(f"n_producers {old_plan.n_producers} -> "
                          f"{new_plan.n_producers}")
    if old_plan.n_groups != new_plan.n_groups:
        mismatches.append(f"n_groups {old_plan.n_groups} -> "
                          f"{new_plan.n_groups}")
    if ckpt_cfg.endpoint_count != live_cfg.endpoint_count:
        mismatches.append(f"endpoint_count {ckpt_cfg.endpoint_count} -> "
                          f"{live_cfg.endpoint_count}")
    if ckpt_cfg.delivery != live_cfg.delivery:
        mismatches.append(f"delivery {ckpt_cfg.delivery!r} -> "
                          f"{live_cfg.delivery!r}")
    if mismatches:
        raise RestoreTopologyError(
            "checkpointed topology does not match the live config "
            f"({'; '.join(mismatches)}): per-group WAL/ledger state cannot "
            "be adopted across a topology change — restore with the "
            "checkpointed topology (or pass config=None to adopt it)")


class FieldHandle:
    """Typed handle for one streamed field (all ranks of the job).

    ``shape=()`` means "unchecked" (the paper's ``void* data``); a concrete
    shape makes every write validate the payload's size.  Arrays are coerced
    to the declared dtype before they hit the wire — except with
    ``coerce_dtype=False`` (the paper-API compat path), where the declared
    dtype is schema metadata only and payloads keep their input dtype, as
    the original ``broker_write`` did.
    """

    def __init__(self, broker: Broker, name: str, shape=(),
                 dtype: str = "float32", rank: int = 0, *,
                 coerce_dtype: bool = True, tenant: str = "default"):
        self.broker = broker
        self.name = name
        self.shape = tuple(shape)
        self.dtype = dtype
        self.coerce_dtype = coerce_dtype
        self.rank = rank                    # default rank for write()
        self.tenant = tenant                # QoS identity stamped on writes
        for g in range(broker.plan.n_groups):
            broker.register(FieldSchema(field_name=name, shape=self.shape,
                                        dtype=dtype, group_id=g))

    def _coerce(self, arr) -> np.ndarray:
        out = np.asarray(arr, dtype=self.dtype if self.coerce_dtype else None)
        if self.shape and out.size != int(np.prod(self.shape)):
            raise ValueError(
                f"field {self.name!r} declared shape {self.shape} "
                f"({int(np.prod(self.shape))} elems) but payload has shape "
                f"{out.shape} ({out.size} elems)")
        return out

    def write(self, step: int, arr, *, rank: int | None = None,
              t: float | None = None) -> bool:
        """Enqueue one snapshot; returns False if backpressure dropped it.
        ``t``: explicit event timestamp (default: session clock's now)."""
        r = self.rank if rank is None else rank
        return self.broker.write(self.name, r, step, self._coerce(arr), t=t,
                                 tenant=self.tenant)

    def write_batch(self, steps, arrs, *, ranks=None,
                    t: float | None = None) -> int:
        """Enqueue many snapshots as one aggregated batch.

        ``steps`` is a scalar (broadcast) or a sequence aligned with
        ``arrs``; ``ranks`` likewise (default: the handle's rank).  Records
        are grouped by destination and each group receives ONE queue item,
        so the batch leaves as at most one wire frame per (field, group).
        Returns #records accepted.
        """
        arrs = [self._coerce(a) for a in arrs]
        n = len(arrs)
        if np.isscalar(steps):
            steps = [int(steps)] * n
        if ranks is None:
            ranks = [self.rank] * n
        elif np.isscalar(ranks):
            ranks = [int(ranks)] * n
        if not (len(steps) == len(ranks) == n):
            raise ValueError(
                f"write_batch needs aligned sequences: {len(steps)} steps, "
                f"{len(ranks)} ranks, {n} payloads")
        return self.broker.write_batch(self.name, list(ranks), list(steps),
                                       arrs, t=t, tenant=self.tenant)

    def __repr__(self):
        return (f"FieldHandle({self.name!r}, shape={self.shape}, "
                f"dtype={self.dtype!r})")


class Session:
    """Context manager owning broker → endpoint → engine → DAG wiring."""

    def __init__(self, config: WorkflowConfig | None = None, *,
                 endpoints: list | None = None, analyze=None, pipeline=None,
                 clock: Clock | None = None, wal: WalStore | None = None,
                 checkpoints=None, ledger: SeqLedger | None = None,
                 _paused: bool = False):
        self.config = (config or WorkflowConfig()).validate()
        self.plan = self.config.group_plan()
        # one time source for every layer: an explicit ``clock`` wins,
        # otherwise the config's clock knob ("wall" | "virtual") decides
        self.clock = ensure_clock(clock) if clock is not None \
            else self.config.make_clock()
        self._attached_thread = None
        if self.clock.virtual:
            # the building thread is the schedule's driver: register it
            # before any component thread starts, so virtual time cannot
            # advance while construction is still in flight.  Remembered so
            # close() detaches THIS thread even when called from another —
            # detaching the closer would strand the builder in the
            # runnable set and freeze the schedule.
            self._attached_thread = threading.current_thread()
            self.clock.attach(self._attached_thread)
        # -- exactly-once durability (no-ops in at-most-once mode) --------
        self._ckpt_store = checkpoints
        exactly_once = self.config.delivery == "exactly-once"
        if exactly_once:
            if ledger is None:
                ledger = SeqLedger()
            if wal is None:
                retain = "commit" if checkpoints is not None else "ack"
                if self.config.wal_dir is not None:
                    # disk-backed: adopts whatever a previous run synced
                    # into the directory (torn tails discarded on load)
                    wal = FileWalStore(
                        self.config.wal_dir,
                        capacity_bytes=self.config.wal_capacity_bytes,
                        queue_capacity=self.config.queue_capacity,
                        retain=retain)
                else:
                    wal = WalStore(
                        capacity_bytes=self.config.wal_capacity_bytes,
                        queue_capacity=self.config.queue_capacity,
                        retain=retain)
        self._ledger = ledger
        self._wal = wal
        self._stats_base: dict[str, int] = {}
        self._tenants_base: dict[str, dict[str, int]] = {}
        # multi-tenant QoS: one registry for the whole wiring (broker
        # admission, telemetry rollups, debt-weighted scaling); None keeps
        # every layer on its single-tenant fast path
        self.tenants = self.config.tenant_registry()
        self.recovery: RecoverySupervisor | None = None
        if endpoints is not None:
            self.endpoints = list(endpoints)
            self._owns_endpoints = False
        else:
            # endpoint_count >= plan.n_groups is enforced by validate()
            self.endpoints = make_endpoints(
                self.config.endpoint_count,
                inbound_bw=self.config.inbound_bw,
                base_port=self.config.base_port,
                transport=self.config.transport,
                clock=self.clock, ledger=self._ledger)
            self._owns_endpoints = True
        self.broker = Broker(self.plan, self.endpoints,
                             self.config.broker_config(), clock=self.clock,
                             wal=self._wal, paused=_paused,
                             tenants=self.tenants)
        self.engine: StreamEngine | None = None
        self.dag: AnalysisDAG | None = None
        self.exec_plan: ExecutionPlan | None = None   # compiled operator plan
        # control plane (built lazily with the engine when elasticity is on)
        self.telemetry: TelemetryBus | None = None
        self.detector: FailureDetector | None = None
        self.controller: ElasticController | None = None
        # cloud capacity plane (built with the control plane when
        # ``elasticity.provision``); _dynamic_eps tracks endpoints attached
        # to the live session so teardown closes them even when the base
        # fleet was caller-supplied
        self.provisioner = None
        self._dynamic_eps: list = []
        self._fields: dict[tuple, FieldHandle] = {}
        self._closed = False
        try:
            if pipeline is not None:
                self.attach_pipeline(pipeline)
            elif analyze is not None:
                self.attach_analyzer(analyze)
        except Exception:   # don't leak sender threads / loopback sockets
            self.close()
            raise

    # ---- consumer-side wiring -------------------------------------------
    def _handles(self) -> list:
        return [e.handle for e in self.endpoints]

    def attach_endpoint(self) -> int:
        """Attach one more endpoint to the LIVE session (cloud capacity
        plane: a freshly booted node brings its endpoint up mid-run).

        The new endpoint shares the fleet's SeqLedger so exactly-once
        dedupe spans it, and is registered with the broker (routable on
        the next send/reroute), the engine (drained next trigger cycle)
        and the telemetry bus.  Returns the new fleet index."""
        i = len(self.endpoints)
        ledger = self._ledger
        if ledger is None and self.endpoints:
            ledger = getattr(self.endpoints[0].handle, "ledger", None)
        ep = make_endpoint(i, inbound_bw=self.config.inbound_bw,
                           base_port=self.config.base_port,
                           transport=self.config.transport,
                           clock=self.clock, ledger=ledger)
        self.endpoints.append(ep)
        self._dynamic_eps.append(ep)
        bidx = self.broker.attach_endpoint(ep)
        assert bidx == i, f"broker fleet index diverged: {bidx} != {i}"
        if self.engine is not None:
            self.engine.attach_endpoint(ep.handle)
        if self.telemetry is not None:
            self.telemetry.endpoints.append(ep.handle)
        return i

    def attach_analyzer(self, fn) -> StreamEngine:
        """Point the engine at ``fn(stream_key, records)`` (created lazily
        on first attach; swapped in place afterwards)."""
        if self.engine is None:
            self.engine = StreamEngine.from_config(
                self.config, self._handles(), fn, plan=self.plan,
                clock=self.clock)
            self._start_control_plane()
        else:
            self.engine.attach_dag(fn)      # also detaches any operator plan
        self.exec_plan = None               # stale sinks must not shadow fn
        self.dag = None
        return self.engine

    def attach_pipeline(self, pipeline):
        """Route every micro-batch through an analysis pipeline.

        Accepts the stream-operator API — an :class:`OperatorPipeline`
        (compiled here against the Session clock) or a prebuilt
        :class:`ExecutionPlan` — and, deprecated, the legacy
        :class:`Pipeline` / :class:`AnalysisDAG`, which are lowered onto the
        same operator machinery (``lower_dag``): identical stage results,
        ``dag.results()`` keeps working, sink timestamps come from the
        Session clock.  Returns the legacy DAG for legacy inputs (API
        compatibility), the compiled plan otherwise."""
        legacy = None
        if isinstance(pipeline, OperatorPipeline):
            plan = pipeline.compile(clock=self.clock)
            self.dag = None                 # drop any stale legacy sinks
        elif isinstance(pipeline, ExecutionPlan):
            plan = pipeline
            plan.bind_clock(self.clock)
            self.dag = None
        else:
            warnings.warn(
                "Pipeline/AnalysisDAG are deprecated: build an "
                "OperatorPipeline (repro.streaming.operators) with typed "
                "operators and per-stage ordering contracts instead",
                DeprecationWarning, stacklevel=2)
            legacy = pipeline.compile() if isinstance(pipeline, Pipeline) \
                else pipeline
            legacy.bind_clock(self.clock)
            plan = lower_dag(legacy, clock=self.clock)
            self.dag = legacy
        if self.engine is None:
            self.engine = StreamEngine.from_config(
                self.config, self._handles(), plan, plan=self.plan,
                clock=self.clock)
            self._start_control_plane()
        self.engine.attach_plan(plan)
        self.exec_plan = plan
        return legacy if legacy is not None else plan

    def _start_control_plane(self) -> None:
        """With ``elasticity.enabled``, the Session owns the closed loop:
        a TelemetryBus over its broker/endpoints/engine, a FailureDetector,
        and the ElasticController thread (started here, stopped FIRST in
        :meth:`close` so no actuator races the ordered teardown)."""
        el = self.config.elasticity
        if not el.enabled or self.controller is not None \
                or self.engine is None:
            return
        self.telemetry = TelemetryBus(broker=self.broker,
                                      endpoints=self._handles(),
                                      engine=self.engine, clock=self.clock,
                                      tenants=self.tenants)
        self.detector = FailureDetector(
            timeout_s=el.heartbeat_timeout_s,
            straggler_factor=el.straggler_factor, clock=self.clock)
        if self.config.delivery == "exactly-once":
            # endpoint/executor death routes through the supervisor: the
            # same re-point, but with WAL replay behind it instead of loss
            self.recovery = RecoverySupervisor(broker=self.broker,
                                               engine=self.engine,
                                               clock=self.clock)
        if el.provision:
            from repro.cloud import (DEFAULT_CATALOG, CloudProvisioner,
                                     SessionFabric)
            if el.node_class not in DEFAULT_CATALOG:
                raise ValueError(
                    f"unknown elasticity.node_class {el.node_class!r}; "
                    f"catalog has {sorted(DEFAULT_CATALOG)}")
            self.provisioner = CloudProvisioner(
                SessionFabric(self), clock=self.clock,
                seed=self.config.clock_seed,
                retry_limit=el.provision_retry_limit,
                backoff_s=el.provision_backoff_s)
        self.controller = ElasticController(
            self.telemetry, el, engine=self.engine, broker=self.broker,
            detector=self.detector, clock=self.clock,
            recovery=self.recovery, provisioner=self.provisioner,
            tenants=self.tenants)
        self.controller.start()

    # ---- producer-side API ----------------------------------------------
    def open_field(self, name: str, shape=(), dtype: str = "float32",
                   tenant: str = "default") -> FieldHandle:
        """Register a field and return its (cached) typed handle.

        ``tenant`` stamps every write from the handle with that QoS
        identity (must be declared in ``config.tenants`` when a registry
        is active)."""
        key = (name, tuple(shape), dtype, tenant)
        if key not in self._fields:
            self._fields[key] = FieldHandle(self.broker, name, shape=shape,
                                            dtype=dtype, tenant=tenant)
        return self._fields[key]

    # ---- observability ---------------------------------------------------
    @property
    def stats(self) -> BrokerStats:
        """Broker counters, folded with whatever previous broker/session
        incarnations accumulated before a crash (exactly-once restarts)."""
        return self._merge_base(self.broker.stats)

    def _merge_base(self, st: BrokerStats) -> BrokerStats:
        for f, v in self._stats_base.items():
            setattr(st, f, getattr(st, f) + v)
        if self._tenants_base:
            merge_counts(st.tenants, self._tenants_base)
        return st

    def _absorb_stats(self, stats: BrokerStats) -> None:
        """Fold a dead incarnation's counters into the session base.

        In exactly-once mode ``written`` is excluded: it derives from the
        WAL segments the successor broker shares, so the live broker's
        count already covers the dead incarnation's writes.  Per-tenant
        counters fold additively — ``admitted`` is counted once at WAL
        append and never on replay, so the sum stays exact."""
        for f in _COUNTER_FIELDS:
            if f == "written" and self._wal is not None:
                continue
            self._stats_base[f] = self._stats_base.get(f, 0) \
                + getattr(stats, f)
        merge_counts(self._tenants_base, stats.tenants)

    def results(self, stage: str | None = None) -> list:
        """Engine results; with ``stage``, a legacy DAG stage's sink or an
        operator plan's :class:`Sink` results."""
        if stage is not None:
            if self.dag is not None:
                # legacy pipeline: every stage has a DAG sink, and an
                # unknown stage raises KeyError exactly as the old API did
                return self.dag.results(stage)
            if self.exec_plan is not None:
                return self.exec_plan.results(stage)
            raise ValueError("no pipeline attached; results(stage=...) "
                             "needs attach_pipeline()")
        return self.engine.collect() if self.engine is not None else []

    def latency_stats(self) -> dict:
        return self.engine.latency_stats() if self.engine is not None else {"n": 0}

    def flush(self, timeout: float | None = None) -> None:
        self.broker.flush(timeout=timeout)

    # ---- exactly-once: checkpoint / crash / restore ----------------------
    def _quiesce_engine(self, timeout: float = 60.0) -> None:
        """Run the pipeline dry: force-trigger until nothing is pending on
        the endpoints, held in the engine, queued, or being analyzed.  A
        checkpoint taken here is a consistent cut — every record the broker
        acked has fully traversed the plan."""
        eng = self.engine

        def idle() -> bool:
            if self._closed:
                raise RuntimeError("session killed during checkpoint quiesce")
            eng.trigger_once(force=True)
            if any(h.pending() for h in self._handles()):
                return False
            m = eng.metrics()
            return (eng.held() == 0 and m["queued"] == 0
                    and all(e["current_key"] is None
                            for e in m["executors"]))

        if not self.clock.wait(idle, timeout=timeout, poll=0.01):
            raise TimeoutError(
                "pipeline did not quiesce within the checkpoint timeout")

    def checkpoint(self, timeout: float = 60.0) -> int:
        """Quiesce and capture a consistent cut of the whole run — plan
        state (window panes, watermarks, loss ledgers, sink results), the
        per-stream commit frontier, engine seq counters + results, broker
        counters and WAL trim points, the receive-side seq ledger, and the
        endpoints' audit counters — into the checkpoint store.  The WAL is
        marked committed through the cut only after the store commits, so a
        crash during save still restores from the previous checkpoint."""
        if self._ckpt_store is None:
            raise ValueError("no checkpoint store: pass "
                             "checkpoints=SessionCheckpointStore(dir)")
        if self._wal is None or self.exec_plan is None:
            raise ValueError("checkpoint() requires delivery='exactly-once' "
                             "and an attached operator pipeline")
        self.broker.flush(timeout=timeout)
        self._quiesce_engine(timeout=timeout)
        st = self.stats
        state = {
            "config": self.config.to_dict(),
            "plan": self.exec_plan.snapshot(),
            "frontier": self.exec_plan.frontier_snapshot(),
            "engine": self.engine.state_snapshot(),
            "stats": {f: getattr(st, f) for f in _COUNTER_FIELDS},
            "tenant_stats": {k: dict(v) for k, v in st.tenants.items()},
            "wal": self.broker.wal_points(),
            "ledger": self._ledger.snapshot(),
            "endpoints": [h.audit_snapshot() for h in self._handles()],
        }
        cid = self._ckpt_store.save(state)
        self.broker.commit_wal()
        if isinstance(self._wal, FileWalStore):
            # durable cut: the commit frontier (and the tail behind it)
            # reaches disk, so a *host* crash restores from this checkpoint
            self._wal.sync()
        return cid

    def kill(self) -> None:
        """Simulated whole-session crash: controller, broker senders, and
        engine threads stop immediately; queued work and in-memory state
        die.  The durable artifacts — the WalStore and checkpoint store
        passed to __init__ — survive for :meth:`restore`."""
        if self._closed:
            return
        self._closed = True
        if self.controller is not None:
            self.controller.stop()
        self.broker.kill()
        if self.engine is not None:
            self.engine.kill()
        closing = list(self.endpoints) if self._owns_endpoints \
            else list(self._dynamic_eps)
        for ep in closing:
            close = getattr(ep, "close", None)
            if close is not None:
                close()
        self.clock.detach(self._attached_thread)

    def restart_broker(self) -> Broker:
        """Crash-and-replace the broker in place (exactly-once only): the
        dead broker's senders stop without draining, a fresh Broker adopts
        the same WalStore, and each group's unacked tail replays through
        the endpoints (receive-side dedupe keeps delivery exact)."""
        if self._wal is None:
            raise ValueError("restart_broker() requires "
                             "delivery='exactly-once' (the WAL is what "
                             "makes a broker restart lossless)")
        old = self.broker
        self._absorb_stats(old.kill())
        replay = self._wal.unacked_records()
        self.broker = Broker(self.plan, self.endpoints,
                             self.config.broker_config(), clock=self.clock,
                             wal=self._wal)
        for schema in old.schemas.values():
            self.broker.register(schema)
        for h in self._fields.values():
            h.broker = self.broker
        if self.telemetry is not None:
            self.telemetry.broker = self.broker
        if self.controller is not None:
            self.controller.broker = self.broker
        if self.recovery is not None:
            self.recovery.broker = self.broker
            self.recovery.on_broker_restart(replay)
        return self.broker

    @classmethod
    def restore(cls, config: WorkflowConfig | None = None, *, checkpoints,
                wal: WalStore, pipeline, clock: Clock | None = None,
                endpoints: list | None = None) -> "Session":
        """Rebuild a crashed exactly-once run: load the latest committed
        checkpoint (if any), rewind the WAL's acked frontier to its commit
        frontier, and start a Session whose broker replays the uncommitted
        tail through a freshly-built pipeline restored to the checkpoint
        cut — windows resume mid-pane, sinks keep pre-crash results, and
        the loss ledger stays closed across the crash."""
        try:
            state, _cid = checkpoints.load()
        except FileNotFoundError:
            state = None                   # crash before the 1st checkpoint
        if config is None:
            if state is None:
                raise ValueError("no checkpoint and no config: cannot "
                                 "reconstruct the workflow")
            config = WorkflowConfig.from_dict(state["config"])
        elif state is not None:
            _check_restore_topology(
                WorkflowConfig.from_dict(state["config"]), config)
        ledger = SeqLedger()
        if state is not None:
            ledger.restore(state["ledger"])
        wal.reset_for_restore()            # tail past the commit replays
        sess = cls(config, pipeline=pipeline, clock=clock, wal=wal,
                   checkpoints=checkpoints, ledger=ledger,
                   endpoints=endpoints, _paused=True)
        try:
            if state is not None:
                sess.exec_plan.restore(state["plan"])
                sess.exec_plan.restore_frontier(state["frontier"])
                sess.engine.restore_state(state["engine"])
                for h, snap in zip(sess._handles(), state["endpoints"]):
                    h.restore_audit(snap)
                sess._stats_base = dict(state["stats"])
                sess._tenants_base = {
                    k: dict(v)
                    for k, v in state.get("tenant_stats", {}).items()}
            # ``written`` derives from the shared WAL segments (total ever
            # appended, across every incarnation), so the new broker already
            # reports the pre-crash writes — carrying the checkpoint's count
            # forward would double them
            sess._stats_base["written"] = 0
        except Exception:
            sess.kill()
            raise
        sess.broker.release()              # state is in place: replay
        return sess

    # ---- lifecycle --------------------------------------------------------
    def close(self) -> BrokerStats:
        """Ordered teardown: controller.stop() (quiesce the control plane so
        no scale/reroute action races the drain) → broker.finalize() →
        engine.drain_and_stop() → transport close.  Idempotent; returns the
        final broker stats."""
        if self._closed:
            return self._merge_base(self.broker.stats)
        self._closed = True
        if self.controller is not None:
            self.controller.stop()
        stats = self._merge_base(self.broker.finalize())
        if self.engine is not None:
            self.engine.drain_and_stop()
        if self.provisioner is not None:
            # close the capacity books: any node still booting/ready/
            # draining is powered off now, so the cost ledger ends closed
            self.provisioner.shutdown()
        if isinstance(self._wal, FileWalStore):
            self._wal.sync()
        closing = list(self.endpoints) if self._owns_endpoints \
            else list(self._dynamic_eps)
        for ep in closing:
            close = getattr(ep, "close", None)
            if close is not None:
                close()
        # leave the virtual schedule: every component thread is joined by
        # now.  Detach the thread __init__ attached (not necessarily the
        # closer) so a cross-thread close can't strand the builder as a
        # permanently-runnable participant.
        self.clock.detach(self._attached_thread)
        return stats

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def __repr__(self):
        state = "closed" if self._closed else "open"
        return (f"Session({state}, plan={self.plan.n_producers}p/"
                f"{self.plan.n_groups}g, transport={self.config.transport!r}, "
                f"fields={sorted({k[0] for k in self._fields})})")
