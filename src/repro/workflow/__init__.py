"""repro.workflow — the declarative HPC→Cloud workflow API.

Public surface:

* :class:`WorkflowConfig` — one validated config (topology + endpoint +
  broker + engine knobs) with a lossless ``to_dict``/``from_dict``.
* :class:`Session` — context manager owning endpoint creation, broker
  construction, engine/DAG lifecycle, and ordered teardown.
* :class:`FieldHandle` — typed producer handle (``write``/``write_batch``).
* :class:`Pipeline` — fluent builder compiling to an ``AnalysisDAG``.
* :class:`ElasticityConfig` — the control-plane knob block; with
  ``enabled=True`` the Session owns a telemetry bus + ElasticController
  that holds the p99 QoS target by scaling executors, adapting wire batch
  caps, and recovering from endpoint/executor failure.

The analysis surface is the **stream-operator API**
(:mod:`repro.streaming.operators`, re-exported here): an
:class:`OperatorPipeline` of typed operators (``Map``/``Filter``/``KeyBy``/
``TumblingWindow``/``SlidingWindow``/``Aggregate``/``Sink``), each with an
ordering contract (``ordered`` | ``unordered`` | ``keyed``) and a
parallelism hint, compiled to an :class:`ExecutionPlan` the engine honors —
order-insensitive stages run intra-stream parallel, windows hold keyed
state with snapshot/restore.  The older :class:`Pipeline`/``AnalysisDAG``
callback API still works as a deprecated shim that compiles onto the same
operators.

The paper's Listing 1.1 C API (``broker_connect``/``broker_init``/
``broker_write``/``broker_finalize`` in :mod:`repro.core.api`) is kept as a
thin, deprecated compatibility shim over :class:`Session`.
"""
from repro.runtime.controller import ElasticityConfig
from repro.streaming.operators import (Aggregate, ExecutionPlan, Filter,
                                       KeyBy, Map, OperatorPipeline, Sink,
                                       SlidingWindow, TumblingWindow,
                                       WindowPane)
from repro.workflow.config import WorkflowConfig
from repro.workflow.pipeline import Pipeline
from repro.workflow.session import FieldHandle, Session

__all__ = ["WorkflowConfig", "Session", "FieldHandle", "Pipeline",
           "ElasticityConfig", "OperatorPipeline", "ExecutionPlan",
           "Map", "Filter", "KeyBy", "TumblingWindow", "SlidingWindow",
           "Aggregate", "Sink", "WindowPane"]
