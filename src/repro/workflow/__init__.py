"""repro.workflow — the declarative HPC→Cloud workflow API.

Public surface:

* :class:`WorkflowConfig` — one validated config (topology + endpoint +
  broker + engine knobs) with a lossless ``to_dict``/``from_dict``.
* :class:`Session` — context manager owning endpoint creation, broker
  construction, engine/DAG lifecycle, and ordered teardown.
* :class:`FieldHandle` — typed producer handle (``write``/``write_batch``).
* :class:`Pipeline` — fluent builder compiling to an ``AnalysisDAG``.
* :class:`ElasticityConfig` — the control-plane knob block; with
  ``enabled=True`` the Session owns a telemetry bus + ElasticController
  that holds the p99 QoS target by scaling executors, adapting wire batch
  caps, and recovering from endpoint/executor failure.

The paper's Listing 1.1 C API (``broker_connect``/``broker_init``/
``broker_write``/``broker_finalize`` in :mod:`repro.core.api`) is kept as a
thin, deprecated compatibility shim over :class:`Session`.
"""
from repro.runtime.controller import ElasticityConfig
from repro.workflow.config import WorkflowConfig
from repro.workflow.pipeline import Pipeline
from repro.workflow.session import FieldHandle, Session

__all__ = ["WorkflowConfig", "Session", "FieldHandle", "Pipeline",
           "ElasticityConfig"]
