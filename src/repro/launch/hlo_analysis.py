"""Post-SPMD HLO cost extractor for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE, even when it
is a while-loop body with N iterations (verified empirically) — useless for
scan-over-layers programs.  This module parses ``compiled.as_text()``
(optimized, *per-device* HLO):

  * builds the computation call graph (fusion ``calls=``, ``while`` body/cond,
    ``conditional`` branches),
  * extracts while trip counts from the loop-condition constant,
  * propagates call multiplicity from ENTRY,
  * counts dot/convolution FLOPs from operand shapes + contracting dims,
  * approximates HBM bytes per op as result+operand bytes at fusion
    boundaries (fusion internals stay in registers/VMEM),
  * buckets collective bytes by kind (all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute), with replica-group
    sizes.

All numbers are per-device (the text is the partitioned module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\((.*?)\)(.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (tuples summed)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> tuple[list[int], str]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], ""
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims, m.group(1)


_META_RE = re.compile(r'op_name="([^"]*)"')
_KERNEL_RE = re.compile(r"(kernel_[\w]+)")


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    operands: list[str]
    attrs: str

    @property
    def kernel_region(self) -> str | None:
        """Named-scope Pallas-kernel marker (models/layers.py), if any."""
        m = _META_RE.search(self.attrs)
        if not m:
            return None
        k = _KERNEL_RE.search(m.group(1))
        return k.group(1) if k else None


@dataclass
class Computation:
    name: str
    is_entry: bool
    params: dict[str, str] = field(default_factory=dict)   # name -> type
    ops: list[Op] = field(default_factory=list)
    symbols: dict[str, str] = field(default_factory=dict)  # name -> type


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if not line.strip():
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.rstrip().endswith("{"):
            cur = Computation(name=hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            # parameters: "name: type" pairs
            for pm in re.finditer(r"%?([\w\.\-]+)\s*:\s*([\w\[\],\{\}\/ ]+?)(?:,|\)$|\)\s*->)",
                                  line):
                cur.params[pm.group(1)] = pm.group(2)
                cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        _, name, rtype, kind, operand_str, attrs = om.groups()
        operands = []
        for tok in operand_str.split(","):
            tok = tok.strip()
            m2 = _OPERAND_RE.match(tok)
            if m2:
                operands.append(m2.group(1))
        op = Op(name=name, kind=kind, result_type=rtype,
                operands=operands, attrs=attrs)
        cur.ops.append(op)
        cur.symbols[name] = rtype
        # parameter ops inside body: "%p = f32[..] parameter(0)"
        if kind == "parameter":
            cur.params[name] = rtype
    return comps


def _trip_count(cond: Computation, comps: dict[str, Computation]) -> int:
    """Largest integer constant in the loop condition (and its callees)."""
    best = 1
    seen = set()

    def visit(c: Computation):
        if c.name in seen:
            return
        seen.add(c.name)
        nonlocal best
        for op in c.ops:
            for m in _CONST_RE.finditer(op.kind + "(" + ",".join(op.operands) + ")" + op.attrs):
                best = max(best, int(m.group(1)))
            if op.kind == "constant":
                m = re.search(r"constant\((\d+)\)", f"constant({op.attrs})")
            cm = _CALLS_RE.search(op.attrs)
            if cm and cm.group(1) in comps:
                visit(comps[cm.group(1)])
        return

    visit(cond)
    return best


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dims, _ = shape_dims(op.result_type)
    n_out = 1
    for d in out_dims:
        n_out *= d
    cm = _CONTRACT_RE.search(op.attrs)
    contract = 1
    if cm and op.operands:
        lhs_type = comp.symbols.get(op.operands[0], "")
        lhs_dims, _ = shape_dims(lhs_type)
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * n_out * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    out_dims, _ = shape_dims(op.result_type)
    n_out = 1
    for d in out_dims:
        n_out *= d
    rhs_type = comp.symbols.get(op.operands[1], "") if len(op.operands) > 1 else ""
    rhs_dims, _ = shape_dims(rhs_type)
    k = 1
    for d in rhs_dims[:-1]:  # kernel spatial x in-features (approx)
        k *= d
    return 2.0 * n_out * k


_MOVEMENT = {"parameter", "constant", "bitcast", "tuple", "get-tuple-element",
             "convert", "copy", "transpose", "reshape", "broadcast", "slice",
             "dynamic-slice", "iota", "pad"}


def _fusion_bytes(op: Op, comp: Computation, comps: dict) -> tuple[float, str]:
    """HBM write cost of a fusion, classified:

    * contains dynamic-update-slice and otherwise only data movement ->
      in-place on TPU: cost = the update slices' bytes, not the full buffer
      (scan stashes / cache writes were otherwise counted len(stack)x too big)
    * pure convert/copy movement -> counted, but tagged 'convert' so the
      TPU-dtype correction can drop it (bf16 legalization artifact)
    * anything with math -> full result bytes
    """
    cm = _CALLS_RE.search(op.attrs)
    callee = comps.get(cm.group(1)) if cm else None
    if callee is None:
        return shape_bytes(op.result_type), "math"
    kinds = {o.kind for o in callee.ops}
    extra = kinds - _MOVEMENT - {"dynamic-update-slice"}
    if "dynamic-update-slice" in kinds and not extra:
        b = 0.0
        for o in callee.ops:
            if o.kind == "dynamic-update-slice" and len(o.operands) > 1:
                b += shape_bytes(callee.symbols.get(o.operands[1], ""))
        return b, "dus"
    if not extra and "dynamic-update-slice" not in kinds:
        kind = "convert" if "convert" in kinds else "movement"
        return shape_bytes(op.result_type), kind
    return shape_bytes(op.result_type), "math"


def analyze(text: str) -> dict:
    """Returns per-device totals: flops, bytes, collective bytes by kind."""
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # ---- multiplicity propagation -------------------------------------
    mult: dict[str, float] = {c: 0.0 for c in comps}
    mult[entry.name] = 1.0
    order = [entry.name]
    seen = {entry.name}
    # BFS through call graph, accumulating multiplicity
    queue = [entry.name]
    while queue:
        cname = queue.pop(0)
        comp = comps[cname]
        m = mult[cname]
        for op in comp.ops:
            callees: list[tuple[str, float]] = []
            if op.kind == "while":
                bm = _BODY_RE.search(op.attrs)
                cm = _COND_RE.search(op.attrs)
                trips = 1
                if cm and cm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)], comps)
                if bm and bm.group(1) in comps:
                    callees.append((bm.group(1), float(trips)))
                if cm and cm.group(1) in comps:
                    callees.append((cm.group(1), float(trips + 1)))
            elif op.kind == "conditional":
                br = _BRANCH_RE.search(op.attrs)
                if br:
                    for b in br.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b in comps:
                            callees.append((b, 1.0))
            else:
                cm = _CALLS_RE.search(op.attrs)
                if cm and cm.group(1) in comps:
                    callees.append((cm.group(1), 1.0))
            for callee, k in callees:
                mult[callee] = mult.get(callee, 0.0) + m * k
                if callee not in seen:
                    seen.add(callee)
                    queue.append(callee)

    # ---- cost accumulation --------------------------------------------
    flops = 0.0
    bytes_accessed = 0.0
    coll: dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    coll_count: dict[str, int] = {k: 0 for k in COLLECTIVES}
    group_size: dict[str, int] = {}
    fused: dict[str, dict] = {}        # kernel marker -> {flops, bytes}
    # CPU-legalization tracking: XLA CPU has no bf16 ALU, so bf16 dots are
    # rewritten convert(bf16->f32) + f32 dot (+ convert back).  On TPU those
    # dots, their collectives, and their materializations are native bf16.
    bytes_f32_dots = 0.0               # non-fused f32 dot results
    bytes_converts = 0.0               # top-level convert results
    coll_f32 = 0.0                     # f32 collective bytes (dot-adjacent)

    fusion_names = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                cm = _CALLS_RE.search(op.attrs)
                if cm:
                    fusion_names.add(cm.group(1))

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        in_fusion = comp.name in fusion_names
        for op in comp.ops:
            kind = op.kind
            region = op.kernel_region
            if kind == "dot":
                f = m * _dot_flops(op, comp)
                flops += f
                if region:
                    fused.setdefault(region, {"flops": 0.0, "bytes": 0.0})
                    fused[region]["flops"] += f
            elif kind == "convolution":
                flops += m * _conv_flops(op, comp)
            # memory: results-only at fusion boundaries (each tensor written
            # once; reads approximated by the producing op's write — avoids
            # double counting operands through elementwise chains that a TPU
            # compile would fuse).  Entry parameters are added once below.
            # while/conditional results are loop-carry tuples XLA aliases in
            # place — the body ops' writes are already counted
            if not in_fusion and kind not in ("parameter", "constant",
                                              "get-tuple-element", "tuple",
                                              "bitcast", "copy-start",
                                              "copy-done", "while",
                                              "conditional"):
                if kind == "fusion":
                    fb, fclass = _fusion_bytes(op, comp, comps)
                    b = m * fb
                elif kind == "dynamic-update-slice":
                    # in-place: the update slice is the real write
                    fclass = "dus"
                    b = m * shape_bytes(comp.symbols.get(op.operands[1], "")
                                        if len(op.operands) > 1 else op.result_type)
                else:
                    fclass = kind
                    b = m * shape_bytes(op.result_type)
                bytes_accessed += b
                if region:
                    fused.setdefault(region, {"flops": 0.0, "bytes": 0.0})
                    fused[region]["bytes"] += b
                else:
                    if kind == "dot" and op.result_type.startswith("f32"):
                        bytes_f32_dots += b
                    elif fclass == "convert":
                        bytes_converts += b
            for ck in COLLECTIVES:
                if kind == ck or kind == ck + "-start":
                    cb = max(shape_bytes(op.result_type),
                             sum(shape_bytes(comp.symbols.get(o, ""))
                                 for o in op.operands))
                    coll[ck] += m * cb
                    coll_count[ck] += int(m)
                    if "f32[" in op.result_type and not region:
                        coll_f32 += m * cb
                    gm = _GROUPS_RE.search(op.attrs)
                    if gm:
                        group_size[ck] = max(
                            group_size.get(ck, 0),
                            len([x for x in gm.group(1).split(",") if x]))
    # entry parameters are read (at least) once per step
    bytes_accessed += sum(shape_bytes(t) for t in entry.params.values())

    return {
        "flops": flops,
        "bytes": bytes_accessed,
        "collectives": coll,
        "collective_counts": coll_count,
        "collective_group_sizes": group_size,
        "fused_regions": fused,
        "bytes_f32_dots": bytes_f32_dots,
        "bytes_converts": bytes_converts,
        "collective_f32_bytes": coll_f32,
        "n_computations": len(comps),
    }


def tpu_dtype_corrected(analysis: dict, grad_dtype_f32: bool = False) -> dict:
    """Undo XLA-CPU bf16 legalization for the TPU roofline: f32 dot results
    halve to their semantic bf16 size, legalization converts vanish, and f32
    collectives (weight gathers / activation reduces that are bf16 on TPU)
    halve.  ``grad_dtype_f32``: archs accumulating f32 grads keep 25% of the
    f32-collective discount as genuinely-f32 gradient reductions (bounded
    estimate, stated in EXPERIMENTS.md)."""
    coll_discount = analysis["collective_f32_bytes"] * (0.5 if not grad_dtype_f32
                                                        else 0.375)
    total_coll = sum(analysis["collectives"].values())
    scale = (max(total_coll - coll_discount, 0.0) / total_coll
             if total_coll else 1.0)
    return {**analysis,
            "bytes": max(analysis["bytes"] - 0.5 * analysis["bytes_f32_dots"]
                         - analysis["bytes_converts"], 0.0),
            "collectives": {k: v * scale
                            for k, v in analysis["collectives"].items()}}


def kernelized(analysis: dict, causal_skip: float = 0.5) -> dict:
    """Adjusted totals when the marked regions run as the shipped Pallas
    kernels: region HBM traffic becomes VMEM-resident (boundary q/k/v/o
    writes are already counted outside the markers; the o-write is folded in,
    <1% error), and the kernels skip causally-masked blocks — the portable
    path computes them masked, so region dot FLOPs scale by ``causal_skip``.
    """
    out = dict(analysis)
    fbytes = sum(r["bytes"] for r in analysis.get("fused_regions", {}).values())
    fflops = sum(r["flops"] for r in analysis.get("fused_regions", {}).values())
    out = {**analysis,
           "bytes": max(analysis["bytes"] - fbytes, 0.0),
           "flops": max(analysis["flops"] - (1 - causal_skip) * fflops, 0.0)}
    return out


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (per the assignment)
ICI_LINKS = 4             # 2D torus: 4 links/chip usable


def roofline_terms(analysis: dict, model_flops_per_device: float | None = None,
                   dcn_bytes: float = 0.0, dcn_bw: float = 25e9) -> dict:
    """Convert per-device HLO totals into the three roofline times (s)."""
    compute_t = analysis["flops"] / PEAK_FLOPS
    memory_t = analysis["bytes"] / HBM_BW
    ici_bytes = sum(analysis["collectives"].values())
    collective_t = ici_bytes / (ICI_BW * ICI_LINKS) + dcn_bytes / dcn_bw
    bound = max(
        [("compute", compute_t), ("memory", memory_t),
         ("collective", collective_t)], key=lambda kv: kv[1])[0]
    out = {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": collective_t,
        "bound": bound,
        "hlo_flops": analysis["flops"],
        "hlo_bytes": analysis["bytes"],
        "collective_bytes": ici_bytes,
    }
    if model_flops_per_device:
        out["model_flops"] = model_flops_per_device
        out["useful_ratio"] = model_flops_per_device / max(analysis["flops"], 1.0)
        # roofline fraction: useful work time / achievable step time
        step_t = max(compute_t, memory_t, collective_t)
        out["roofline_fraction"] = (model_flops_per_device / PEAK_FLOPS) / max(step_t, 1e-12)
    return out
