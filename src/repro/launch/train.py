"""Production training driver.

Wires every subsystem together: config registry -> sharded params/optimizer ->
jitted train step (microbatched, optionally 8-bit moments) -> deterministic
data pipeline -> async checkpointing -> broker taps streaming to the Cloud
analysis plane -> failure detector heartbeats.

On a real TPU cluster this runs one process per host under the production
mesh; on CPU (CI / examples) pass ``--preset ci`` for a reduced config.

Usage:
  python -m repro.launch.train --arch starcoder2-3b --steps 100 --preset ci
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax

from repro import configs
from repro.checkpoint.ckpt import CheckpointManager
from repro.core.taps import TapStreamer
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as T
from repro.models.modules import materialize
from repro.models.steps import make_train_step
from repro.optim import adamw
from repro.runtime.fault import FailureDetector
from repro.workflow import Session, WorkflowConfig
from repro.analysis.dmd import StreamingDMD
from repro.analysis.metrics import unit_circle_distance


def dmd_analyzer(n_features: int):
    states: dict = {}

    def analyze(key, records):
        sd = states.setdefault(
            key, StreamingDMD(n_features=n_features, window=16, rank=4))
        # one device call per micro-batch (not per record)
        sd.update_batch([r.payload for r in
                         sorted(records, key=lambda r: r.step)])
        return unit_circle_distance(sd.eigenvalues())

    return analyze


def build(arch: str, preset: str, batch: int, seq: int, microbatches: int,
          mesh=None):
    cfg = configs.get(arch)
    if preset == "ci":
        cfg = cfg.reduced()
    constrain = T._ID
    if mesh is not None:
        from repro.launch.shardings import make_constrain
        constrain = make_constrain(mesh)
    params = materialize(T.build_specs(cfg), jax.random.key(0), cfg.dtype)
    opt_cfg = adamw.AdamWConfig(use_8bit=cfg.opt_8bit, lr=3e-3,
                                warmup_steps=20)
    opt = adamw.init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, microbatches, constrain))
    pipe = TokenPipeline(cfg, batch=batch, seq=seq)
    return cfg, params, opt, step_fn, pipe


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="starcoder2-3b")
    p.add_argument("--preset", default="ci", choices=["ci", "full"])
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--regions", type=int, default=4)
    p.add_argument("--no-broker", action="store_true")
    p.add_argument("--resume", action="store_true")
    args = p.parse_args(argv)

    cfg, params, opt, step_fn, pipe = build(
        args.arch, args.preset, args.batch, args.seq, args.microbatches)
    mgr = CheckpointManager(Path(args.ckpt_dir) / cfg.name)

    start = 0
    if args.resume and mgr.latest_step() is not None:
        tree, start = mgr.restore({"params": params, "opt": opt})
        params, opt = tree["params"], tree["opt"]
        print(f"[train] resumed from step {start}")

    session = streamer = None
    if not args.no_broker:
        workflow = WorkflowConfig(n_producers=args.regions,
                                  n_groups=max(1, args.regions // 4),
                                  executors_per_group=4,
                                  compress="int8+zstd", trigger_interval=1.0,
                                  n_executors=args.regions)
        session = Session(workflow,
                          analyze=dmd_analyzer(cfg.tap_snapshot_dim))
        streamer = TapStreamer(session, n_regions=args.regions)

    det = FailureDetector(timeout_s=30.0)
    det.register("trainer", "producer")

    t0 = time.time()
    for s in range(start, args.steps):
        params, opt, metrics, taps = step_fn(params, opt, pipe.batch_at(s))
        det.beat("trainer")
        if streamer is not None:
            streamer.publish(s, {"resid_norm": taps["resid_norm"],
                                 "snapshot": taps["snapshot"]})
        if (s + 1) % args.ckpt_every == 0:
            mgr.save(s + 1, {"params": params, "opt": opt})
        if s % 10 == 0 or s == args.steps - 1:
            print(f"[train] step {s} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(s-start+1):.2f}s/step)", flush=True)
    mgr.wait()

    if session is not None:
        stats = session.close()      # broker drain -> engine drain, in order
        panel = {}
        for r in session.results():
            if not isinstance(r.value, Exception):
                panel[r.stream_key] = r.value
        print("[analysis] per-region DMD stability "
              "(closer to 0 = more stable dynamics):")
        for k in sorted(panel):
            print(f"  {k:32s} {panel[k]:.5f}")
        print(f"[analysis] stream latency: {session.latency_stats()}")
        print(f"[broker] {stats}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
