"""Abstract (ShapeDtypeStruct) inputs for every model step — the dry-run feed.

Weak-type-correct, sharded, zero-allocation stand-ins for params, optimizer
state, batches and serve caches, per (arch x shape x mesh) cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.modules import ParamSpec, tree_map_specs
from repro.optim import adamw
from repro.launch.shardings import sharding_for, DEFAULT_RULES


def _sds(shape, dtype, mesh, axes, rules=None):
    return jax.ShapeDtypeStruct(
        tuple(shape), dtype, sharding=sharding_for(shape, axes, mesh, rules))


def abstract_params(cfg: ArchConfig, mesh, rules=None):
    specs = T.build_specs(cfg)

    def one(spec: ParamSpec):
        return _sds(spec.shape, spec.dtype or cfg.dtype, mesh, spec.axes, rules)

    return tree_map_specs(one, specs)


def abstract_opt_state(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig, mesh,
                       rules=None):
    """Mirrors adamw.init_opt_state structure without allocating."""
    specs = T.build_specs(cfg)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))

    def moment(spec: ParamSpec):
        if not opt_cfg.use_8bit:
            z = _sds(spec.shape, jnp.float32, mesh, spec.axes, rules)
            return {"m": z, "v": z}
        q = adamw.block_size(spec.shape[-1])
        data = _sds(spec.shape, jnp.int8, mesh, spec.axes, rules)
        scale = _sds((*spec.shape[:-1], spec.shape[-1] // q), jnp.float32,
                     mesh, spec.axes, rules)
        return {"m": adamw.Q8(data, scale, q), "r": adamw.Q8(data, scale, q)}

    return {"moments": [moment(s) for s in leaves],
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh, rules=None,
                with_labels: bool = True) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {}
    if cfg.frontend == "audio":
        batch["frames"] = _sds((B, S, cfg.d_model), cfg.dtype, mesh,
                               ("batch", "seq", None), rules)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32, mesh, ("batch", "seq"), rules)
    if cfg.frontend == "vision":
        batch["frontend"] = _sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                 cfg.dtype, mesh,
                                 ("batch", "frontend_seq", None), rules)
    if with_labels:
        batch["labels"] = _sds((B, S), jnp.int32, mesh, ("batch", "seq"), rules)
    return batch


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int, mesh, rules=None):
    specs = T.build_cache_specs(cfg, batch, max_seq)

    def one(spec: ParamSpec):
        return _sds(spec.shape, cfg.dtype, mesh, spec.axes, rules)

    return tree_map_specs(one, specs)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh, rules=None,
                opt_cfg: adamw.AdamWConfig | None = None) -> tuple:
    """Positional args matching repro.models.steps.step_for_shape."""
    params = abstract_params(cfg, mesh, rules)
    if shape.kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig(use_8bit=cfg.opt_8bit)
        opt = abstract_opt_state(cfg, opt_cfg, mesh, rules)
        return (params, opt, batch_specs(cfg, shape, mesh, rules))
    if shape.kind == "prefill":
        return (params, batch_specs(cfg, shape, mesh, rules, with_labels=False))
    # decode
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len, mesh, rules)
    tokens = _sds((shape.global_batch, 1), jnp.int32, mesh, ("batch", None), rules)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return (params, cache, tokens, pos)
