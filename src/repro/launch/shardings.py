"""Logical-axis -> mesh-axis rules with divisibility fallback.

Every parameter/activation/cache tensor carries logical axis names
(``repro.models`` SpecTrees).  ``spec_for`` greedily assigns each logical dim
the first mesh axes from its rule that (a) are present in the mesh, (b) are
not already used by another dim of the same tensor, and (c) evenly divide the
dim.  Indivisible dims fall back to replication — e.g. arctic's 56 q-heads
would replicate, which is why q-heads are padded to 64 upstream.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> ordered mesh-axis preferences (tuple => may stack axes)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),          # FSDP: weight d_model dims shard over data
    "heads": ("model",),
    "kv_heads": (),              # replicated; GQA broadcast is a local slice
    "head_dim": (),
    "ffn": ("model",),
    "ffn_e": (),                 # expert inner dim: model axis is taken by E
    "experts": ("model",),
    "vocab": ("model",),
    "inner": ("model",),         # mamba d_inner
    "mamba_heads": ("model",),
    "cache_seq": ("data",),      # seq-shard KV caches when batch can't use data
    "frontend_seq": (),
    "layers": (),
    "seq": (),
}


def spec_for(shape: tuple[int, ...], axes: tuple, mesh: Mesh,
             rules: dict | None = None) -> P:
    rules = rules or DEFAULT_RULES
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, axes):
        assigned: list[str] = []
        if name is not None:
            block = 1
            for ax in rules.get(name, ()):
                if ax in used or ax not in mesh.shape:
                    continue
                size = mesh.shape[ax]
                if dim % (block * size) == 0:
                    assigned.append(ax)
                    used.add(ax)
                    block *= size
        if not assigned:
            parts.append(None)
        elif len(assigned) == 1:
            parts.append(assigned[0])
        else:
            parts.append(tuple(assigned))
    return P(*parts)


def sharding_for(shape, axes, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(tuple(shape), tuple(axes), mesh, rules))


def make_constrain(mesh: Mesh, rules: dict | None = None):
    """Returns constrain(tensor, logical_axes) for in-graph use."""

    def constrain(t, axes):
        spec = spec_for(t.shape, tuple(axes), mesh, rules)
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    return constrain
