"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state.  Dry-run processes set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips single pod; (2,16,16) = 512 two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for subprocess integration tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
