"""Batched serving driver: prefill + decode loop with serving-time broker
telemetry (per-layer residual norms streamed per decode step — the paper's
"insight into a running job", applied to inference).

Usage:
  python -m repro.launch.serve --arch starcoder2-3b --preset ci \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as T
from repro.models.modules import materialize
from repro.models.steps import make_decode_step, make_prefill_step


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="starcoder2-3b")
    p.add_argument("--preset", default="ci", choices=["ci", "full"])
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    args = p.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.preset == "ci":
        cfg = cfg.reduced()
    params = materialize(T.build_specs(cfg), jax.random.key(0), cfg.dtype)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    pipe = TokenPipeline(cfg, batch=args.batch, seq=args.prompt_len)
    batch = pipe.batch_at(0)
    batch.pop("labels", None)

    t0 = time.time()
    logits, cache, _ = prefill(params, batch)
    # pre-extend caches with generation room
    def extend(c):
        if c.ndim == 5 and c.shape[2] == args.prompt_len:
            return jnp.pad(c, [(0, 0), (0, 0), (0, args.gen), (0, 0), (0, 0)])
        return c
    cache = jax.tree.map(extend, cache)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    seqs = [np.asarray(tok[:, 0])]
    t0 = time.time()
    norms = []
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        nxt, cache, taps = decode(params, cache, tok, pos)
        norms.append(np.asarray(taps["resid_norm"]).mean())
        tok = nxt[:, None]
        seqs.append(np.asarray(nxt))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = np.stack(seqs, axis=1)
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode/max(args.gen-1,1)*1e3:.2f} ms/token "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print(f"[serve] telemetry: mean residual norm per step = "
          f"{np.mean(norms):.3f} (streamed to broker in production)")
    print(f"[serve] sample continuation ids: {out[0][:12].tolist()}")
    return out


if __name__ == "__main__":
    main()
