"""Batched serving driver: prefill + decode loop with serving-time broker
telemetry — per-layer residual norms streamed through a workflow Session per
decode step (the paper's "insight into a running job", applied to inference).

Usage:
  python -m repro.launch.serve --arch starcoder2-3b --preset ci \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as T
from repro.models.modules import materialize
from repro.models.steps import make_decode_step, make_prefill_step
from repro.workflow import OperatorPipeline, Session, WorkflowConfig


def _telemetry_pipeline():
    """norms (mean per micro-batch) -> drift (|latest-first| across the whole
    decode: the stage keeps the first-seen mean per stream, so each sink
    value is cumulative, and latest() reports drift over the full loop).
    Both stages are stateful per stream, hence the ordered contract."""
    first_seen = {}

    def norms_stage(key, records):
        recs = sorted(records, key=lambda r: r.step)
        return [float(np.asarray(r.payload).mean()) for r in recs]

    def drift_stage(key, means):
        first = first_seen.setdefault(key, means[0])
        return abs(means[-1] - first)

    return (OperatorPipeline(granularity="batch")
            .map("norms", norms_stage, ordering="ordered")
            .map("drift", drift_stage, ordering="ordered")
            .sink("drift_panel"))


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="starcoder2-3b")
    p.add_argument("--preset", default="ci", choices=["ci", "full"])
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--no-broker", action="store_true")
    args = p.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.preset == "ci":
        cfg = cfg.reduced()
    params = materialize(T.build_specs(cfg), jax.random.key(0), cfg.dtype)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    session = resid = None
    if not args.no_broker:
        workflow = WorkflowConfig(n_producers=1, n_groups=1,
                                  executors_per_group=1, compress="none",
                                  trigger_interval=0.1, min_batch=4,
                                  n_executors=1)
        session = Session(workflow, pipeline=_telemetry_pipeline())
        resid = session.open_field("resid_norm")

    pipe = TokenPipeline(cfg, batch=args.batch, seq=args.prompt_len)
    batch = pipe.batch_at(0)
    batch.pop("labels", None)

    t0 = time.time()
    logits, cache, _ = prefill(params, batch)
    # pre-extend caches with generation room
    def extend(c):
        if c.ndim == 5 and c.shape[2] == args.prompt_len:
            return jnp.pad(c, [(0, 0), (0, 0), (0, args.gen), (0, 0), (0, 0)])
        return c
    cache = jax.tree.map(extend, cache)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    seqs = [np.asarray(tok[:, 0])]
    t0 = time.time()
    norms = []
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        nxt, cache, taps = decode(params, cache, tok, pos)
        resid_norm = np.asarray(taps["resid_norm"])
        norms.append(resid_norm.mean())
        if resid is not None:    # per-layer means, streamed in-flight
            resid.write(args.prompt_len + i, resid_norm.mean(axis=1))
        tok = nxt[:, None]
        seqs.append(np.asarray(nxt))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = np.stack(seqs, axis=1)
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode "
          f"{t_decode/max(args.gen-1,1)*1e3:.2f} ms/token "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    if session is not None:
        stats = session.close()
        drift = session.exec_plan.latest("drift_panel")
        print(f"[serve] telemetry: mean residual norm per step = "
              f"{np.mean(norms):.3f}; residual drift over decode = "
              f"{max(drift.values(), default=0.0):.4f} "
              f"({stats.sent} records / {stats.frames_sent} frames on the wire)")
    else:
        print(f"[serve] telemetry: mean residual norm per step = "
              f"{np.mean(norms):.3f} (broker disabled)")
    print(f"[serve] sample continuation ids: {out[0][:12].tolist()}")
    return out


if __name__ == "__main__":
    main()
