"""Multi-pod dry-run driver.

For each (arch x shape x mesh) cell: lower the real step function against
abstract sharded inputs, ``.compile()`` it, record ``memory_analysis()`` /
``cost_analysis()``, and run the per-device HLO roofline extractor
(``repro.launch.hlo_analysis``).  Artifacts land in
``benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json`` (+ zstd HLO text
for offline re-analysis during perf iterations).

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all
"""
from __future__ import annotations

import os
# MUST precede any jax import: jax locks the device count on first init.
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.configs.base import SHAPES
from repro.launch import hlo_analysis
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import make_constrain
from repro.models.steps import step_for_shape

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path = DEFAULT_OUT, save_hlo: bool = True,
             tag: str = "", cfg_override=None) -> dict:
    cfg = cfg_override or configs.get(arch)
    cells = {s.name: s for s in cfg.shape_cells()}
    if shape_name not in cells:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention"}
    shape = cells[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    n_dev = mesh.size

    t0 = time.time()
    step = step_for_shape(cfg, shape, constrain=make_constrain(mesh))
    args = input_specs(cfg, shape, mesh)
    donate = (0, 1) if shape.kind == "train" else ((1,) if shape.kind == "decode" else ())
    with mesh:
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    analysis = hlo_analysis.analyze(text)
    model_flops = cfg.model_flops(shape) / n_dev
    terms = hlo_analysis.roofline_terms(analysis, model_flops)
    # TPU-adjusted: Pallas-kernel regions fused + CPU bf16-legalization undone
    adjusted = hlo_analysis.tpu_dtype_corrected(
        hlo_analysis.kernelized(analysis),
        grad_dtype_f32=(shape.kind == "train" and not cfg.opt_8bit))
    terms_kernel = hlo_analysis.roofline_terms(adjusted, model_flops)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "kind": shape.kind,
        "microbatches": shape.microbatches,
        "n_devices": n_dev,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
        },
        "xla_cost_analysis": {k: v for k, v in cost.items()
                              if k in ("flops", "bytes accessed")},
        "hlo_analysis": analysis,
        "roofline": terms,
        "roofline_kernelized": terms_kernel,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    stem = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    (out_dir / f"{stem}.json").write_text(json.dumps(result, indent=1))
    if save_hlo:
        try:
            import zstandard
            (out_dir / f"{stem}.hlo.zst").write_bytes(
                zstandard.ZstdCompressor(level=3).compress(text.encode()))
        except Exception:
            pass
    return result


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", help="architecture id (see repro.configs)")
    p.add_argument("--shape", help="shape cell name", default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true", help="all cells, both meshes")
    p.add_argument("--out", default=str(DEFAULT_OUT))
    p.add_argument("--no-hlo", action="store_true")
    p.add_argument("--tag", default="", help="artifact suffix (perf iterations)")
    args = p.parse_args()
    out = Path(args.out)

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in configs.list_archs():
            for s in configs.get(arch).shape_cells():
                cells.append((arch, s.name, False))
                cells.append((arch, s.name, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch} x {shape} x {'multipod' if mp else 'pod'}"
        try:
            r = run_cell(arch, shape, mp, out, save_hlo=not args.no_hlo,
                         tag=args.tag)
            rf = r.get("roofline", {})
            print(f"[dryrun] OK {tag}: bound={rf.get('bound')} "
                  f"compute={rf.get('compute_s', 0):.4f}s "
                  f"mem={rf.get('memory_s', 0):.4f}s "
                  f"coll={rf.get('collective_s', 0):.4f}s "
                  f"compile={r.get('compile_s')}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"[dryrun] FAIL {tag}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
