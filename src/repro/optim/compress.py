"""int8-compressed cross-pod gradient reduction.

The `pod` mesh axis crosses DCN (~25 GB/s vs ~200 GB/s ICI), so the cross-pod
gradient all-reduce is the slowest collective of a multi-pod step.  This
module reduces it with blockwise-int8 compression (the same codec as
``kernels/quant`` / 8-bit moments): under ``shard_map`` over the pod axis,
each pod quantizes its local gradient, all-gathers int8 data + f32 block
scales (4x fewer bytes than f32, 2x fewer than bf16), and dequant-averages
locally.

Error feedback (residual carried to the next step) keeps the compression
unbiased over time — standard distributed-SGD practice.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import QBLOCK

F32 = jnp.float32


def _q8_flat(x):
    """Flatten + blockwise int8. Returns (data int8 (nb,Q), scales (nb,), n)."""
    flat = x.astype(F32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % QBLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-20) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale, n


def _dq8_flat(q, scale, n, shape):
    flat = (q.astype(F32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def compressed_pod_mean(grads, mesh, axis: str = "pod"):
    """Mean-reduce a gradient pytree across ``axis`` with int8 payloads.

    Each leaf must already be replicated across ``axis`` up to the summand
    (i.e. per-pod partial gradients).  Returns the pod-mean with the same
    shardings on the remaining axes.
    """
    n_pods = mesh.shape[axis]
    if n_pods == 1:
        return grads

    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    def reduce_leaf(g):
        spec = P(*([None] * g.ndim))

        @partial(jax.shard_map, mesh=mesh, in_specs=spec, out_specs=spec,
                 check_vma=False)
        def go(local):
            q, s, n = _q8_flat(local)
            qs = jax.lax.all_gather(q, axis)          # (pods, nb, Q) int8
            ss = jax.lax.all_gather(s, axis)          # (pods, nb) f32
            total = jnp.zeros(local.shape, F32)
            for p in range(n_pods):
                total = total + _dq8_flat(qs[p], ss[p], n, local.shape)
            return (total / n_pods).astype(local.dtype)

        return go(g)

    return jax.tree.map(reduce_leaf, grads)


class ErrorFeedback:
    """Residual accumulator making compressed reductions unbiased over time:
    send quantize(g + e); e' = (g + e) - dequantize(sent)."""

    @staticmethod
    def init(grads):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads)

    @staticmethod
    def apply(grads, residual):
        corrected = jax.tree.map(lambda g, e: g.astype(F32) + e, grads, residual)

        def roundtrip(x):
            q, s, n = _q8_flat(x)
            return _dq8_flat(q, s, n, x.shape)

        sent = jax.tree.map(roundtrip, corrected)
        new_residual = jax.tree.map(lambda c, s_: c - s_, corrected, sent)
        return sent, new_residual
