"""AdamW on parameter pytrees, with optional 8-bit block-quantized moments.

The 8-bit path stores m/v as int8 + per-block f32 scales (block = 256
elements along the flattened trailing axis), the same scheme as the
``repro.kernels.quant`` Pallas kernel uses on real TPU for stream-record and
cross-pod gradient compression.  For the 398–480B archs this is what makes
optimizer state fit 16 GB v5e HBM at 256-way sharding:
bf16 params (2) + grads (2) + int8 m (1) + int8 v (1) ≈ 6 bytes/param.

Optimizer moments are stored as a *list aligned with the flattened param
leaves* (not a mirrored tree) so quantized and dense leaves coexist cleanly.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

F32 = jnp.float32
QBLOCK = 256


# ---------------------------------------------------------------------------
# 8-bit blockwise codec (pure jnp; kernels/quant.py is the TPU Pallas version)
# ---------------------------------------------------------------------------

def block_size(last_dim: int, max_shards: int = 16) -> int:
    """Quantization block along the last axis: the largest power-of-2 divisor
    of the *per-shard* extent (assuming up to ``max_shards``-way sharding),
    capped at QBLOCK — so the block reshape never crosses shard boundaries and
    the moments keep exactly the param's sharding."""
    l = last_dim // max_shards if last_dim % max_shards == 0 else last_dim
    q = 1
    while l % 2 == 0 and q < QBLOCK:
        q *= 2
        l //= 2
    return max(q, 1)


@jax.tree_util.register_pytree_node_class
class Q8:
    """int8 blockwise tensor.  ``data`` keeps the ORIGINAL param shape (and
    thus the param's sharding); ``scale`` is f32 per block of ``q`` along the
    last axis: shape = data.shape[:-1] + (last/q,)."""

    def __init__(self, data, scale, q):
        self.data = data
        self.scale = scale
        self.q = int(q)

    def tree_flatten(self):
        return (self.data, self.scale), self.q

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    def __repr__(self):
        return f"Q8(shape={getattr(self.data, 'shape', '?')}, q={self.q})"


def q8_encode(x: jax.Array) -> Q8:
    shape = x.shape
    q = block_size(shape[-1])
    blocks = x.astype(F32).reshape(*shape[:-1], shape[-1] // q, q)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1), 1e-20) / 127.0
    data = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    return Q8(data.astype(jnp.int8).reshape(shape), scale, q)


def q8_decode(z: Q8) -> jax.Array:
    shape = z.data.shape
    blocks = z.data.astype(F32).reshape(*shape[:-1], shape[-1] // z.q, z.q)
    return (blocks * z.scale[..., None]).reshape(shape)


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, F32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    use_8bit: bool = False


def _decays(path) -> bool:
    """Weight decay only for matmul weights (skip norms / SSM scalars)."""
    name = str(path[-1]) if path else ""
    return not any(s in name for s in ("norm", "A_log", "'D'", "dt_bias", "embed"))


def init_opt_state(cfg: AdamWConfig, params):
    """8-bit moments store m and sqrt(v): quantizing the *root* halves v's
    dynamic range in log-space, so small-|g| elements don't round to zero
    inside large blocks (which would explode m/sqrt(v) — the classic 8-bit
    Adam failure).  Update clipping below is the second guard."""
    def one(p):
        z = jnp.zeros(p.shape, F32)
        if cfg.use_8bit:
            return {"m": q8_encode(z), "r": q8_encode(z)}
        return {"m": z, "v": z}

    moments = [one(p) for p in jax.tree.leaves(params)]
    return {"moments": moments, "step": jnp.zeros((), jnp.int32)}


def apply_updates(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg.lr, cfg.warmup_steps, cfg.total_steps)(step)

    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_leaves = jax.tree.leaves(grads)
    assert len(g_leaves) == len(paths_and_leaves)

    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(F32) ** 2) for g in g_leaves))
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    bc1 = 1 - cfg.b1 ** step.astype(F32)
    bc2 = 1 - cfg.b2 ** step.astype(F32)

    new_p_leaves, new_moments = [], []
    for (path, p), g, mo in zip(paths_and_leaves, g_leaves, opt_state["moments"]):
        g = g.astype(F32) * clip
        if cfg.use_8bit:
            m = q8_decode(mo["m"])
            r = q8_decode(mo["r"])
            v = r * r
        else:
            m, v = mo["m"], mo["v"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        # Adafactor-style update clipping: |m|/sqrt(v) ~ O(1); anything far
        # beyond is quantization/denominator noise
        u = jnp.clip(u, -5.0, 5.0)
        if _decays(path):
            u = u + cfg.weight_decay * p.astype(F32)
        new_p_leaves.append((p.astype(F32) - lr * u).astype(p.dtype))
        if cfg.use_8bit:
            new_moments.append({"m": q8_encode(m), "r": q8_encode(jnp.sqrt(v))})
        else:
            new_moments.append({"m": m, "v": v})

    new_params = jax.tree_util.tree_unflatten(treedef, new_p_leaves)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"moments": new_moments, "step": step}, metrics
