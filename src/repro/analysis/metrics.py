"""Analysis metrics — the paper's Fig-5 stability score.

"Each subplot shows the average sum of square distances from eigenvalues to
the unit circle of that region.  Values closer to 0 mean fluids in that
region are more stable."
"""
from __future__ import annotations

import numpy as np


def unit_circle_distance(eigs: np.ndarray) -> float:
    """mean over (finite) eigenvalues of (|lambda| - 1)^2.

    NaN entries are rank-padding from the online-DMD solver and are ignored.
    """
    eigs = np.asarray(eigs)
    eigs = eigs[np.isfinite(eigs)]
    if eigs.size == 0:
        return 0.0
    return float(np.mean((np.abs(eigs) - 1.0) ** 2))


def region_stability(eigs_by_region: dict) -> dict:
    """Fig-5 panel: region key -> stability score."""
    return {k: unit_circle_distance(v) for k, v in eigs_by_region.items()}
