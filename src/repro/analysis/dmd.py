"""Dynamic Mode Decomposition in JAX — the paper's Cloud-side analysis.

Three implementations:

* ``exact_dmd`` — PyDMD-equivalent batch DMD on a snapshot window
  (SVD -> low-rank operator -> eigenvalues), jitted.
* ``window_dmd`` / ``batched_window_dmd`` — the stream-operator entry
  points.  Both route through the *method-of-snapshots* solve
  ``_masked_window_eigs``: eigenvalues come from the (m, m) snapshot Gram
  matrix instead of the (d, m) SVD, so a window of d=512 features costs one
  ``(d, m)·(d, m)`` einsum plus small-matrix eigendecompositions.  Because
  validity is a mask rather than a shape, panes are zero-padded to
  power-of-two buckets (features, snapshots, and — for the batched entry —
  pane count), the jit cache stays O(log) across ragged windows, and
  ``batched_window_dmd`` vmaps the whole solve across co-fired panes in a
  single device dispatch.
* ``StreamingDMD`` — online DMD over unbounded streams: Gram updates
  G += XᵀX, A += YᵀX over snapshot-pair blocks, eigenvalues from the
  Gram-space operator.  This is what each stream's executor runs per
  micro-batch.

``StreamingDMD`` is **device-resident**: G and A live as ``jax.Array`` and
never round-trip through the host between updates.  The batched entry point
``update_batch((n, d) snapshots)`` forms the shifted X/Y pair in one shot
and issues a single device call per micro-batch — the fused Pallas
``gram_pair`` kernel (kernels/gram.py) on TPU, a jitted jnp matmul pair
elsewhere — instead of one ``G += x xᵀ, A += y xᵀ`` dispatch (plus four
host↔device transfers) per snapshot.  The update **donates** G/A into the
jitted accumulator (``donate_argnums``) so XLA updates them in place
instead of allocating a fresh (d, d) pair per micro-batch, and
``eigenvalues()`` caches its last solve until the next update lands.
``h2d_transfers`` / ``d2h_transfers`` / ``device_calls`` counters make the
savings measurable (benchmarks/kernels_bench.py writes them to
BENCH_hotpath.json / BENCH_multikey.json).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


@partial(jax.jit, static_argnames=("rank",))
def exact_dmd(snapshots: jax.Array, rank: int = 8):
    """snapshots: (n_features, n_steps).  Returns (eigenvalues, energy).

    X = snaps[:, :-1], Y = snaps[:, 1:];  A~ = Uᵀ Y V S⁻¹ (rank-truncated).
    """
    X = snapshots[:, :-1].astype(F32)
    Y = snapshots[:, 1:].astype(F32)
    U, S, Vt = jnp.linalg.svd(X, full_matrices=False)
    r = min(rank, S.shape[0])
    U, S, Vt = U[:, :r], S[:r], Vt[:r]
    Sinv = jnp.where(S > 1e-10, 1.0 / S, 0.0)
    Atilde = U.T @ Y @ Vt.T * Sinv[None, :]
    eigs = jnp.linalg.eigvals(Atilde)
    energy = jnp.sum(S[:r] ** 2) / jnp.maximum(jnp.sum(S ** 2), 1e-30)
    return eigs, energy


@jax.jit
def gram_update(G: jax.Array, A: jax.Array, x: jax.Array, y: jax.Array):
    """Rank-1 online-DMD update: G += x xᵀ, A += y xᵀ (single-pair oracle)."""
    return G + jnp.outer(x, x), A + jnp.outer(y, x)


def _gram_pair_raw(G: jax.Array, A: jax.Array, X: jax.Array, Y: jax.Array):
    """Batched online-DMD update: G += XᵀX, A += YᵀX over (n, d) pair blocks.

    The portable jnp form of the fused Pallas ``gram_pair`` kernel
    (kernels/gram.py) and its allclose oracle.  All-zero padding rows are
    no-ops in both products, so callers may pad n freely."""
    Xf, Yf = X.astype(F32), Y.astype(F32)
    return G + Xf.T @ Xf, A + Yf.T @ Xf


gram_pair_update = jax.jit(_gram_pair_raw)
# donated flavor: XLA reuses the incoming G/A buffers for the outputs —
# the hot loop stops allocating a fresh (d, d) pair per micro-batch.
# Callers must not read the donated arrays afterwards (StreamingDMD
# rebinds self._G/_A to the results, so nothing ever does).
gram_pair_update_donated = jax.jit(_gram_pair_raw, donate_argnums=(0, 1))


@partial(jax.jit, static_argnames=("rank",))
def gram_eigs(G: jax.Array, A: jax.Array, rank: int = 8,
              rel_tol: float = 1e-7):
    """Eigenvalues of the online-DMD operator, rank-truncated.

    G = X Xᵀ (PSD), A = Y Xᵀ.  Project onto G's dominant eigenspace U_r
    (anything else is noise-nullspace and would blow up the pseudo-inverse):
    M_r = U_rᵀ A U_r diag(1/s_r);  eig(M_r)."""
    s, U = jnp.linalg.eigh(G)                    # ascending
    s = s[::-1]
    U = U[:, ::-1]
    r = min(rank, G.shape[0])
    s_r, U_r = s[:r], U[:, :r]
    good = s_r > rel_tol * jnp.maximum(s_r[0], 1e-30)
    inv = jnp.where(good, 1.0 / jnp.maximum(s_r, 1e-30), 0.0)
    M = (U_r.T @ A @ U_r) * inv[None, :]
    eigs = jnp.linalg.eigvals(M)
    # null directions are padded with NaN — consumers (metrics, tests) filter
    # non-finite entries, so rank padding never reads as (in)stability
    return jnp.where(good, eigs, jnp.nan + 0.0j)


def _pad_rows(n: int) -> int:
    """Round a batch size up to the next power of two so the jitted update
    compiles O(log n) variants instead of one per micro-batch size."""
    return 1 << max(0, n - 1).bit_length()


def _pad_cols(n: int, minimum: int = 4) -> int:
    """Power-of-two bucket for a pane's snapshot count (floor ``minimum``
    so the tiniest legal pane, 3 snapshots, shares a bucket with 4)."""
    return max(minimum, _pad_rows(n))


def _masked_window_eigs(snaps: jax.Array, n_valid: jax.Array,
                        rank: int, rel_tol: float = 1e-5):
    """Windowed DMD on a zero-padded (d, m) pane, method of snapshots.

    ``snaps`` holds ``n_valid`` real snapshot columns followed by zero
    padding; ``rank``/shapes are static, ``n_valid`` is data, so one
    compiled variant serves every pane in the same (d, m) bucket and the
    whole thing vmaps across panes.

    ``rel_tol`` applies to s² (the Gram eigenvalues): 1e-5 relative sits
    safely above the f32 ``eigh`` noise floor (~machine-eps relative, so a
    rank-deficient pane's junk directions straddle a 1e-7 cutoff and would
    leak spurious near-zero eigenvalues into the spectrum).

    Exactness: with X = snaps[:, :n-1], Y = snaps[:, 1:n], exact DMD's
    reduced operator is A~ = Uᵀ Y V S⁻¹ with X = U S Vᵀ.  Substituting
    Uᵀ = S⁻¹ Vᵀ Xᵀ gives A~' = S⁻¹ Vᵀ (XᵀY) V S⁻¹ — similar to A~ (same
    eigenvalues), and V/S² are the eigenvectors/eigenvalues of the small
    (m-1)² Gram XᵀX.  Zero feature rows change neither Gram; zero snapshot
    columns are removed by masking column ``n_valid - 1`` of X (the one
    padded position that holds real data) out of both Grams.  Spurious
    directions (beyond the pane's true pair count or below ``rel_tol``)
    are zeroed out of the operator — block-triangular, so they contribute
    exact-zero eigenvalues — then the magnitude-descending sort pushes
    them last and they are masked to NaN, which consumers already filter.
    """
    m = snaps.shape[1]
    P = snaps.T @ snaps                           # (m, m) snapshot Gram
    lane = jnp.arange(m - 1)
    colmask = (lane < n_valid - 1).astype(F32)    # valid X columns
    mm = colmask[:, None] * colmask[None, :]
    G = P[:-1, :-1] * mm                          # XᵀX
    C = P[:-1, 1:] * mm                           # XᵀY
    s2, V = jnp.linalg.eigh(G)                    # ascending
    r = min(rank, m - 1)
    s2_r = s2[-r:][::-1]                          # top-r, descending
    V_r = V[:, -r:][:, ::-1]
    good = ((jnp.arange(r) < n_valid - 1)
            & (s2_r > rel_tol * jnp.maximum(s2_r[0], 1e-30)))
    sinv = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(s2_r, 1e-30)), 0.0)
    M = (V_r.T @ C @ V_r) * (sinv[:, None] * sinv[None, :])
    gm = good.astype(F32)
    M = M * (gm[:, None] * gm[None, :])
    eigs = jnp.linalg.eigvals(M)
    eigs = eigs[jnp.argsort(-jnp.abs(eigs))]
    return jnp.where(jnp.arange(r) < jnp.sum(good), eigs, jnp.nan + 0.0j)


_window_solve = jax.jit(_masked_window_eigs, static_argnames=("rank",))

# one vmapped+jitted solver per rank (rank is a config constant in
# practice, so this dict stays O(1); the jit cache under each entry stays
# O(log) thanks to power-of-two (k, d, m) bucketing by the callers)
_BATCH_SOLVERS: dict[int, object] = {}


def _batched_solver(rank: int):
    fn = _BATCH_SOLVERS.get(rank)
    if fn is None:
        fn = jax.jit(jax.vmap(partial(_masked_window_eigs, rank=rank)))
        _BATCH_SOLVERS[rank] = fn
    return fn


def _pane_rows(snapshots) -> list[np.ndarray]:
    return [np.asarray(s, np.float32).reshape(-1) for s in snapshots]


def _fill_pane(out: np.ndarray, rows: list[np.ndarray], d: int) -> None:
    """Write a pane's snapshots into the (d_pad, m_pad) zero slab ``out``."""
    if rows and all(r.size == rows[0].size for r in rows):
        w = min(rows[0].size, d)        # uniform width: one C-level copy
        out[:w, : len(rows)] = np.stack(rows, axis=1)[:w]
        return
    for j, r in enumerate(rows):
        r = r[:d]
        out[: r.size, j] = r


def window_dmd(snapshots, rank: int = 8,
               n_features: int | None = None) -> np.ndarray:
    """Batch DMD over one window pane — the stream-operator entry point.

    ``snapshots``: iterable of 1-D arrays (a fired window's values, e.g.
    record payloads in step order).  Each is flattened and trimmed /
    zero-padded to ``n_features`` (default: the longest snapshot).  The
    pane is zero-padded to a power-of-two (d, m) bucket before the masked
    solve, so sliding windows with ragged tails reuse O(log) compiled
    variants instead of one per pane size.  Windows shorter than 3
    snapshots can't form a snapshot pair worth solving — returns the same
    zero sentinel ``StreamingDMD.eigenvalues`` uses.  Null/padded
    directions come back NaN; consumers filter non-finite entries."""
    rows = _pane_rows(snapshots)
    if len(rows) < 3:
        return np.zeros(1, np.complex64)
    d = max(r.size for r in rows) if n_features is None else int(n_features)
    m = len(rows)
    pane = np.zeros((_pad_rows(max(d, 1)), _pad_cols(m)), np.float32)
    _fill_pane(pane, rows, d)
    eigs = _window_solve(jnp.asarray(pane), jnp.int32(m), rank=rank)
    return np.asarray(eigs)


def batched_window_dmd(panes, rank: int = 8,
                       n_features: int | None = None) -> list[np.ndarray]:
    """Multi-key windowed DMD: solve many co-fired panes in one dispatch.

    ``panes``: sequence of snapshot iterables (one fired pane per key /
    stream).  Panes are zero-padded into power-of-two (k, d, m) buckets and
    each bucket goes through one vmapped ``_masked_window_eigs`` call —
    k ragged panes cost O(distinct m-buckets) dispatches instead of k.
    Returns one eigenvalue array per pane, in input order; panes shorter
    than 3 snapshots get the zero sentinel, padding slots inside a bucket
    are solved as empty panes and discarded."""
    pane_rows = [_pane_rows(p) for p in panes]
    out: list[np.ndarray | None] = [None] * len(pane_rows)
    if n_features is None:
        sizes = [r.size for rows in pane_rows for r in rows]
        d = max(sizes) if sizes else 1
    else:
        d = int(n_features)
    buckets: dict[int, list[int]] = {}
    for i, rows in enumerate(pane_rows):
        if len(rows) < 3:
            out[i] = np.zeros(1, np.complex64)
        else:
            buckets.setdefault(_pad_cols(len(rows)), []).append(i)
    # fold buckets one power-of-two level apart into the wider one: the
    # masked solve makes extra column padding exactly invariant, and one
    # slightly wider slab beats a whole extra dispatch for the narrow panes
    grouped: list[tuple[int, list[int]]] = []
    for mp in sorted(buckets, reverse=True):
        if grouped and mp * 2 >= grouped[-1][0]:
            grouped[-1][1].extend(buckets[mp])
        else:
            grouped.append((mp, list(buckets[mp])))
    dp = _pad_rows(max(d, 1))
    solver = _batched_solver(rank)
    pending = []                          # dispatch all, then sync once
    for mp, idxs in grouped:
        kp = _pad_rows(len(idxs))
        slab = np.zeros((kp, dp, mp), np.float32)
        nv = np.zeros(kp, np.int32)       # padding panes solve as empty
        for slot, i in enumerate(idxs):
            _fill_pane(slab[slot], pane_rows[i], d)
            nv[slot] = len(pane_rows[i])
        pending.append((idxs, solver(jnp.asarray(slab), jnp.asarray(nv))))
    for idxs, dev_eigs in pending:
        eigs = np.asarray(dev_eigs)
        for slot, i in enumerate(idxs):
            out[i] = eigs[slot]
    return out   # type: ignore[return-value]


def make_dmd_aggregate(rank: int = 8, n_features: int | None = None,
                       prepare=None):
    """Build the batch function for a ``BatchAggregate`` window consumer.

    Returns ``fn(items) -> list[np.ndarray]`` with ``items`` a list of
    ``(key, values)`` pairs (the BatchAggregate contract); ``prepare``
    (optional) maps a pane's value list to its snapshot iterable first
    (e.g. ``lambda vals: [r.payload for r in vals]``).  Co-fired panes
    across keys coalesce into one vmapped device dispatch — wire it as
    ``BatchAggregate("dmd", make_dmd_aggregate(...))``."""
    def batch_fn(items):
        panes = [prepare(v) if prepare is not None else v for _k, v in items]
        return batched_window_dmd(panes, rank=rank, n_features=n_features)
    return batch_fn


@dataclass
class StreamingDMD:
    """Per-stream online DMD state (executor-side), device-resident.

    ``use_kernel``: None = auto (fused Pallas kernel on TPU, jnp matmuls
    elsewhere — interpret-mode Pallas is not a hot-path option on CPU);
    True/False forces the choice (tests force True to exercise the kernel).
    ``donate``: donate G/A buffers into the jitted update so XLA reuses
    them in place (set False only when holding external references to the
    internal Gram arrays across updates).
    """

    n_features: int
    window: int = 32                 # snapshots kept for exact re-solves
    rank: int = 8
    use_kernel: bool | None = None
    donate: bool = True
    _buf: list = field(default_factory=list)
    _G: jax.Array | None = None      # (d, d) Gram, lives on device
    _A: jax.Array | None = None      # (d, d) cross-Gram, lives on device
    last_snapshot: np.ndarray | None = None
    n_seen: int = 0
    # eigensolve cache: valid until the next update lands
    _eigs_cache: np.ndarray | None = None
    _eigs_seen: int = -1             # n_seen at the time of the cached solve
    # hot-path accounting (BENCH_hotpath.json scoreboard)
    h2d_transfers: int = 0
    d2h_transfers: int = 0
    device_calls: int = 0

    def _coerce(self, snapshot) -> np.ndarray:
        x = np.asarray(snapshot, np.float32).reshape(-1)[: self.n_features]
        if x.size < self.n_features:   # short payloads embed zero-padded
            x = np.pad(x, (0, self.n_features - x.size))
        return x

    def _coerce_block(self, snaps) -> np.ndarray:
        """(n, d) float32 block from any snapshot batch.  A 2-D ndarray of
        matching width takes the no-copy fast path — the per-row python
        loop is what BENCH_hotpath's update_only section times at d=512."""
        if isinstance(snaps, np.ndarray) and snaps.ndim == 2:
            arr = snaps.astype(np.float32, copy=False)
            d = self.n_features
            if arr.shape[1] > d:
                arr = arr[:, :d]
            elif arr.shape[1] < d:
                arr = np.pad(arr, ((0, 0), (0, d - arr.shape[1])))
            return arr
        rows = [self._coerce(s) for s in snaps]
        if not rows:
            return np.empty((0, self.n_features), np.float32)
        return np.stack(rows)

    def _apply_pair_block(self, X: np.ndarray, Y: np.ndarray) -> None:
        """One device call: G += XᵀX, A += YᵀX for an (n, d) pair block."""
        d = self.n_features
        if self._G is None:
            self._G = jnp.zeros((d, d), F32)
            self._A = jnp.zeros((d, d), F32)
        Xd, Yd = jnp.asarray(X), jnp.asarray(Y)
        self.h2d_transfers += 2
        self.device_calls += 1
        use_kernel = (self.use_kernel if self.use_kernel is not None
                      else jax.default_backend() == "tpu")
        if use_kernel:
            from repro.kernels import ops
            fn = (ops.gram_pair_accumulate_donated if self.donate
                  else ops.gram_pair_accumulate)
            self._G, self._A = fn(Xd, Yd, self._G, self._A)
        else:
            fn = gram_pair_update_donated if self.donate else gram_pair_update
            self._G, self._A = fn(self._G, self._A, Xd, Yd)

    def update(self, snapshot: np.ndarray) -> None:
        """Single-snapshot update (legacy per-record path)."""
        self.update_batch([snapshot])

    def update_batch(self, snaps) -> None:
        """Batched update: ``snaps`` is an (n, d) array or list of snapshots
        (each trimmed/zero-padded to ``n_features``).  Forms the shifted
        X = chain[:-1], Y = chain[1:] pair — chaining through the previous
        batch's last snapshot — and applies it in one device call."""
        block = self._coerce_block(snaps)
        if block.shape[0] == 0:
            return
        if self.last_snapshot is not None:
            chain = np.concatenate([self.last_snapshot[None], block])
        else:
            chain = block
        X, Y = chain[:-1], chain[1:]
        n = X.shape[0]
        if n:
            m = _pad_rows(n)
            if m != n:   # zero rows contribute nothing to XᵀX / YᵀX
                pad = np.zeros((m - n, self.n_features), np.float32)
                X = np.concatenate([X, pad])
                Y = np.concatenate([Y, pad])
            self._apply_pair_block(X, Y)
        self.last_snapshot = np.ascontiguousarray(chain[-1])
        self._buf.extend(block)
        del self._buf[: max(0, len(self._buf) - self.window)]
        self.n_seen += block.shape[0]

    def eigenvalues(self) -> np.ndarray:
        """Current DMD eigenvalues.  Cached: a second call with no update
        in between returns the previous solve without touching the device
        (telemetry re-reads stop re-running ``gram_eigs`` on unchanged
        G/A — watch ``device_calls`` stand still)."""
        if self._eigs_cache is not None and self._eigs_seen == self.n_seen:
            return self._eigs_cache
        if self.n_seen < 3:
            eigs = np.zeros(1, np.complex64)
        elif self.n_seen <= self.window:
            snaps = jnp.asarray(np.stack(self._buf, axis=1))
            self.h2d_transfers += 1
            self.device_calls += 1
            e, _ = exact_dmd(snaps, rank=self.rank)
            self.d2h_transfers += 1
            eigs = np.asarray(e)
        else:
            self.device_calls += 1
            e = gram_eigs(self._G, self._A, rank=self.rank)
            self.d2h_transfers += 1
            eigs = np.asarray(e)
        self._eigs_cache = eigs
        self._eigs_seen = self.n_seen
        return eigs
