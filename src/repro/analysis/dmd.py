"""Dynamic Mode Decomposition in JAX — the paper's Cloud-side analysis.

Two implementations:

* ``exact_dmd`` — PyDMD-equivalent batch DMD on a snapshot window
  (SVD -> low-rank operator -> eigenvalues), jitted.
* ``StreamingDMD`` — online DMD over unbounded streams: Gram updates
  G += XᵀX, A += YᵀX over snapshot-pair blocks, eigenvalues from the
  Gram-space operator.  This is what each stream's executor runs per
  micro-batch.

``StreamingDMD`` is **device-resident**: G and A live as ``jax.Array`` and
never round-trip through the host between updates.  The batched entry point
``update_batch((n, d) snapshots)`` forms the shifted X/Y pair in one shot
and issues a single device call per micro-batch — the fused Pallas
``gram_pair`` kernel (kernels/gram.py) on TPU, a jitted jnp matmul pair
elsewhere — instead of one ``G += x xᵀ, A += y xᵀ`` dispatch (plus four
host↔device transfers) per snapshot.  ``h2d_transfers`` / ``d2h_transfers``
/ ``device_calls`` counters make the savings measurable
(benchmarks/kernels_bench.py writes them to BENCH_hotpath.json).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


@partial(jax.jit, static_argnames=("rank",))
def exact_dmd(snapshots: jax.Array, rank: int = 8):
    """snapshots: (n_features, n_steps).  Returns (eigenvalues, energy).

    X = snaps[:, :-1], Y = snaps[:, 1:];  A~ = Uᵀ Y V S⁻¹ (rank-truncated).
    """
    X = snapshots[:, :-1].astype(F32)
    Y = snapshots[:, 1:].astype(F32)
    U, S, Vt = jnp.linalg.svd(X, full_matrices=False)
    r = min(rank, S.shape[0])
    U, S, Vt = U[:, :r], S[:r], Vt[:r]
    Sinv = jnp.where(S > 1e-10, 1.0 / S, 0.0)
    Atilde = U.T @ Y @ Vt.T * Sinv[None, :]
    eigs = jnp.linalg.eigvals(Atilde)
    energy = jnp.sum(S[:r] ** 2) / jnp.maximum(jnp.sum(S ** 2), 1e-30)
    return eigs, energy


@jax.jit
def gram_update(G: jax.Array, A: jax.Array, x: jax.Array, y: jax.Array):
    """Rank-1 online-DMD update: G += x xᵀ, A += y xᵀ (single-pair oracle)."""
    return G + jnp.outer(x, x), A + jnp.outer(y, x)


@jax.jit
def gram_pair_update(G: jax.Array, A: jax.Array, X: jax.Array, Y: jax.Array):
    """Batched online-DMD update: G += XᵀX, A += YᵀX over (n, d) pair blocks.

    The portable jnp form of the fused Pallas ``gram_pair`` kernel
    (kernels/gram.py) and its allclose oracle.  All-zero padding rows are
    no-ops in both products, so callers may pad n freely."""
    Xf, Yf = X.astype(F32), Y.astype(F32)
    return G + Xf.T @ Xf, A + Yf.T @ Xf


@partial(jax.jit, static_argnames=("rank",))
def gram_eigs(G: jax.Array, A: jax.Array, rank: int = 8,
              rel_tol: float = 1e-7):
    """Eigenvalues of the online-DMD operator, rank-truncated.

    G = X Xᵀ (PSD), A = Y Xᵀ.  Project onto G's dominant eigenspace U_r
    (anything else is noise-nullspace and would blow up the pseudo-inverse):
    M_r = U_rᵀ A U_r diag(1/s_r);  eig(M_r)."""
    s, U = jnp.linalg.eigh(G)                    # ascending
    s = s[::-1]
    U = U[:, ::-1]
    r = min(rank, G.shape[0])
    s_r, U_r = s[:r], U[:, :r]
    good = s_r > rel_tol * jnp.maximum(s_r[0], 1e-30)
    inv = jnp.where(good, 1.0 / jnp.maximum(s_r, 1e-30), 0.0)
    M = (U_r.T @ A @ U_r) * inv[None, :]
    eigs = jnp.linalg.eigvals(M)
    # null directions are padded with NaN — consumers (metrics, tests) filter
    # non-finite entries, so rank padding never reads as (in)stability
    return jnp.where(good, eigs, jnp.nan + 0.0j)


def window_dmd(snapshots, rank: int = 8,
               n_features: int | None = None) -> np.ndarray:
    """Batch DMD over one window pane — the stream-operator entry point.

    ``snapshots``: iterable of 1-D arrays (a fired window's values, e.g.
    record payloads in step order).  Each is flattened and trimmed /
    zero-padded to ``n_features`` (default: the longest snapshot), stacked
    to the ``(d, n)`` matrix ``exact_dmd`` expects.  Windows shorter than 3
    snapshots can't form a snapshot pair worth solving — returns the same
    zero sentinel ``StreamingDMD.eigenvalues`` uses."""
    rows = [np.asarray(s, np.float32).reshape(-1) for s in snapshots]
    if len(rows) < 3:
        return np.zeros(1, np.complex64)
    d = max(r.size for r in rows) if n_features is None else int(n_features)
    rows = [np.pad(r[:d], (0, max(0, d - r[:d].size))) for r in rows]
    eigs, _energy = exact_dmd(jnp.asarray(np.stack(rows, axis=1)), rank=rank)
    return np.asarray(eigs)


def _pad_rows(n: int) -> int:
    """Round a batch size up to the next power of two so the jitted update
    compiles O(log n) variants instead of one per micro-batch size."""
    return 1 << max(0, n - 1).bit_length()


@dataclass
class StreamingDMD:
    """Per-stream online DMD state (executor-side), device-resident.

    ``use_kernel``: None = auto (fused Pallas kernel on TPU, jnp matmuls
    elsewhere — interpret-mode Pallas is not a hot-path option on CPU);
    True/False forces the choice (tests force True to exercise the kernel).
    """

    n_features: int
    window: int = 32                 # snapshots kept for exact re-solves
    rank: int = 8
    use_kernel: bool | None = None
    _buf: list = field(default_factory=list)
    _G: jax.Array | None = None      # (d, d) Gram, lives on device
    _A: jax.Array | None = None      # (d, d) cross-Gram, lives on device
    last_snapshot: np.ndarray | None = None
    n_seen: int = 0
    # hot-path accounting (BENCH_hotpath.json scoreboard)
    h2d_transfers: int = 0
    d2h_transfers: int = 0
    device_calls: int = 0

    def _coerce(self, snapshot) -> np.ndarray:
        x = np.asarray(snapshot, np.float32).reshape(-1)[: self.n_features]
        if x.size < self.n_features:   # short payloads embed zero-padded
            x = np.pad(x, (0, self.n_features - x.size))
        return x

    def _apply_pair_block(self, X: np.ndarray, Y: np.ndarray) -> None:
        """One device call: G += XᵀX, A += YᵀX for an (n, d) pair block."""
        d = self.n_features
        if self._G is None:
            self._G = jnp.zeros((d, d), F32)
            self._A = jnp.zeros((d, d), F32)
        Xd, Yd = jnp.asarray(X), jnp.asarray(Y)
        self.h2d_transfers += 2
        self.device_calls += 1
        use_kernel = (self.use_kernel if self.use_kernel is not None
                      else jax.default_backend() == "tpu")
        if use_kernel:
            from repro.kernels import ops
            self._G, self._A = ops.gram_pair_accumulate(Xd, Yd, self._G,
                                                        self._A)
        else:
            self._G, self._A = gram_pair_update(self._G, self._A, Xd, Yd)

    def update(self, snapshot: np.ndarray) -> None:
        """Single-snapshot update (legacy per-record path)."""
        self.update_batch([snapshot])

    def update_batch(self, snaps) -> None:
        """Batched update: ``snaps`` is an (n, d) array or list of snapshots
        (each trimmed/zero-padded to ``n_features``).  Forms the shifted
        X = chain[:-1], Y = chain[1:] pair — chaining through the previous
        batch's last snapshot — and applies it in one device call."""
        rows = [self._coerce(s) for s in snaps]
        if not rows:
            return
        if self.last_snapshot is not None:
            chain = np.stack([self.last_snapshot] + rows)
        else:
            chain = np.stack(rows)
        X, Y = chain[:-1], chain[1:]
        n = X.shape[0]
        if n:
            m = _pad_rows(n)
            if m != n:   # zero rows contribute nothing to XᵀX / YᵀX
                pad = np.zeros((m - n, self.n_features), np.float32)
                X = np.concatenate([X, pad])
                Y = np.concatenate([Y, pad])
            self._apply_pair_block(X, Y)
        self.last_snapshot = chain[-1]
        self._buf.extend(rows)
        del self._buf[: max(0, len(self._buf) - self.window)]
        self.n_seen += len(rows)

    def eigenvalues(self) -> np.ndarray:
        if self.n_seen < 3:
            return np.zeros(1, np.complex64)
        if self.n_seen <= self.window:
            snaps = jnp.asarray(np.stack(self._buf, axis=1))
            self.h2d_transfers += 1
            self.device_calls += 1
            eigs, _ = exact_dmd(snaps, rank=self.rank)
            self.d2h_transfers += 1
            return np.asarray(eigs)
        self.device_calls += 1
        eigs = gram_eigs(self._G, self._A, rank=self.rank)
        self.d2h_transfers += 1
        return np.asarray(eigs)
