"""Dynamic Mode Decomposition in JAX — the paper's Cloud-side analysis.

Two implementations:

* ``exact_dmd`` — PyDMD-equivalent batch DMD on a snapshot window
  (SVD -> low-rank operator -> eigenvalues), jitted.
* ``StreamingDMD`` — online DMD over unbounded streams: rank-1 Gram updates
  G += x xᵀ, A += y xᵀ per incoming snapshot pair (the hot loop the Pallas
  ``gram`` kernel implements on TPU), eigenvalues from the Gram-space
  operator.  This is what each stream's executor runs per micro-batch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


@partial(jax.jit, static_argnames=("rank",))
def exact_dmd(snapshots: jax.Array, rank: int = 8):
    """snapshots: (n_features, n_steps).  Returns (eigenvalues, energy).

    X = snaps[:, :-1], Y = snaps[:, 1:];  A~ = Uᵀ Y V S⁻¹ (rank-truncated).
    """
    X = snapshots[:, :-1].astype(F32)
    Y = snapshots[:, 1:].astype(F32)
    U, S, Vt = jnp.linalg.svd(X, full_matrices=False)
    r = min(rank, S.shape[0])
    U, S, Vt = U[:, :r], S[:r], Vt[:r]
    Sinv = jnp.where(S > 1e-10, 1.0 / S, 0.0)
    Atilde = U.T @ Y @ Vt.T * Sinv[None, :]
    eigs = jnp.linalg.eigvals(Atilde)
    energy = jnp.sum(S[:r] ** 2) / jnp.maximum(jnp.sum(S ** 2), 1e-30)
    return eigs, energy


@jax.jit
def gram_update(G: jax.Array, A: jax.Array, x: jax.Array, y: jax.Array):
    """Rank-1 online-DMD update: G += x xᵀ, A += y xᵀ.

    On TPU this runs as the Pallas ``gram`` kernel (kernels/gram.py) over
    batched snapshot blocks; this jnp form is the portable path and oracle.
    """
    return G + jnp.outer(x, x), A + jnp.outer(y, x)


@partial(jax.jit, static_argnames=("rank",))
def gram_eigs(G: jax.Array, A: jax.Array, rank: int = 8,
              rel_tol: float = 1e-7):
    """Eigenvalues of the online-DMD operator, rank-truncated.

    G = X Xᵀ (PSD), A = Y Xᵀ.  Project onto G's dominant eigenspace U_r
    (anything else is noise-nullspace and would blow up the pseudo-inverse):
    M_r = U_rᵀ A U_r diag(1/s_r);  eig(M_r)."""
    s, U = jnp.linalg.eigh(G)                    # ascending
    s = s[::-1]
    U = U[:, ::-1]
    r = min(rank, G.shape[0])
    s_r, U_r = s[:r], U[:, :r]
    good = s_r > rel_tol * jnp.maximum(s_r[0], 1e-30)
    inv = jnp.where(good, 1.0 / jnp.maximum(s_r, 1e-30), 0.0)
    M = (U_r.T @ A @ U_r) * inv[None, :]
    eigs = jnp.linalg.eigvals(M)
    # null directions are padded with NaN — consumers (metrics, tests) filter
    # non-finite entries, so rank padding never reads as (in)stability
    return jnp.where(good, eigs, jnp.nan + 0.0j)


@dataclass
class StreamingDMD:
    """Per-stream online DMD state (executor-side)."""

    n_features: int
    window: int = 32                 # snapshots kept for exact re-solves
    rank: int = 8
    _buf: list = field(default_factory=list)
    _G: np.ndarray | None = None
    _A: np.ndarray | None = None
    last_snapshot: np.ndarray | None = None
    n_seen: int = 0

    def update(self, snapshot: np.ndarray) -> None:
        x = np.asarray(snapshot, np.float32).reshape(-1)[: self.n_features]
        if x.size < self.n_features:   # short payloads embed zero-padded
            x = np.pad(x, (0, self.n_features - x.size))
        if self._G is None:
            self._G = np.zeros((self.n_features, self.n_features), np.float32)
            self._A = np.zeros((self.n_features, self.n_features), np.float32)
        if self.last_snapshot is not None:
            G, A = gram_update(jnp.asarray(self._G), jnp.asarray(self._A),
                               jnp.asarray(self.last_snapshot), jnp.asarray(x))
            self._G, self._A = np.asarray(G), np.asarray(A)
        self.last_snapshot = x
        self._buf.append(x)
        if len(self._buf) > self.window:
            self._buf.pop(0)
        self.n_seen += 1

    def eigenvalues(self) -> np.ndarray:
        if self.n_seen < 3:
            return np.zeros(1, np.complex64)
        if self.n_seen <= self.window:
            snaps = jnp.asarray(np.stack(self._buf, axis=1))
            eigs, _ = exact_dmd(snaps, rank=self.rank)
            return np.asarray(eigs)
        return np.asarray(gram_eigs(jnp.asarray(self._G), jnp.asarray(self._A),
                                    rank=self.rank))
