"""Sharded, async, manifest-based checkpointing with elastic restore.

Layout:
    <dir>/step_<N>/manifest.json       # tree structure, shapes, dtypes
    <dir>/step_<N>/leaf_<i>.npy        # one file per pytree leaf
    <dir>/step_<N>/COMMITTED           # atomicity marker (written last)

* ``save`` runs on a background thread (training never stalls — the same
  asynchrony argument as the broker's Fig-6 result).
* ``restore`` rebuilds the pytree; with ``target_sharding_fn`` it re-shards
  onto a *different* mesh than the one that saved (elastic restart).
* uncommitted step dirs are ignored and garbage-collected.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

from repro.optim.adamw import Q8


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: isinstance(x, Q8))
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.save_count = 0

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = False):
        self.wait()  # one in-flight save at a time
        leaves, treedef = _flatten(tree)
        # materialize to host BEFORE backgrounding (device buffers may be
        # donated by the next train step)
        host = []
        for leaf in leaves:
            if isinstance(leaf, Q8):
                host.append(("q8", np.asarray(leaf.data), np.asarray(leaf.scale),
                             leaf.q))
            else:
                host.append(("arr", np.asarray(leaf)))
        payload = (step, host, jax.tree_util.treedef_tuple((treedef,)))

        def _write():
            d = self.dir / f"step_{step:08d}"
            tmp = self.dir / f".tmp_step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": [], "time": time.time()}
            for i, item in enumerate(host):
                if item[0] == "q8":
                    np.save(tmp / f"leaf_{i:05d}.data.npy", item[1])
                    np.save(tmp / f"leaf_{i:05d}.scale.npy", item[2])
                    manifest["leaves"].append(
                        {"kind": "q8", "q": item[3],
                         "shape": list(item[1].shape),
                         "dtype": str(item[1].dtype)})
                else:
                    np.save(tmp / f"leaf_{i:05d}.npy", item[1])
                    manifest["leaves"].append(
                        {"kind": "arr", "shape": list(item[1].shape),
                         "dtype": str(item[1].dtype)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "COMMITTED").write_text("ok")
            if d.exists():
                shutil.rmtree(d)
            tmp.rename(d)
            self.save_count += 1
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        # stash treedef for restore symmetry checks
        self._last_treedef = treedef
        return step

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
        for tmp in self.dir.glob(".tmp_step_*"):
            shutil.rmtree(tmp, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in sorted(self.dir.glob("step_*")):
            if (d / "COMMITTED").exists():
                out.append(int(d.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None,
                target_sharding_fn=None):
        """tree_like: pytree with the target structure (arrays or SDS).

        target_sharding_fn(leaf_index, leaf_like) -> Sharding | None enables
        elastic restore onto a different mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        like_leaves, treedef = _flatten(tree_like)
        assert len(like_leaves) == len(manifest["leaves"]), (
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target expects {len(like_leaves)}")
        out = []
        for i, (meta, like) in enumerate(zip(manifest["leaves"], like_leaves)):
            if meta["kind"] == "q8":
                data = np.load(d / f"leaf_{i:05d}.data.npy")
                scale = np.load(d / f"leaf_{i:05d}.scale.npy")
                leaf = Q8(jax.numpy.asarray(data), jax.numpy.asarray(scale),
                          meta["q"])
            else:
                arr = np.load(d / f"leaf_{i:05d}.npy")
                sharding = None
                if target_sharding_fn is not None:
                    sharding = target_sharding_fn(i, like)
                elif hasattr(like, "sharding"):
                    sharding = like.sharding
                leaf = (jax.device_put(arr, sharding) if sharding is not None
                        else jax.numpy.asarray(arr))
            out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out), step
