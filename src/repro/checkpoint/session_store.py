"""Atomic on-disk store for Session-level checkpoints.

Reuses the commit protocol of ``repro.checkpoint.ckpt.CheckpointManager``
(stage into a ``.tmp`` directory, write payload + manifest, touch a
``COMMITTED`` marker, atomically rename, garbage-collect to ``keep``) —
but without its jax dependency: session state is an opaque pickle (window
panes, commit frontier, broker counters, WAL trim points), not a pytree of
device arrays, and ``Session.restore()`` must work on machines that never
import jax.

A crash at ANY point leaves either the previous committed checkpoint or
the new one — never a torn directory visible to ``load()`` (uncommitted
leftovers are swept by the next save's gc).
"""
from __future__ import annotations

import json
import pickle
import shutil
from pathlib import Path

_PREFIX = "ckpt_"
_FORMAT = 1


class SessionCheckpointStore:
    def __init__(self, directory: str | Path, keep: int = 3):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ---- helpers ---------------------------------------------------------
    def _committed_ids(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith(_PREFIX) \
                    and (p / "COMMITTED").exists():
                try:
                    out.append(int(p.name[len(_PREFIX):]))
                except ValueError:
                    continue
        return sorted(out)

    def _path(self, ckpt_id: int) -> Path:
        return self.dir / f"{_PREFIX}{ckpt_id:08d}"

    # ---- API -------------------------------------------------------------
    def latest_id(self) -> int | None:
        ids = self._committed_ids()
        return ids[-1] if ids else None

    def save(self, state: dict) -> int:
        ids = self._committed_ids()
        ckpt_id = (ids[-1] if ids else 0) + 1
        tmp = self.dir / f".tmp_{_PREFIX}{ckpt_id:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        (tmp / "state.pkl").write_bytes(
            pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL))
        (tmp / "manifest.json").write_text(
            json.dumps({"id": ckpt_id, "format": _FORMAT}))
        (tmp / "COMMITTED").touch()
        tmp.rename(self._path(ckpt_id))
        self._gc()
        return ckpt_id

    def load(self, ckpt_id: int | None = None) -> tuple[dict, int]:
        """Load (state, id) of the given or latest committed checkpoint.
        Raises FileNotFoundError when the store has none (a fresh run)."""
        if ckpt_id is None:
            ckpt_id = self.latest_id()
            if ckpt_id is None:
                raise FileNotFoundError(
                    f"no committed session checkpoint in {self.dir}")
        path = self._path(ckpt_id)
        if not (path / "COMMITTED").exists():
            raise FileNotFoundError(f"checkpoint {ckpt_id} not committed")
        manifest = json.loads((path / "manifest.json").read_text())
        if manifest.get("format") != _FORMAT:
            raise ValueError(f"unsupported checkpoint format "
                             f"{manifest.get('format')!r}")
        state = pickle.loads((path / "state.pkl").read_bytes())
        return state, ckpt_id

    def _gc(self) -> None:
        committed = self._committed_ids()
        for old in committed[:-self.keep]:
            shutil.rmtree(self._path(old), ignore_errors=True)
        for p in self.dir.iterdir():     # sweep torn/uncommitted leftovers
            if p.is_dir() and (p.name.startswith(".tmp_") or (
                    p.name.startswith(_PREFIX)
                    and not (p / "COMMITTED").exists())):
                shutil.rmtree(p, ignore_errors=True)
