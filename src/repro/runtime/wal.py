"""Bounded write-ahead frame log for exactly-once broker delivery.

The paper's broker streams simulation frames to cloud endpoints with no
durability story: a dead endpoint (or a dead broker) simply loses whatever
it had in flight.  This module adds the minimal machinery to make the
broker -> endpoint -> engine path *exactly-once*:

``WalSegment``
    A per-group, bounded, in-memory log of encoded records.  Every record
    is appended (with a monotonic sequence number) *before* it ships; the
    segment tracks four pointers::

        base < - trimmed - >  acked  <= shipped  <=  last
                              committed (checkpoint frontier)

    - ``shipped`` — highest seq handed to the group sender.  In
      exactly-once mode the WAL *is* the send queue: the sender fetches
      entries through this pointer, so there is no separate queue whose
      ordering could diverge from the seq order.
    - ``acked`` — highest seq contiguously applied by an endpoint.  On
      endpoint failure/reroute or broker restart the sender rewinds
      ``shipped`` to ``acked`` and replays the tail.
    - ``committed`` — highest seq captured by a session checkpoint.  With
      ``retain="commit"`` entries survive until both acked *and*
      committed, so ``Session.restore()`` can replay everything after the
      last checkpoint even though it was already delivered once (the
      receive side dedupes on seq).

    ``to_bytes``/``from_bytes`` give the segment a durable, CRC-framed
    serialization; a torn final record (partial write at crash) is
    discarded cleanly rather than corrupting the log.

``WalStore``
    The collection of per-group segments.  It outlives Broker and Session
    objects: a restarted broker or a restored session adopts the same
    store and replays its unacked/uncommitted tails.

``SeqLedger``
    The receive-side dedupe table, shared by every endpoint of a session
    (a frame retried onto a *different* endpoint after failover must still
    be recognized as a duplicate).  It records, per group, the highest
    contiguously applied seq; replayed prefixes are skipped, never
    double-applied.
"""
from __future__ import annotations

import struct
import threading
import zlib
from dataclasses import dataclass

_MAGIC = b"WALSEG1\n"
_HDR = struct.Struct("!IQQQ")      # group_id, base_seq, acked_seq, committed_seq
_REC = struct.Struct("!QII")       # seq, payload_len, crc32(payload)

_RETAIN = ("ack", "commit")


@dataclass
class WalEntry:
    """One logged record: wire blob + (when still in memory) the decoded
    record object, so the hot path never re-decodes what it just encoded."""
    seq: int
    blob: bytes
    rec: object | None = None


class WalSegment:
    """Bounded per-group write-ahead log (see module docstring).

    Thread-safe: producers append concurrently with the group sender
    fetching and acking.  No method blocks — a full segment makes
    ``try_append`` return ``None`` and the caller retries outside any lock
    (a blocking append while holding a lock would deadlock VirtualClock's
    one-runnable-thread schedule).
    """

    def __init__(self, group_id: int = 0, *, capacity_bytes: int = 16 << 20,
                 max_pending: int = 256, retain: str = "ack"):
        if retain not in _RETAIN:
            raise ValueError(f"retain must be one of {_RETAIN}, got {retain!r}")
        self.group_id = group_id
        self.capacity_bytes = int(capacity_bytes)
        self.max_pending = int(max_pending)
        self.retain = retain
        self._lock = threading.Lock()
        self._entries: list[WalEntry] = []     # seqs base+1 .. last, contiguous
        self._bytes = 0
        self.base_seq = 0                      # everything <= base is trimmed
        self.last_seq = 0
        self.shipped_seq = 0
        self.acked_seq = 0
        self.committed_seq = 0

    # ---- append / fetch / ack ------------------------------------------
    def try_append(self, blob: bytes, rec: object | None = None) -> int | None:
        """Log one encoded record; returns its seq, or None when the
        segment is at capacity (caller backs off and retries)."""
        with self._lock:
            if self._entries and self._bytes + len(blob) > self.capacity_bytes:
                return None
            if self.last_seq - self.shipped_seq >= self.max_pending:
                return None
            self.last_seq += 1
            self._entries.append(WalEntry(self.last_seq, blob, rec))
            self._bytes += len(blob)
            return self.last_seq

    def fetch_unshipped(self, limit: int) -> list[WalEntry]:
        """Hand the sender the next <= limit entries, advancing shipped."""
        with self._lock:
            if self.shipped_seq >= self.last_seq or limit < 1:
                return []
            lo = self.shipped_seq - self.base_seq          # list index
            hi = min(lo + limit, self.last_seq - self.base_seq)
            out = self._entries[lo:hi]
            self.shipped_seq = self.base_seq + hi
            return out

    def rewind_shipped(self) -> int:
        """Point the sender back at the acked frontier (endpoint failover /
        broker restart): everything unacked re-ships.  Returns the number
        of entries that will replay."""
        with self._lock:
            self.shipped_seq = self.acked_seq
            return self.last_seq - self.shipped_seq

    def ack(self, seq: int) -> None:
        """Endpoint applied everything through ``seq`` (contiguously)."""
        with self._lock:
            self.acked_seq = max(self.acked_seq, min(seq, self.last_seq))
            if self.shipped_seq < self.acked_seq:
                self.shipped_seq = self.acked_seq
            self._trim_locked()

    def commit(self, seq: int) -> None:
        """A session checkpoint captured state through ``seq``."""
        with self._lock:
            self.committed_seq = max(self.committed_seq,
                                     min(seq, self.last_seq))
            self._trim_locked()

    def reset_acked_to_commit(self) -> int:
        """Session restore: delivery beyond the last checkpoint is void
        (the state it produced died with the session) — rewind acked and
        shipped to the committed frontier so the tail replays.  Returns
        the number of entries that will replay."""
        with self._lock:
            self.acked_seq = self.committed_seq
            self.shipped_seq = self.committed_seq
            return self.last_seq - self.shipped_seq

    def _trim_locked(self) -> None:
        point = self.acked_seq if self.retain == "ack" \
            else min(self.acked_seq, self.committed_seq)
        if point > self.base_seq:
            drop = point - self.base_seq
            for e in self._entries[:drop]:
                self._bytes -= len(e.blob)
            del self._entries[:drop]
            self.base_seq = point

    # ---- introspection --------------------------------------------------
    def unshipped_count(self) -> int:
        with self._lock:
            return self.last_seq - self.shipped_seq

    def unacked_count(self) -> int:
        with self._lock:
            return self.last_seq - self.acked_seq

    def uncommitted_count(self) -> int:
        with self._lock:
            return self.last_seq - self.committed_seq

    def bytes_used(self) -> int:
        with self._lock:
            return self._bytes

    def points(self) -> dict:
        with self._lock:
            return {"base": self.base_seq, "last": self.last_seq,
                    "shipped": self.shipped_seq, "acked": self.acked_seq,
                    "committed": self.committed_seq, "bytes": self._bytes}

    # ---- durable serialization -----------------------------------------
    def to_bytes(self) -> bytes:
        """CRC-framed snapshot of the retained tail + pointers."""
        with self._lock:
            parts = [_MAGIC, _HDR.pack(self.group_id, self.base_seq,
                                       self.acked_seq, self.committed_seq)]
            for e in self._entries:
                parts.append(_REC.pack(e.seq, len(e.blob),
                                       zlib.crc32(e.blob) & 0xFFFFFFFF))
                parts.append(e.blob)
            return b"".join(parts)

    @classmethod
    def from_bytes(cls, data: bytes, *, capacity_bytes: int = 16 << 20,
                   max_pending: int = 256, retain: str = "ack") -> "WalSegment":
        """Recover a segment from ``to_bytes`` output.  A torn tail — a
        final record cut short or failing its CRC (partial write at crash)
        — is discarded; everything before it survives intact."""
        if data[:len(_MAGIC)] != _MAGIC:
            raise ValueError("not a WAL segment (bad magic)")
        off = len(_MAGIC)
        if len(data) < off + _HDR.size:
            raise ValueError("WAL segment header truncated")
        group_id, base, acked, committed = _HDR.unpack_from(data, off)
        off += _HDR.size
        seg = cls(group_id, capacity_bytes=capacity_bytes,
                  max_pending=max_pending, retain=retain)
        entries: list[WalEntry] = []
        expect = base + 1
        while off + _REC.size <= len(data):
            seq, ln, crc = _REC.unpack_from(data, off)
            body = data[off + _REC.size: off + _REC.size + ln]
            if len(body) < ln or (zlib.crc32(body) & 0xFFFFFFFF) != crc \
                    or seq != expect:
                break                      # torn/corrupt tail: stop here
            entries.append(WalEntry(seq, body))
            expect += 1
            off += _REC.size + ln
        seg._entries = entries
        seg._bytes = sum(len(e.blob) for e in entries)
        seg.base_seq = base
        seg.last_seq = entries[-1].seq if entries \
            else max(base, acked, committed)
        # pointers never exceed what actually survived
        seg.acked_seq = min(acked, seg.last_seq)
        seg.committed_seq = min(committed, seg.last_seq)
        seg.shipped_seq = seg.acked_seq
        return seg


class WalStore:
    """Per-group WAL segments with shared limits.  Lives *outside* Broker
    and Session so a restarted broker / restored session adopts the same
    log and replays its tail."""

    def __init__(self, *, capacity_bytes: int = 16 << 20,
                 queue_capacity: int = 256, retain: str = "ack"):
        if retain not in _RETAIN:
            raise ValueError(f"retain must be one of {_RETAIN}, got {retain!r}")
        self.capacity_bytes = int(capacity_bytes)
        self.queue_capacity = int(queue_capacity)
        self.retain = retain
        self._lock = threading.Lock()
        self._segs: dict[int, WalSegment] = {}

    def segment(self, group_id: int) -> WalSegment:
        with self._lock:
            seg = self._segs.get(group_id)
            if seg is None:
                seg = WalSegment(group_id, capacity_bytes=self.capacity_bytes,
                                 max_pending=self.queue_capacity,
                                 retain=self.retain)
                self._segs[group_id] = seg
            return seg

    def groups(self) -> list[int]:
        with self._lock:
            return sorted(self._segs)

    def reset_for_restore(self) -> int:
        """Rewind every segment's acked frontier to its committed frontier
        (see WalSegment.reset_acked_to_commit).  Returns total replay size."""
        return sum(self.segment(g).reset_acked_to_commit()
                   for g in self.groups())

    def unacked_records(self) -> int:
        return sum(self.segment(g).unacked_count() for g in self.groups())

    def uncommitted_records(self) -> int:
        return sum(self.segment(g).uncommitted_count() for g in self.groups())

    def points(self) -> dict[int, dict]:
        return {g: self.segment(g).points() for g in self.groups()}


class FileWalStore(WalStore):
    """Disk-backed WalStore: exactly-once across *host* crashes.

    Segments live in memory exactly as in :class:`WalStore` (the hot path
    is unchanged); :meth:`sync` persists each group's CRC-framed
    ``to_bytes`` image atomically (tmp file + rename), and a new store
    over the same directory adopts whatever survived — ``from_bytes``
    discards a torn tail, so a crash mid-write costs at most the last
    unsynced suffix, never log integrity.  Session wires this in behind
    ``WorkflowConfig(wal_dir=...)`` and syncs on every checkpoint and at
    close.
    """

    def __init__(self, directory, *, capacity_bytes: int = 16 << 20,
                 queue_capacity: int = 256, retain: str = "ack"):
        super().__init__(capacity_bytes=capacity_bytes,
                         queue_capacity=queue_capacity, retain=retain)
        from pathlib import Path
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        for p in sorted(self.dir.glob("group-*.wal")):
            try:
                g = int(p.stem.split("-", 1)[1])
            except ValueError:
                continue
            try:
                seg = WalSegment.from_bytes(
                    p.read_bytes(), capacity_bytes=self.capacity_bytes,
                    max_pending=self.queue_capacity, retain=self.retain)
            except ValueError:
                # unreadable magic/header: the file never completed its
                # first sync — an empty segment is the correct recovery
                continue
            with self._lock:
                self._segs[g] = seg

    def _path(self, group_id: int):
        return self.dir / f"group-{group_id:05d}.wal"

    def sync(self) -> int:
        """Persist every segment atomically; returns total bytes written."""
        total = 0
        for g in self.groups():
            data = self.segment(g).to_bytes()
            path = self._path(g)
            tmp = path.with_suffix(".wal.tmp")
            tmp.write_bytes(data)
            tmp.replace(path)
            total += len(data)
        return total


class SeqLedger:
    """Receive-side dedupe table: per group, the highest contiguously
    applied seq.  One ledger is shared by all endpoints of a session so a
    frame replayed onto a *different* endpoint after failover still reads
    as a duplicate."""

    def __init__(self):
        self._lock = threading.Lock()
        self._applied: dict[int, int] = {}

    def applied(self, group_id: int) -> int:
        with self._lock:
            return self._applied.get(group_id, 0)

    def admit(self, group_id: int, base_seq: int, count: int) -> int:
        """A frame carrying seqs [base, base+count) arrived: advance the
        applied frontier and return how many *leading* records are
        duplicates the endpoint must skip (count == whole-frame dup)."""
        with self._lock:
            ap = self._applied.get(group_id, 0)
            top = base_seq + count - 1
            if top <= ap:
                return count
            self._applied[group_id] = top
            return max(0, ap - base_seq + 1)

    def mark_consumed(self, group_id: int, base_seq: int, count: int) -> None:
        """Consume seqs without applying them — used when an injected
        silent drop eats a frame: the drop is acked upstream, so replay
        must *not* resurrect it (it stays visible as audited loss)."""
        with self._lock:
            ap = self._applied.get(group_id, 0)
            self._applied[group_id] = max(ap, base_seq + count - 1)

    def snapshot(self) -> dict:
        with self._lock:
            return {"applied": dict(self._applied)}

    def restore(self, state: dict) -> None:
        with self._lock:
            self._applied = {int(k): int(v)
                             for k, v in state["applied"].items()}
