"""Injectable time — the seam that makes the control plane testable.

Every temporal behavior in this repo (QoS held through a spike, stragglers
detected from beat intervals, ordering preserved across steals) used to be
exercised against ``time.time()``/``time.sleep()``, so validating it meant
real waiting and real flake.  This module splits "what time is it / wait
until" into a :class:`Clock` protocol with two implementations:

* :class:`WallClock` — thin veneer over ``time``/native blocking primitives.
  The default everywhere; production behavior is unchanged.
* :class:`VirtualClock` — deterministic simulated time.  ``sleep()`` parks
  the calling thread; when **every** participating thread is parked, the
  clock advances to the earliest deadline and wakes exactly ONE waiter
  (ordered wakeups: earliest deadline first, FIFO among equal deadlines,
  or a seeded tie-breaker when ``seed`` is given so chaos tests can explore
  different interleavings reproducibly).  Strict one-runnable-thread
  serialization is what makes whole-Session scenario runs replay
  byte-for-byte from a seed — and finish in milliseconds, because a
  "10 second" load spike is just a few thousand heap pops.

Participation rules for ``VirtualClock`` (see ``sim/scenario.py`` for the
canonical driver):

* A thread joins the clock's schedule the first time it parks, or earlier
  via ``thread_started(t)`` (call it BEFORE ``t.start()`` so the clock
  cannot advance while the newborn thread is still racing to its first
  park — every runtime component that owns threads does this).
* The driving thread should ``attach()`` itself before building the
  pipeline, and must block only through the clock (``sleep``/``wait``/
  ``queue_get``/``join``) while other participants are live; native blocking
  calls stall virtual time for everyone.
* Threads leave the schedule by exiting (dead threads are pruned) or via
  ``detach()``.

Beyond the protocol's ``now``/``sleep``/``wait``, both clocks provide the
blocking helpers the runtime actually needs — ``queue_get``/``queue_put``/
``wait_event``/``wait_cv``/``join`` — implemented natively for wall time and
as deterministic polls for virtual time.
"""
from __future__ import annotations

import heapq
import itertools
import queue as _queue
import random
import threading
import time
from typing import Callable, Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    """What the runtime requires of a time source.  ``now``/``sleep``/
    ``wait`` are the conceptual core; the blocking helpers and participation
    hooks below are equally load-bearing — every consumer (broker senders,
    engine, controller, Session teardown) calls them, so a custom clock must
    implement the full surface (subclass :class:`WallClock` to inherit
    working defaults)."""

    virtual: bool

    def now(self) -> float:
        ...

    def sleep(self, duration: float) -> None:
        ...

    def sleep_until(self, t: float) -> None:
        ...

    def wait(self, condition: Callable[[], bool], timeout: float | None = None,
             poll: float | None = None) -> bool:
        ...

    # ---- blocking helpers -------------------------------------------------
    def queue_get(self, q: _queue.Queue, timeout: float | None = None):
        ...

    def queue_put(self, q: _queue.Queue, item,
                  timeout: float | None = None) -> bool:
        ...

    def wait_event(self, evt: threading.Event,
                   timeout: float | None = None) -> bool:
        ...

    def wait_cv(self, cv: threading.Condition, predicate,
                timeout: float | None = None) -> bool:
        ...

    def join(self, thread: threading.Thread,
             timeout: float | None = None) -> bool:
        ...

    # ---- participation hooks (no-ops for wall time) -----------------------
    def thread_started(self, thread: threading.Thread) -> None:
        ...

    def attach(self, thread: threading.Thread | None = None) -> None:
        ...

    def detach(self, thread: threading.Thread | None = None) -> None:
        ...


class WallClock:
    """Real time.  Blocking helpers delegate to the native primitives, so a
    wall-clock pipeline behaves exactly like the pre-clock code did."""

    virtual = False

    def now(self) -> float:
        return time.time()

    def sleep(self, duration: float) -> None:
        if duration > 0:
            time.sleep(duration)

    def sleep_until(self, t: float) -> None:
        self.sleep(t - self.now())

    def wait(self, condition, timeout=None, poll=None) -> bool:
        """Poll ``condition`` until it returns True (-> True) or ``timeout``
        elapses (-> False).  The deflake primitive: use this instead of
        hand-rolled ``while time.time() < deadline: time.sleep(...)``."""
        poll = 0.01 if poll is None else poll
        deadline = None if timeout is None else time.time() + timeout
        while True:
            if condition():
                return True
            if deadline is not None:
                remaining = deadline - time.time()
                if remaining <= 0:
                    return False
                time.sleep(min(poll, remaining))
            else:
                time.sleep(poll)

    # ---- blocking helpers (native) --------------------------------------
    def queue_get(self, q: _queue.Queue, timeout: float | None = None):
        """Blocking get; returns the item or None on timeout."""
        try:
            return q.get(timeout=timeout) if timeout is not None else q.get()
        except _queue.Empty:
            return None

    def queue_put(self, q: _queue.Queue, item, timeout: float | None = None) -> bool:
        try:
            if timeout is not None:
                q.put(item, timeout=timeout)
            else:
                q.put(item)
            return True
        except _queue.Full:
            return False

    def wait_event(self, evt: threading.Event, timeout: float | None = None) -> bool:
        return evt.wait(timeout)

    def wait_cv(self, cv: threading.Condition, predicate, timeout=None) -> bool:
        """Wait on a condition variable until ``predicate()`` holds (checked
        with ``cv`` held); relies on notifiers calling ``cv.notify_all()``."""
        deadline = None if timeout is None else time.time() + timeout
        with cv:
            while not predicate():
                remaining = None if deadline is None else deadline - time.time()
                if remaining is not None and remaining <= 0:
                    return False
                cv.wait(remaining)
            return True

    def join(self, thread: threading.Thread, timeout: float | None = None) -> bool:
        thread.join(timeout)
        return not thread.is_alive()

    # participation hooks are wall-clock no-ops
    def thread_started(self, thread: threading.Thread) -> None:
        pass

    def attach(self, thread: threading.Thread | None = None) -> None:
        pass

    def detach(self, thread: threading.Thread | None = None) -> None:
        pass


class _Waiter:
    __slots__ = ("thread", "event", "deadline")

    def __init__(self, thread: threading.Thread, deadline: float):
        self.thread = thread
        self.event = threading.Event()
        self.deadline = deadline


class VirtualClock:
    """Deterministic simulated time over real threads.

    The scheduling invariants (property-tested in ``tests/test_clock*.py``):

    * ``now()`` is monotonically non-decreasing,
    * exactly one participant runs at a time; time advances only when every
      participant is parked, to the earliest pending deadline,
    * wakeups at equal deadlines are FIFO in park order — unless ``seed`` is
      given, in which case equal-deadline order is shuffled by a seeded RNG
      (deterministic per seed; the chaos suite's interleaving explorer),
    * no lost wakeups: every ``sleep`` returns once its deadline is reached,
      regardless of how many threads are sleeping concurrently.

    A real-time watchdog (the 50 ms re-check in :meth:`sleep`) exists only to
    prune participants that died without detaching; it never changes what the
    schedule decides, so it cannot perturb determinism.
    """

    virtual = True

    def __init__(self, seed: int | None = None, *, start: float = 0.0,
                 poll: float = 0.005):
        self._lock = threading.Lock()
        self._now = float(start)
        self._heap: list = []               # (deadline, tiebreak, seq, waiter)
        self._seq = itertools.count()
        self._rng = random.Random(seed) if seed is not None else None
        self._runnable: set = set()         # participant threads not parked
        self.poll = poll                    # default condition-poll quantum
        self.wakeups = 0                    # scheduling events (observability)

    # ---- participation ---------------------------------------------------
    def attach(self, thread: threading.Thread | None = None) -> None:
        """Register a participant as runnable.  The driving thread calls this
        on itself before building the pipeline, so the clock cannot advance
        behind its back during setup."""
        t = thread if thread is not None else threading.current_thread()
        with self._lock:
            self._runnable.add(t)

    # registering a thread BEFORE .start() closes the race where the clock
    # advances while the newborn thread is still on its way to its first park
    thread_started = attach

    def detach(self, thread: threading.Thread | None = None) -> None:
        t = thread if thread is not None else threading.current_thread()
        with self._lock:
            self._runnable.discard(t)
            self._advance_locked()

    # ---- core ------------------------------------------------------------
    def now(self) -> float:
        with self._lock:
            return self._now

    def sleep(self, duration: float) -> None:
        """Park until virtual time reaches ``now + duration``.  The caller
        becomes a participant if it wasn't one already."""
        self._park(None, max(0.0, float(duration)))

    def sleep_until(self, t: float) -> None:
        """Park until virtual time reaches the absolute instant ``t`` (the
        exact float, so concurrent sleepers targeting the same ``t`` tie and
        wake in FIFO/seeded order)."""
        self._park(float(t), None)

    def _park(self, deadline_abs: float | None, duration: float | None) -> None:
        me = threading.current_thread()
        with self._lock:
            deadline = max(self._now, deadline_abs) if deadline_abs is not None \
                else self._now + duration
            w = _Waiter(me, deadline)
            jitter = self._rng.random() if self._rng is not None else 0.0
            heapq.heappush(self._heap, (w.deadline, jitter, next(self._seq), w))
            self._runnable.discard(me)
            self._advance_locked()
        # Real park.  The periodic re-check is the dead-participant watchdog:
        # if a runnable thread exits without detaching, some parked thread
        # notices within 50 ms real and re-runs the (purely state-driven,
        # hence still deterministic) advance decision.
        while not w.event.wait(0.05):
            with self._lock:
                self._advance_locked()

    def _advance_locked(self) -> None:
        """If no participant is runnable, advance to the earliest deadline
        and wake exactly that one waiter."""
        if self._runnable:
            dead = [t for t in self._runnable if not t.is_alive()]
            for t in dead:
                self._runnable.discard(t)
        if self._runnable or not self._heap:
            return
        deadline, _, _, w = heapq.heappop(self._heap)
        if deadline > self._now:
            self._now = deadline
        self._runnable.add(w.thread)
        self.wakeups += 1
        w.event.set()

    def wait(self, condition, timeout=None, poll=None) -> bool:
        poll = self.poll if poll is None else poll
        deadline = None if timeout is None else self.now() + timeout
        while True:
            if condition():
                return True
            now = self.now()
            if deadline is not None and now >= deadline:
                return False
            step = poll if deadline is None else min(poll, deadline - now)
            self.sleep(step)

    # ---- blocking helpers (deterministic polls) --------------------------
    def queue_get(self, q: _queue.Queue, timeout: float | None = None):
        out: list = []

        def _try() -> bool:
            try:
                out.append(q.get_nowait())
                return True
            except _queue.Empty:
                return False

        return out[0] if self.wait(_try, timeout=timeout) else None

    def queue_put(self, q: _queue.Queue, item, timeout: float | None = None) -> bool:
        def _try() -> bool:
            try:
                q.put_nowait(item)
                return True
            except _queue.Full:
                return False

        return self.wait(_try, timeout=timeout)

    def wait_event(self, evt: threading.Event, timeout: float | None = None) -> bool:
        return self.wait(evt.is_set, timeout=timeout)

    def wait_cv(self, cv: threading.Condition, predicate, timeout=None) -> bool:
        # never hold the cv while parked — another participant needs it to
        # make the predicate true
        def _check() -> bool:
            with cv:
                return predicate()

        return self.wait(_check, timeout=timeout)

    def join(self, thread: threading.Thread, timeout: float | None = None) -> bool:
        return self.wait(lambda: not thread.is_alive(), timeout=timeout)


#: process-wide default; ``clock or WALL`` is the injection idiom everywhere
WALL = WallClock()


def ensure_clock(clock: Clock | None) -> Clock:
    return clock if clock is not None else WALL
