"""Fault-tolerance runtime: heartbeats, failure detection, restart policy.

At 1000+ nodes, *something* is always failing.  The controller tracks
heartbeats from producers/endpoints/executors, detects misses, and drives the
recovery matrix:

  producer dies    -> restart from last committed checkpoint (deterministic
                      data pipeline => bitwise identical continuation)
  endpoint dies    -> broker group senders re-route (core.broker)
  executor dies    -> engine reassigns partitions (streaming.engine)
  straggler        -> work stealing absorbs (streaming.engine); controller
                      flags persistent stragglers for replacement

This module is deliberately transport-agnostic (in-process for tests; the
heartbeat source would be the pod controller on a real cluster).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable

from repro.runtime.clock import Clock, ensure_clock


@dataclass
class NodeState:
    name: str
    kind: str                     # producer | endpoint | executor
    # 0.0, not wall time: FailureDetector.register stamps this from its
    # clock; a wall-epoch default would mix time bases under VirtualClock
    last_beat: float = 0.0
    alive: bool = True
    marked_straggler: bool = False
    beat_intervals: list = field(default_factory=list)


class FailureDetector:
    def __init__(self, timeout_s: float = 1.0,
                 straggler_factor: float = 3.0, *,
                 clock: Clock | None = None):
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.clock = ensure_clock(clock)
        self.nodes: dict[str, NodeState] = {}
        self._lock = threading.Lock()
        self.on_failure: list[Callable[[NodeState], None]] = []
        self.on_straggler: list[Callable[[NodeState], None]] = []

    def register(self, name: str, kind: str):
        with self._lock:
            self.nodes[name] = NodeState(name=name, kind=kind,
                                         last_beat=self.clock.now())

    def remove(self, name: str):
        """Forget a node: a deliberately powered-off endpoint must not be
        reported as a failure on the next scan."""
        with self._lock:
            self.nodes.pop(name, None)

    def beat(self, name: str):
        now = self.clock.now()
        with self._lock:
            st = self.nodes[name]
            st.beat_intervals.append(now - st.last_beat)
            if len(st.beat_intervals) > 32:
                st.beat_intervals.pop(0)
            st.last_beat = now

    def scan(self) -> list[NodeState]:
        """One detection pass; returns newly failed nodes."""
        now = self.clock.now()
        failed = []
        with self._lock:
            for st in self.nodes.values():
                if not st.alive:
                    continue
                if now - st.last_beat > self.timeout_s:
                    st.alive = False
                    failed.append(st)
                elif len(st.beat_intervals) >= 4:
                    mean = sum(st.beat_intervals) / len(st.beat_intervals)
                    # only peers with a meaningful sample: a just-registered
                    # node's single ~0s interval (register→beat in one
                    # control tick) would poison the median and flag any
                    # long-running busy node as a straggler
                    others = [n for n in self.nodes.values()
                              if n.kind == st.kind and n is not st
                              and len(n.beat_intervals) >= 4]
                    if others:
                        peer = sorted(
                            [iv for o in others for iv in o.beat_intervals]
                            or [mean])
                        med = peer[len(peer) // 2]
                        if (mean > self.straggler_factor * max(med, 1e-6)
                                and not st.marked_straggler):
                            st.marked_straggler = True
                            for cb in self.on_straggler:
                                cb(st)
        for st in failed:
            for cb in self.on_failure:
                cb(st)
        return failed


@dataclass
class RestartPolicy:
    """Checkpoint-restart driver for the training producer."""

    max_restarts: int = 5
    restarts: int = 0

    def run_with_restarts(self, train_fn: Callable[[int | None], int],
                          ckpt_mgr) -> int:
        """train_fn(resume_step) -> final step; raises on simulated failure."""
        resume = None
        while True:
            try:
                return train_fn(resume)
            except RuntimeError:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                resume = ckpt_mgr.latest_step()
