"""Replay-based failure recovery for exactly-once sessions.

The at-most-once control plane reacts to a dead endpoint by re-pointing its
groups (``Broker.reroute_from_endpoint``) — whatever the dead endpoint had
in flight is simply gone.  :class:`RecoverySupervisor` is the exactly-once
counterpart the :class:`~repro.runtime.controller.ElasticController` calls
instead: the same re-point, but because every unacked frame still sits in
the broker's write-ahead log (``runtime.wal``), the group senders replay
the tail to the new primary and the receive-side ``SeqLedger`` dedupes any
frame the dead endpoint *did* manage to apply.  Nothing is lost, nothing is
double-applied.

Executor deaths route through here too so one component owns the recovery
event log: chaos scenarios read ``events``/``summary()`` to assert that
every injected death was answered by a replayed (not dropped) recovery.
"""
from __future__ import annotations

import threading

from repro.runtime.clock import Clock, ensure_clock


class RecoverySupervisor:
    """Turns detector-driven failures into replay instead of loss.

    Holds references to the live broker/engine (a Session re-points
    ``broker`` after a broker restart) and records every recovery action
    with its virtual timestamp and the WAL backlog it found.
    """

    def __init__(self, *, broker=None, engine=None,
                 clock: Clock | None = None):
        self.broker = broker
        self.engine = engine
        self.clock = ensure_clock(clock)
        self.events: list[tuple[float, str, dict]] = []
        self._lock = threading.Lock()

    def _record(self, kind: str, **detail) -> None:
        with self._lock:
            self.events.append((self.clock.now(), kind, detail))

    # ---- failure handlers ------------------------------------------------
    def on_endpoint_failure(self, idx: int, reason: str = "") -> int:
        """A dead endpoint: re-point every group whose primary it was.  The
        senders' in-flight retries then land on the new primary, and any
        unacked WAL tail replays there (seq dedupe keeps it exact)."""
        groups = self.broker.reroute_from_endpoint(idx) \
            if self.broker is not None else 0
        unacked = self.broker.unacked_records() \
            if self.broker is not None else 0
        self._record("endpoint_failover", endpoint=idx, groups=groups,
                     unacked=unacked, reason=reason)
        return groups

    def on_executor_failure(self, idx: int, reason: str = "") -> None:
        """A dead executor: replace it; its queued partitions are re-dealt
        to survivors by the engine (no records were lost — they had already
        left the WAL's responsibility once applied by an endpoint)."""
        if self.engine is not None:
            self.engine.replace_executor(idx)
        self._record("executor_replaced", executor=idx, reason=reason)

    def on_broker_restart(self, replayed: int) -> None:
        """Log hook for ``Session.restart_broker`` (the restart itself is
        orchestrated by the session, which owns broker construction)."""
        self._record("broker_restarted", replay_backlog=replayed)

    # ---- observability ---------------------------------------------------
    def summary(self) -> dict:
        with self._lock:
            counts: dict[str, int] = {}
            for _, kind, _ in self.events:
                counts[kind] = counts.get(kind, 0) + 1
            return counts
