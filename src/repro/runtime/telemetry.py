"""Unified telemetry bus — the control plane's sensor layer.

Every layer of the HPC→Cloud pipeline already kept private counters (the
broker's per-sender stats, the endpoints' ingest totals, the engine's
results); :class:`TelemetryBus` samples them into one immutable
:class:`TelemetrySnapshot` per tick:

  * per-group broker state — live queue depth, drop/error *rates* (computed
    as deltas between consecutive samples), wire batch cap,
  * per-endpoint ingest rate and pending backlog,
  * per-executor queue depth / steal counts,
  * rolling p50/p99 generation→analysis latency (the paper's §4.3 QoS
    metric, over the engine's windowed recent results).

Snapshots fan out to subscribers (the :class:`repro.runtime.controller.
ElasticController` closes the loop on them) and accumulate in a bounded
history so policies can reason about trends, not just instants.  The bus
holds weak expectations of its sources — anything exposing
``group_telemetry()`` / ``telemetry()`` / ``metrics()`` works — so it stays
import-free of broker/engine internals.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from dataclasses import dataclass

from repro.runtime.clock import Clock, ensure_clock


@dataclass(frozen=True)
class GroupTelemetry:
    """One broker group sender, sampled."""

    group: int
    queue_depth: int
    queue_capacity: int
    batch_cap: int
    primary: int
    written: int
    sent: int
    dropped: int
    send_errors: int
    drop_rate: float = 0.0        # records/s since previous sample
    error_rate: float = 0.0       # send errors/s since previous sample
    send_rate: float = 0.0        # delivered records/s since previous sample


@dataclass(frozen=True)
class ShardTelemetry:
    """One broker shard (group-owning slice of the sharded fan-in),
    sampled: how much backlog and traffic its groups carry together."""

    shard: int
    groups: int
    queue_depth: int
    written: int
    sent: int
    dropped: int
    send_errors: int
    rerouted: int
    endpoints: int
    send_rate: float = 0.0        # delivered records/s since previous sample


@dataclass(frozen=True)
class EndpointTelemetry:
    name: str
    healthy: bool
    pending: int                  # undrained records buffered
    records_in: int
    ingest_rate_rps: float


@dataclass(frozen=True)
class ExecutorTelemetry:
    idx: int
    alive: bool
    queue_depth: int              # micro-batches waiting
    queued_records: int           # records inside those micro-batches
    processed: int
    stolen: int


@dataclass(frozen=True)
class TenantTelemetry:
    """One tenant's QoS rollup: the registry's declared contract plus the
    broker's loss-ledger counters and the engine's per-tenant latency."""

    name: str
    priority: int = 0
    p99_target_s: float | None = None
    weight: float = 1.0
    admitted: int = 0
    sent: int = 0
    dropped: int = 0
    evicted: int = 0
    quota_rejected: int = 0
    backlog: int = 0              # queued + parked records in the broker
    parked: int = 0               # currently parked (subset of backlog)
    analyzed: int = 0
    latency_p50: float = math.nan
    latency_p99: float = math.nan
    latency_n: int = 0            # samples in the rolling window


@dataclass(frozen=True)
class TelemetrySnapshot:
    """One consistent-enough control-plane sample across all layers."""

    t: float
    groups: tuple[GroupTelemetry, ...] = ()
    shards: tuple[ShardTelemetry, ...] = ()
    endpoints: tuple[EndpointTelemetry, ...] = ()
    executors: tuple[ExecutorTelemetry, ...] = ()
    held_records: int = 0         # engine hold-buffer backlog
    alive_executors: int = 0
    queued_partitions: int = 0    # micro-batches waiting on executors
    latency_p50: float = math.nan
    latency_p99: float = math.nan
    latency_n: int = 0            # samples in the rolling window
    executor_seconds: float = 0.0
    tenants: tuple[TenantTelemetry, ...] = ()   # QoS plane rollups (by name)

    @property
    def backlog(self) -> int:
        """Total records not yet analyzed anywhere in the pipeline: broker
        queues + endpoint buffers + engine hold + records queued on
        executors — the load signal scale-up policies watch.  (Executor
        queues matter most: when analysis saturates, dispatch keeps up and
        the pile-up happens there.)"""
        return (sum(g.queue_depth for g in self.groups)
                + sum(e.pending for e in self.endpoints)
                + self.held_records
                + sum(x.queued_records for x in self.executors if x.alive))


@dataclass
class _GroupPrev:
    t: float = 0.0
    dropped: int = 0
    send_errors: int = 0
    sent: int = 0


@dataclass
class _ShardPrev:
    t: float = 0.0
    sent: int = 0


class TelemetryBus:
    """Samples broker + endpoints + engine into TelemetrySnapshots, keeps a
    bounded history, and fans snapshots out to subscribers.

    All sources are optional and attachable after construction (the Session
    creates its engine lazily): ``attach_engine`` late-binds the consumer
    side.  ``sample()`` is safe from any thread; subscriber callbacks run on
    the sampling thread and must not block.
    """

    def __init__(self, *, broker=None, endpoints=(), engine=None,
                 history: int = 256, clock: Clock | None = None,
                 tenants=None):
        self.broker = broker
        self.endpoints = list(endpoints)
        self.engine = engine
        self.tenants = tenants      # TenantRegistry (duck-typed), or None
        self.clock = ensure_clock(clock)
        self.history: deque[TelemetrySnapshot] = deque(maxlen=history)
        self._subs: list = []
        self._prev: dict[int, _GroupPrev] = {}
        self._shard_prev: dict[int, _ShardPrev] = {}
        self._lock = threading.Lock()

    def attach_engine(self, engine) -> None:
        self.engine = engine

    def subscribe(self, cb) -> None:
        """cb(snapshot) on every sample()."""
        self._subs.append(cb)

    def last(self) -> TelemetrySnapshot | None:
        with self._lock:
            return self.history[-1] if self.history else None

    # ---- sampling --------------------------------------------------------
    def _sample_groups(self, now: float) -> tuple[GroupTelemetry, ...]:
        if self.broker is None:
            return ()
        out = []
        for row in self.broker.group_telemetry():
            g = row["group"]
            prev = self._prev.get(g)
            dt = (now - prev.t) if prev else 0.0
            if prev and dt > 1e-6:
                drop_rate = (row["dropped"] - prev.dropped) / dt
                error_rate = (row["send_errors"] - prev.send_errors) / dt
                send_rate = (row["sent"] - prev.sent) / dt
            else:
                drop_rate = error_rate = send_rate = 0.0
            self._prev[g] = _GroupPrev(t=now, dropped=row["dropped"],
                                       send_errors=row["send_errors"],
                                       sent=row["sent"])
            out.append(GroupTelemetry(
                group=g, queue_depth=row["queue_depth"],
                queue_capacity=row["queue_capacity"],
                batch_cap=row["batch_cap"], primary=row["primary"],
                written=row["written"], sent=row["sent"],
                dropped=row["dropped"], send_errors=row["send_errors"],
                drop_rate=drop_rate, error_rate=error_rate,
                send_rate=send_rate))
        return tuple(out)

    def _sample_shards(self, now: float) -> tuple[ShardTelemetry, ...]:
        """Per-shard rollups from a sharded broker (``shard_telemetry()``);
        () for brokers without shards — policies treat that as 'no shard
        signal' and fall back to fleet-level thresholds."""
        shard_fn = getattr(self.broker, "shard_telemetry", None)
        if shard_fn is None:
            return ()
        out = []
        for row in shard_fn():
            sid = row["shard"]
            prev = self._shard_prev.get(sid)
            dt = (now - prev.t) if prev else 0.0
            send_rate = (row["sent"] - prev.sent) / dt \
                if prev and dt > 1e-6 else 0.0
            self._shard_prev[sid] = _ShardPrev(t=now, sent=row["sent"])
            out.append(ShardTelemetry(
                shard=sid, groups=row["groups"],
                queue_depth=row["queue_depth"], written=row["written"],
                sent=row["sent"], dropped=row["dropped"],
                send_errors=row["send_errors"], rerouted=row["rerouted"],
                endpoints=row["endpoints"], send_rate=send_rate))
        return tuple(out)

    def _sample_endpoints(self) -> tuple[EndpointTelemetry, ...]:
        out = []
        for ep in self.endpoints:
            t = ep.telemetry()
            out.append(EndpointTelemetry(
                name=t["name"], healthy=t["healthy"], pending=t["pending"],
                records_in=t["records_in"],
                ingest_rate_rps=t["ingest_rate_rps"]))
        return tuple(out)

    def _sample_tenants(self, engine_metrics: dict | None) \
            -> tuple[TenantTelemetry, ...]:
        """Join the broker's per-tenant loss ledger with the engine's
        per-tenant latency under the registry's declared contracts; ()
        without a registry (single-tenant deployments pay nothing)."""
        if self.tenants is None:
            return ()
        broker_rows = {}
        tenant_fn = getattr(self.broker, "tenant_telemetry", None)
        if tenant_fn is not None:
            broker_rows = tenant_fn()
        eng_rows = (engine_metrics or {}).get("tenants", {})
        out = []
        for name in self.tenants.names():
            spec = self.tenants.spec(name)
            b = broker_rows.get(name, {})
            e = eng_rows.get(name, {})
            out.append(TenantTelemetry(
                name=name, priority=spec.priority,
                p99_target_s=spec.p99_target_s, weight=spec.weight,
                admitted=b.get("admitted", 0), sent=b.get("sent", 0),
                dropped=b.get("dropped", 0), evicted=b.get("evicted", 0),
                quota_rejected=b.get("quota_rejected", 0),
                backlog=b.get("backlog", 0), parked=b.get("parked", 0),
                analyzed=e.get("analyzed", 0),
                latency_p50=e.get("latency_p50", math.nan),
                latency_p99=e.get("latency_p99", math.nan),
                latency_n=e.get("latency_window_n", 0)))
        return tuple(out)

    def sample(self) -> TelemetrySnapshot:
        now = self.clock.now()
        with self._lock:
            groups = self._sample_groups(now)
            shards = self._sample_shards(now)
        endpoints = self._sample_endpoints()
        executors: tuple[ExecutorTelemetry, ...] = ()
        held = queued = alive = lat_n = 0
        p50 = p99 = math.nan
        exec_secs = 0.0
        m = None
        if self.engine is not None:
            m = self.engine.metrics()
            executors = tuple(ExecutorTelemetry(
                idx=e["idx"], alive=e["alive"],
                queue_depth=e["queue_depth"],
                queued_records=e["queued_records"], processed=e["processed"],
                stolen=e["stolen"]) for e in m["executors"])
            held = m["held_records"]
            queued = m["queued"]
            alive = m["alive_executors"]
            p50, p99 = m["latency_p50"], m["latency_p99"]
            lat_n = m["latency_window_n"]
            exec_secs = m["executor_seconds"]
        snap = TelemetrySnapshot(
            t=now, groups=groups, shards=shards,
            endpoints=endpoints, executors=executors,
            held_records=held, queued_partitions=queued,
            alive_executors=alive, latency_p50=p50, latency_p99=p99,
            latency_n=lat_n, executor_seconds=exec_secs,
            tenants=self._sample_tenants(m))
        with self._lock:
            self.history.append(snap)
        for cb in list(self._subs):
            try:
                cb(snap)
            except Exception:       # a broken subscriber must not kill the bus
                pass
        return snap
