"""QoS-driven elasticity controller — the control plane's actuator layer.

The system is *named* ElasticBroker; this module is where elasticity stops
being manual.  An :class:`ElasticController` thread consumes
:class:`repro.runtime.telemetry.TelemetrySnapshot`s and closes the loop the
paper leaves open (§6 "adjusting cloud resources according to the amount of
data"):

  * **scale out** when the rolling p99 generation→analysis latency breaches
    the QoS target or backlog piles up anywhere in the pipeline
    (broker queues, endpoint buffers, engine hold),
  * **scale in** after sustained quiet, down to ``min_executors``,
  * **adapt wire aggregation**: each broker sender's ``batch_cap`` follows
    its queue depth (deep queue ⇒ bigger frames amortize; drained queue ⇒
    smaller frames keep latency low),
  * **react to failure**: heartbeats are pumped into a
    :class:`repro.runtime.fault.FailureDetector`; a dead endpoint proactively
    re-routes its groups, a dead or persistently-straggling executor is
    replaced and its partitions rebalanced.

Policies are pluggable: anything with ``decide(snapshot, history) ->
list[Action]`` can be handed to the controller, so deployments can bring
their own scaling logic without touching the loop.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

from repro.runtime.clock import Clock, ensure_clock
from repro.runtime.fault import FailureDetector, NodeState
from repro.runtime.telemetry import TelemetryBus, TelemetrySnapshot


@dataclass(frozen=True)
class ElasticityConfig:
    """The control-plane knob block (embedded in WorkflowConfig)."""

    enabled: bool = False
    interval_s: float = 0.25          # control loop period
    target_p99_s: float = 1.0         # QoS: generation→analysis p99 latency
    min_executors: int = 1
    max_executors: int = 64
    scale_up_step: int = 2            # executors added per breach
    backlog_high: int = 64            # records pending anywhere ⇒ breach
    # per-shard breach: one broker shard holding more than this many
    # unsent records triggers scale-up even while the fleet-wide backlog
    # is under backlog_high — a hot shard hides inside a calm total.
    # None disables the shard signal (unsharded brokers report no shards).
    shard_backlog_high: int | None = None
    idle_scale_down_s: float = 3.0    # sustained quiet before scale-in
    cooldown_s: float = 1.0           # min gap between scale actions
    adapt_batch: bool = True          # drive per-sender batch_cap from depth
    batch_cap_min: int = 1
    batch_cap_max: int = 256
    heartbeat_timeout_s: float = 1.0  # FailureDetector miss window
    # -- predictive scale-up (TrendScalePolicy) ---------------------------
    # fit a least-squares slope over the last ``trend_window`` telemetry
    # snapshots and scale out when the projection ``trend_horizon_s`` ahead
    # breaches the p99 target or backlog threshold — BEFORE the breach lands
    predictive: bool = False
    trend_window: int = 8             # snapshots in the slope fit (>= 3)
    trend_horizon_s: float = 1.0      # how far ahead to project
    # 4x margin: a merely-loaded executor working through big batches beats
    # ~2-3x slower than idle peers and must not read as a straggler
    straggler_factor: float = 4.0
    replace_stragglers: bool = True
    # an executor mid-analysis emits no beats (that's how stragglers stand
    # out), so a single analyze call longer than heartbeat_timeout_s trips
    # the failure scan; the controller revives it unless the SAME analysis
    # has run longer than this — only then is the executor deemed wedged
    stuck_analysis_s: float = 30.0
    # -- cloud capacity plane (repro.cloud.CloudProvisioner) --------------
    # when ``provision`` is on, scale-out becomes an async provision
    # request for whole nodes of ``node_class`` (capacity arrives after a
    # cold start) and scale-in drains a node before powering it off
    provision: bool = False
    node_class: str = "standard"      # DEFAULT_CATALOG entry for scale-out
    provision_retry_limit: int = 3    # power_on attempts before FAILED
    provision_backoff_s: float = 0.5  # retry backoff base (doubles/attempt)
    # predictive horizon is floored at cold-start + this margin, so the
    # TrendScalePolicy asks for capacity early enough for it to boot
    cold_start_margin_s: float = 0.5
    # heterogeneous fleet bin-packing: when non-empty, each scale-out
    # decision packs a MIX of these node classes (big nodes cover the bulk
    # of the deficit, the smallest covering class trims the remainder)
    # instead of rounding the whole request up to ``node_class`` units
    node_classes: tuple = ()
    # -- multi-tenant QoS (repro.tenancy) ---------------------------------
    # scale decisions weigh accumulated per-tenant SLO debt (weight ×
    # breach-seconds over each tenant's declared p99 target) instead of the
    # single global target_p99_s; requires WorkflowConfig.tenants
    slo_debt: bool = False
    debt_high_s: float = 0.5          # weighted breach-seconds forcing scale-up
    debt_decay: float = 1.0           # debt paid down per under-target second

    def validate(self) -> "ElasticityConfig":
        if self.interval_s <= 0:
            raise ValueError("interval_s must be > 0")
        if self.target_p99_s <= 0:
            raise ValueError("target_p99_s must be > 0")
        if not (1 <= self.min_executors <= self.max_executors):
            raise ValueError(
                f"need 1 <= min_executors <= max_executors, got "
                f"{self.min_executors}..{self.max_executors}")
        if self.scale_up_step < 1:
            raise ValueError("scale_up_step must be >= 1")
        if self.shard_backlog_high is not None and self.shard_backlog_high < 1:
            raise ValueError("shard_backlog_high must be >= 1 (or None)")
        if not (1 <= self.batch_cap_min <= self.batch_cap_max):
            raise ValueError("need 1 <= batch_cap_min <= batch_cap_max")
        if self.idle_scale_down_s < 0 or self.cooldown_s < 0:
            raise ValueError("idle_scale_down_s and cooldown_s must be >= 0")
        if self.heartbeat_timeout_s <= 0:
            raise ValueError("heartbeat_timeout_s must be > 0")
        if self.trend_window < 3:
            raise ValueError("trend_window must be >= 3 (a slope needs "
                             "history)")
        if self.trend_horizon_s <= 0:
            raise ValueError("trend_horizon_s must be > 0")
        if self.stuck_analysis_s <= 0:
            raise ValueError("stuck_analysis_s must be > 0")
        if self.provision_retry_limit < 1:
            raise ValueError("provision_retry_limit must be >= 1")
        if self.provision_backoff_s < 0:
            raise ValueError("provision_backoff_s must be >= 0")
        if self.cold_start_margin_s < 0:
            raise ValueError("cold_start_margin_s must be >= 0")
        if self.provision and not self.node_class:
            raise ValueError("provision=True needs a node_class")
        if any(not isinstance(n, str) or not n for n in self.node_classes):
            raise ValueError("node_classes entries must be non-empty names")
        if self.debt_high_s <= 0:
            raise ValueError("debt_high_s must be > 0")
        if self.debt_decay < 0:
            raise ValueError("debt_decay must be >= 0")
        return self


@dataclass(frozen=True)
class Action:
    """One control decision (recorded in the controller's action log)."""

    kind: str                     # scale_up | scale_down | set_batch_cap |
                                  # replace_executor | reroute_endpoint
    value: int | None = None
    group: int | None = None
    reason: str = ""


class LatencyScalePolicy:
    """Scale executors from the QoS signal: out on p99/backlog breach (with
    cooldown), in after ``idle_scale_down_s`` of empty pipeline.

    With ``cfg.shard_backlog_high`` set, the per-shard rows of a sharded
    broker (``TelemetrySnapshot.shards``) are a third breach source: one
    shard's queue depth crossing the per-shard threshold scales the fleet
    out even when the fleet-wide backlog still reads calm."""

    def __init__(self, cfg: ElasticityConfig):
        self.cfg = cfg
        # -inf: the first breach must scale regardless of cooldown — a 0.0
        # origin would silently absorb the first cooldown_s of a clock that
        # starts near zero (VirtualClock does)
        self._last_scale = float("-inf")
        self._quiet_since: float | None = None

    def decide(self, snap: TelemetrySnapshot, history) -> list[Action]:
        cfg = self.cfg
        now = snap.t
        p99_breach = (snap.latency_n > 0
                      and snap.latency_p99 > cfg.target_p99_s)
        backlog_breach = snap.backlog > cfg.backlog_high
        hot_shard = None
        if cfg.shard_backlog_high is not None and snap.shards:
            worst = max(snap.shards, key=lambda s: s.queue_depth)
            if worst.queue_depth > cfg.shard_backlog_high:
                hot_shard = worst
        if p99_breach or backlog_breach or hot_shard is not None:
            self._quiet_since = None
            if (now - self._last_scale >= cfg.cooldown_s
                    and snap.alive_executors < cfg.max_executors):
                step = min(cfg.scale_up_step,
                           cfg.max_executors - snap.alive_executors)
                self._last_scale = now
                if p99_breach:
                    why = f"p99={snap.latency_p99:.3f}s>target"
                elif backlog_breach:
                    why = f"backlog={snap.backlog}"
                else:
                    why = (f"shard{hot_shard.shard} backlog="
                           f"{hot_shard.queue_depth}>"
                           f"{cfg.shard_backlog_high}")
                return [Action("scale_up", value=step, reason=why)]
            return []
        quiet = snap.backlog == 0 and snap.queued_partitions == 0
        if quiet and snap.alive_executors > cfg.min_executors:
            if self._quiet_since is None:
                self._quiet_since = now
            elif (now - self._quiet_since >= cfg.idle_scale_down_s
                    and now - self._last_scale >= cfg.cooldown_s):
                self._last_scale = now
                self._quiet_since = now      # one step per quiet window
                return [Action("scale_down", value=1,
                               reason=f"idle {cfg.idle_scale_down_s:.1f}s")]
        elif not quiet:
            self._quiet_since = None
        return []


class TrendScalePolicy:
    """Predictive scale-out (ROADMAP follow-up): instead of waiting for the
    p99 breach, fit a least-squares slope over the last ``trend_window``
    TelemetrySnapshots and act when the projection ``trend_horizon_s`` ahead
    crosses the target.  Backlog is the leading indicator (it rises a full
    queue-drain ahead of the latency percentile), so both series are
    projected and either can trigger.  Scale-in is deliberately NOT done
    here — the reactive :class:`LatencyScalePolicy` owns it, so the two
    compose (Session wires Trend *before* Latency when
    ``cfg.predictive``)."""

    def __init__(self, cfg: ElasticityConfig, horizon_s: float | None = None):
        self.cfg = cfg
        # horizon override: with a CloudProvisioner attached the projection
        # must look past the node-class cold start, or capacity lands late
        self.horizon_s = (cfg.trend_horizon_s if horizon_s is None
                          else float(horizon_s))
        self._last_scale = float("-inf")     # see LatencyScalePolicy note

    @staticmethod
    def _slope(points: list[tuple[float, float]]) -> float:
        """Least-squares d(value)/dt; 0 for degenerate spans."""
        n = len(points)
        if n < 2:
            return 0.0
        mt = sum(t for t, _ in points) / n
        mv = sum(v for _, v in points) / n
        den = sum((t - mt) ** 2 for t, _ in points)
        if den <= 1e-12:
            return 0.0
        return sum((t - mt) * (v - mv) for t, v in points) / den

    def decide(self, snap: TelemetrySnapshot, history) -> list[Action]:
        cfg = self.cfg
        window = list(history)[-cfg.trend_window:]
        if len(window) < 3:
            return []
        now = snap.t
        h = self.horizon_s
        lat_pts = [(s.t, s.latency_p99) for s in window if s.latency_n > 0]
        back_pts = [(s.t, float(s.backlog)) for s in window]
        proj_p99 = (snap.latency_p99 + self._slope(lat_pts) * h
                    if len(lat_pts) >= 3 and snap.latency_n > 0
                    else float("-inf"))
        proj_backlog = snap.backlog + self._slope(back_pts) * h
        p99_rising = proj_p99 > cfg.target_p99_s
        backlog_rising = proj_backlog > cfg.backlog_high
        if not (p99_rising or backlog_rising):
            return []
        if (now - self._last_scale < cfg.cooldown_s
                or snap.alive_executors >= cfg.max_executors):
            return []
        step = min(cfg.scale_up_step,
                   cfg.max_executors - snap.alive_executors)
        self._last_scale = now
        why = (f"projected p99={proj_p99:.3f}s>target in {h:.1f}s"
               if p99_rising else
               f"projected backlog={proj_backlog:.0f}>{cfg.backlog_high} "
               f"in {h:.1f}s")
        return [Action("scale_up", value=step, reason=why)]


class SloDebtScalePolicy:
    """Debt-weighted multi-tenant scale-out (the tenancy plane's policy).

    Each tenant with a declared p99 target accumulates *SLO debt* —
    ``weight × (p99 − target)`` integrated over breach time — and pays it
    down at ``cfg.debt_decay`` per under-target second.  Scale-out fires
    when any SLO tenant is over target *right now* or when total
    outstanding debt crosses ``cfg.debt_high_s``: a heavily-weighted
    tenant that has been quietly over budget forces capacity even while a
    fleet-global p99 (dragged down by happy best-effort traffic) still
    reads fine.  Best-effort tenants (no target) carry no debt and never
    trigger scale-out — their pain is the broker's parking/eviction
    plane, not the fleet's.

    Scale-in is deliberately not done here; the reactive
    :class:`LatencyScalePolicy` owns it (same composition contract as
    :class:`TrendScalePolicy`)."""

    def __init__(self, cfg: ElasticityConfig, tenants=None):
        self.cfg = cfg
        self.tenants = tenants               # TenantRegistry (informational)
        self.debt: dict[str, float] = {}     # tenant -> breach-seconds owed
        self._last_t: float | None = None
        self._last_scale = float("-inf")     # see LatencyScalePolicy note

    def decide(self, snap: TelemetrySnapshot, history) -> list[Action]:
        cfg = self.cfg
        now = snap.t
        dt = 0.0 if self._last_t is None else max(0.0, now - self._last_t)
        self._last_t = now
        over_now = False
        for row in snap.tenants:
            if row.p99_target_s is None:
                continue                     # best-effort: no debt, ever
            d = self.debt.get(row.name, 0.0)
            if row.latency_n > 0 and row.latency_p99 > row.p99_target_s:
                over_now = True
                d += row.weight * (row.latency_p99 - row.p99_target_s) * dt
            else:
                d = max(0.0, d - cfg.debt_decay * dt)
            self.debt[row.name] = d
        total = sum(self.debt.values())
        if not (over_now or total > cfg.debt_high_s):
            return []
        if (now - self._last_scale < cfg.cooldown_s
                or snap.alive_executors >= cfg.max_executors):
            return []
        step = min(cfg.scale_up_step,
                   cfg.max_executors - snap.alive_executors)
        self._last_scale = now
        worst = max((r for r in snap.tenants if r.p99_target_s is not None),
                    key=lambda r: self.debt.get(r.name, 0.0), default=None)
        if worst is not None:
            why = (f"tenant {worst.name} "
                   f"debt={self.debt.get(worst.name, 0.0):.2f}s "
                   f"(total={total:.2f}s)")
        else:
            why = f"slo debt total={total:.2f}s"
        return [Action("scale_up", value=step, reason=why)]


class BatchCapPolicy:
    """Adapt each sender's wire batch cap to its queue depth with hysteresis:
    a queue ≥2× the cap doubles aggregation (amortize framing under load); a
    queue below cap/4 decays the cap back toward the configured baseline
    (small frames ⇒ low latency when drained)."""

    def __init__(self, cfg: ElasticityConfig, baseline: int = 32):
        self.cfg = cfg
        self.baseline = max(cfg.batch_cap_min,
                            min(cfg.batch_cap_max, baseline))

    def decide(self, snap: TelemetrySnapshot, history) -> list[Action]:
        cfg = self.cfg
        acts = []
        for g in snap.groups:
            cap, depth = g.batch_cap, g.queue_depth
            new = cap
            if depth >= 2 * cap:
                new = min(cfg.batch_cap_max, max(2 * cap, depth))
            elif depth <= cap // 4 and cap > self.baseline:
                new = max(self.baseline, cap // 2)
            if new != cap:
                acts.append(Action("set_batch_cap", value=new, group=g.group,
                                   reason=f"depth={depth} cap={cap}"))
        return acts


class ElasticController(threading.Thread):
    """The loop: sample telemetry → run policies → actuate engine/broker,
    plus heartbeat pumping and FailureDetector-driven recovery.

    Owns nothing it actuates — engine/broker/detector are injected, so the
    controller can be run against any wiring (Session does this) or driven
    tick-by-tick in tests via :meth:`tick`.
    """

    def __init__(self, bus: TelemetryBus, cfg: ElasticityConfig | None = None,
                 *, engine=None, broker=None,
                 detector: FailureDetector | None = None, policies=None,
                 clock: Clock | None = None, recovery=None,
                 provisioner=None, tenants=None):
        super().__init__(daemon=True, name="elastic-controller")
        self.bus = bus
        self.cfg = (cfg or ElasticityConfig(enabled=True)).validate()
        # exactly-once wiring: a RecoverySupervisor (runtime.recovery) turns
        # detector-driven failures into WAL replay instead of lossy reroute
        self.recovery = recovery
        # cloud capacity plane: when set, scale decisions actuate through
        # the provisioner (async provision / drain-before-poweroff) instead
        # of instant engine add/remove
        self.provisioner = provisioner
        # multi-tenant QoS: the TenantRegistry backing SloDebtScalePolicy
        self.tenants = tenants
        # one schedule for the whole loop: default to the bus's clock so a
        # virtual-time bus implies a virtual-time controller
        self.clock = ensure_clock(clock if clock is not None else bus.clock)
        self.engine = engine if engine is not None else bus.engine
        self.broker = broker if broker is not None else bus.broker
        self.detector = detector or FailureDetector(
            timeout_s=self.cfg.heartbeat_timeout_s,
            straggler_factor=self.cfg.straggler_factor,
            clock=self.clock)
        if policies is None:
            baseline = getattr(getattr(self.broker, "cfg", None),
                               "max_batch_records", 32)
            policies = []
            if self.cfg.predictive:
                horizon = None
                if self.provisioner is not None:
                    # with a heterogeneous fleet the projection must clear
                    # the SLOWEST cold start a pack decision might pick
                    names = self.cfg.node_classes or (self.cfg.node_class,)
                    horizon = max(
                        [self.cfg.trend_horizon_s]
                        + [self.provisioner.expected_ready_s(n)
                           + self.cfg.cold_start_margin_s for n in names])
                policies.append(TrendScalePolicy(self.cfg, horizon_s=horizon))
            if self.cfg.slo_debt and self.tenants is not None:
                # debt policy runs first: the one-scale_up-per-tick guard
                # means the tenant-aware decision wins over the global one
                policies.append(SloDebtScalePolicy(self.cfg, self.tenants))
            policies.append(LatencyScalePolicy(self.cfg))
            if self.cfg.adapt_batch:
                policies.append(BatchCapPolicy(self.cfg, baseline=baseline))
        self.policies = list(policies)
        self.actions_log: list[tuple[float, Action]] = []
        self.apply_errors = 0
        self._stop_evt = threading.Event()
        self._exec_processed: dict[int, int] = {}
        self.detector.on_failure.append(self._on_node_failure)
        self.detector.on_straggler.append(self._on_straggler)

    # ---- heartbeats ------------------------------------------------------
    def _pump_heartbeats(self) -> None:
        det = self.detector
        for ep in self.bus.endpoints:
            name = getattr(ep, "name", None)
            if name is None:
                continue
            if getattr(ep, "retired", False):
                continue    # deliberately powered off, not a failure
            if name not in det.nodes:
                det.register(name, "endpoint")
            # a draining endpoint reads unhealthy to senders but is alive
            # (it's emptying its queue); don't let the detector fire on it
            if ep.healthy() or getattr(ep, "draining", False):
                det.beat(name)
        if self.engine is not None:
            for e in self.engine.metrics()["executors"]:
                name = f"executor-{e['idx']}"
                if not e["alive"]:
                    continue
                if name not in det.nodes:
                    det.register(name, "executor")
                prev = self._exec_processed.get(e["idx"], 0)
                # proof of life: progress, an ordering-ticket wait, or true
                # idleness (nothing queued AND nothing being analyzed).  An
                # executor stuck *inside* an analysis gets no beat, so a
                # straggler's long service times stand out against its peers
                if (e["processed"] > prev or e.get("waiting")
                        or (e["queue_depth"] == 0
                            and e["current_key"] is None)):
                    det.beat(name)
                self._exec_processed[e["idx"]] = e["processed"]

    # ---- detector callbacks ---------------------------------------------
    def _endpoint_index(self, name: str) -> int | None:
        for i, ep in enumerate(self.bus.endpoints):
            if getattr(ep, "name", None) == name:
                return i
        return None

    def _on_node_failure(self, node: NodeState) -> None:
        if node.kind == "endpoint" and self.broker is not None:
            idx = self._endpoint_index(node.name)
            if idx is not None:
                kind = "recover_endpoint" if self.recovery is not None \
                    else "reroute_endpoint"
                self._apply(Action(kind, value=idx,
                                   reason=f"{node.name} heartbeat lost"))
        elif node.kind == "executor" and self.engine is not None:
            idx = int(node.name.rsplit("-", 1)[-1])
            ex = self.engine.executors[idx]
            if not ex.alive:
                return
            # busy ≠ dead: an executor mid-analysis emits no beats by
            # design; revive it unless this one analysis has overrun the
            # wedge threshold
            if (ex.current_key is not None
                    and self.clock.now() - ex.t_busy_since
                    < self.cfg.stuck_analysis_s):
                node.alive = True
                self.detector.beat(node.name)
                return
            self._apply(Action("replace_executor", value=idx,
                               reason=f"{node.name} heartbeat lost"))

    def _on_straggler(self, node: NodeState) -> None:
        if (node.kind == "executor" and self.engine is not None
                and self.cfg.replace_stragglers):
            idx = int(node.name.rsplit("-", 1)[-1])
            if self.engine.executors[idx].alive:
                self._apply(Action("replace_executor", value=idx,
                                   reason=f"{node.name} straggling"))

    # ---- cloud capacity plane -------------------------------------------
    def _provision_up(self, action: Action) -> Action | None:
        """Turn a scale_up decision into async node provision requests.

        Capacity already in flight (pending/booting nodes) counts against
        the request, so a breach that persists through a cold start does
        not trigger a second wave for the same deficit (flap suppression).

        With ``cfg.node_classes`` set, the deficit is bin-packed across a
        heterogeneous fleet (``repro.cloud.provisioner.pack_nodes``): big
        classes absorb the spike, the smallest covering class trims the
        remainder — instead of rounding the whole request up to
        ``node_class`` units.
        """
        from repro.cloud.provisioner import pack_nodes

        prov = self.provisioner
        alive = (self.engine.metrics()["alive_executors"]
                 if self.engine is not None else 0)
        # a FAILED node is capacity the fleet already decided it wants;
        # recover it before asking for brand-new nodes
        recovered = prov.recover()
        inflight = prov.capacity_in_flight()
        room = self.cfg.max_executors - alive - inflight
        want = max(action.value or 1, 1)
        names = self.cfg.node_classes or (self.cfg.node_class,)
        classes = [prov.node_class(n) for n in names]
        picked = pack_nodes(min(want, max(room, 0)), classes)
        chosen = []
        total = 0
        for cls in picked:                   # front-to-back room clamp
            if total + cls.executors <= room:
                chosen.append(cls)
                total += cls.executors
        if not chosen:
            return (Action("provision", value=0, reason=action.reason)
                    if recovered else None)
        for cls in chosen:
            prov.request_node(cls.name)
        reason = action.reason
        if len(names) > 1:                   # surface the mix when packing
            counts: dict[str, int] = {}
            for cls in chosen:
                counts[cls.name] = counts.get(cls.name, 0) + 1
            mix = "+".join(f"{n}x{name}" for name, n in counts.items())
            reason = f"{reason} [{mix}]"
        return Action("provision", value=len(chosen), group=action.group,
                      reason=reason)

    def _provision_down(self, action: Action) -> Action | None:
        """Turn a scale_down decision into a drain-before-poweroff.

        Only a READY node may be released (never one still booting, never
        one already draining), and only if losing its executors keeps the
        fleet at or above min_executors.
        """
        prov = self.provisioner
        alive = (self.engine.metrics()["alive_executors"]
                 if self.engine is not None else 0)
        node = prov.pick_poweroff(
            lambda n: alive - n.node_class.executors >= self.cfg.min_executors)
        if node is None:
            return None
        prov.request_poweroff(node)
        return Action("drain_node", value=node.node_id,
                      reason=action.reason)

    # ---- actuation -------------------------------------------------------
    def _apply(self, action: Action) -> None:
        try:
            if action.kind == "scale_up" and self.provisioner is not None:
                action = self._provision_up(action)
                if action is None:
                    return
            elif action.kind == "scale_down" and self.provisioner is not None:
                action = self._provision_down(action)
                if action is None:
                    return
            elif action.kind == "scale_up" and self.engine is not None:
                # hard cap regardless of which policy asked: two policies
                # deciding from the same (stale) snapshot must not push the
                # fleet past max_executors
                alive = self.engine.metrics()["alive_executors"]
                step = min(action.value or 1,
                           max(0, self.cfg.max_executors - alive))
                if step == 0:
                    return
                action = Action("scale_up", value=step, group=action.group,
                                reason=action.reason)
                for _ in range(step):
                    self.engine.add_executor()
            elif action.kind == "scale_down" and self.engine is not None:
                for _ in range(action.value or 1):
                    self.engine.remove_executor()
            elif action.kind == "set_batch_cap" and self.broker is not None:
                self.broker.set_batch_cap(action.value, group=action.group)
            elif action.kind == "replace_executor" and self.engine is not None:
                if self.recovery is not None:
                    self.recovery.on_executor_failure(action.value,
                                                      reason=action.reason)
                else:
                    self.engine.replace_executor(action.value)
            elif action.kind == "reroute_endpoint" and self.broker is not None:
                self.broker.reroute_from_endpoint(action.value)
            elif action.kind == "recover_endpoint" and self.recovery is not None:
                self.recovery.on_endpoint_failure(action.value,
                                                  reason=action.reason)
            self.actions_log.append((self.clock.now(), action))
        except Exception:
            self.apply_errors += 1

    # ---- the loop --------------------------------------------------------
    def tick(self) -> TelemetrySnapshot:
        """One control period: heartbeats → failure scan → sample →
        policies → actuate.  Public so tests/benches can drive the loop
        deterministically without the thread."""
        if self.engine is None and self.bus.engine is not None:
            self.engine = self.bus.engine        # Session attaches it lazily
        if self.provisioner is not None:
            # advance the capacity plane first: boots that completed land
            # before this tick's policies look at alive_executors
            self.provisioner.process_pending_tasks()
        self._pump_heartbeats()
        self.detector.scan()
        snap = self.bus.sample()
        scaled_up = False
        for policy in self.policies:
            for action in policy.decide(snap, self.bus.history):
                if action.kind == "scale_up":
                    # one scale-up per tick: with predictive+reactive both
                    # armed, the first policy to ask wins — otherwise two
                    # decisions off the same snapshot double the step rate
                    if scaled_up:
                        continue
                    scaled_up = True
                self._apply(action)
        return snap

    def run(self):
        while not self._stop_evt.is_set():
            t0 = self.clock.now()
            try:
                self.tick()
            except Exception:
                self.apply_errors += 1
            dt = self.clock.now() - t0
            self.clock.wait_event(self._stop_evt,
                                  timeout=max(0.0, self.cfg.interval_s - dt))
        self.clock.detach()    # exit the schedule without a watchdog stall

    def start(self) -> None:
        self.clock.thread_started(self)
        super().start()

    def stop(self, timeout: float = 5.0) -> None:
        self._stop_evt.set()
        if self.is_alive():
            self.clock.join(self, timeout=timeout)

    # ---- reporting -------------------------------------------------------
    def summary(self) -> dict:
        kinds: dict[str, int] = {}
        for _, a in self.actions_log:
            kinds[a.kind] = kinds.get(a.kind, 0) + 1
        out = {"actions": kinds, "apply_errors": self.apply_errors,
               "n_policies": len(self.policies),
               "executor_seconds": (self.engine.executor_seconds()
                                    if self.engine is not None else 0.0)}
        if self.provisioner is not None:
            out["provisioner"] = self.provisioner.summary()
        return out
