"""Workload atlas — the named scenario library and its sweep runner.

The scenario runner (:mod:`repro.sim.scenario`) can replay ONE experiment
from a seed; the atlas turns that into a regression instrument: a curated
library of workload shapes the ElasticBroker pipeline must survive —
diurnal load, flash crowds, correlated endpoint failures, a full network
partition, straggler storms, hot-key drift, and multi-tenant mixes with
conflicting SLOs — swept over seeds × scenarios on virtual time, emitting
one deterministic report artifact.

Every scenario is a zero-config builder ``fn(seed) -> Scenario``; every
run happens under a seeded ``VirtualClock``, so the whole sweep is
byte-reproducible: CI runs the atlas twice and compares the serialized
reports (:func:`report_json`) byte for byte.  Multi-tenant scenarios
additionally gate on the per-tenant loss ledger closing — every admitted
record accounted sent or evicted, per tenant, chaos included.

    from repro.sim.atlas import run_atlas, report_json
    report = run_atlas(seeds=(0, 1, 2))
    print(report_json(report))
"""
from __future__ import annotations

import json

import numpy as np

from repro.runtime.controller import ElasticityConfig
from repro.sim.scenario import (Fault, LoadPhase, Scenario, TenantTraffic,
                                run_scenario)
from repro.streaming.operators import OperatorPipeline
from repro.tenancy import TenantSpec
from repro.workflow.config import WorkflowConfig

# ---------------------------------------------------------------------------
# shared wiring: small fleets, virtual time, fast-but-honest rates — each
# scenario finishes in well under a second of wall time so the full sweep
# stays CI-cheap

_PAYLOAD = 32


def _base(**over) -> WorkflowConfig:
    kw = dict(n_producers=4, n_groups=2, compress="none",
              queue_capacity=64, backpressure="drop_oldest",
              max_batch_records=16, trigger_interval=0.05, min_batch=2,
              n_executors=2, clock="virtual", flush_timeout_s=120.0)
    kw.update(over)
    return WorkflowConfig(**kw)


def _elastic(**over) -> ElasticityConfig:
    kw = dict(enabled=True, interval_s=0.1, target_p99_s=0.5,
              min_executors=1, max_executors=8, cooldown_s=0.5,
              backlog_high=64, idle_scale_down_s=1.5,
              heartbeat_timeout_s=60.0, replace_stragglers=False)
    kw.update(over)
    return ElasticityConfig(**kw)


_TENANTS = (TenantSpec("alerts", priority=2, p99_target_s=0.5, weight=4.0),
            TenantSpec("batch", priority=0, weight=1.0))


# ---------------------------------------------------------------------------
# the scenario library

def diurnal(seed: int) -> Scenario:
    """A day in five phases: the load rises to a peak and falls back to
    quiet.  Exercises scale-out on the ramp and scale-in on the decline —
    no faults, pure elasticity."""
    return Scenario(
        workflow=_base(elasticity=_elastic(target_p99_s=0.3)),
        phases=(LoadPhase("night", 1.0, 5.0),
                LoadPhase("morning", 1.5, 20.0),
                LoadPhase("peak", 1.5, 60.0),
                LoadPhase("evening", 1.5, 20.0),
                LoadPhase("drain", 2.0, 0.0)),
        analysis_cost_s=0.01, payload_elems=_PAYLOAD, seed=seed)


def flash_crowd(seed: int) -> Scenario:
    """Calm, then a 10x step spike, then calm: the classic elasticity
    stress — can the fleet absorb a spike it had no warning of?"""
    return Scenario(
        workflow=_base(elasticity=_elastic(predictive=True,
                                           target_p99_s=0.3)),
        phases=(LoadPhase("calm", 1.5, 8.0),
                LoadPhase("spike", 1.5, 80.0),
                LoadPhase("calm2", 1.5, 8.0),
                LoadPhase("drain", 2.0, 0.0)),
        analysis_cost_s=0.01, payload_elems=_PAYLOAD, seed=seed)


def endpoint_blackout(seed: int) -> Scenario:
    """Correlated endpoint failures: both endpoints of one group die
    within 100ms, recover two virtual seconds later.  Senders reroute to
    the survivors; the failure detector fires on the dead ones."""
    return Scenario(
        workflow=_base(n_groups=2, n_endpoints=3, elasticity=_elastic()),
        phases=(LoadPhase("steady", 4.0, 25.0),
                LoadPhase("drain", 2.0, 0.0)),
        faults=(Fault(t=1.0, kind="fail_endpoint", target=0),
                Fault(t=1.1, kind="fail_endpoint", target=1),
                Fault(t=3.0, kind="recover_endpoint", target=0),
                Fault(t=3.1, kind="recover_endpoint", target=1)),
        analysis_cost_s=0.002, payload_elems=_PAYLOAD, seed=seed)


def partition(seed: int) -> Scenario:
    """Network partition: every endpoint refuses pushes for a window, then
    the partition heals.  The broker rides it out on queues + retries; the
    drop policy sheds what the queues cannot hold."""
    return Scenario(
        workflow=_base(elasticity=_elastic()),
        phases=(LoadPhase("steady", 4.0, 25.0),
                LoadPhase("drain", 2.0, 0.0)),
        faults=(Fault(t=1.5, kind="fail_endpoint", target=0),
                Fault(t=1.5, kind="fail_endpoint", target=1),
                Fault(t=2.5, kind="recover_endpoint", target=0),
                Fault(t=2.5, kind="recover_endpoint", target=1)),
        analysis_cost_s=0.002, payload_elems=_PAYLOAD, seed=seed)


def straggler_storm(seed: int) -> Scenario:
    """Both executors degrade at once (a noisy neighbor hitting the whole
    analysis tier), then clear.  Work-stealing and scale-out carry the
    backlog through the storm."""
    return Scenario(
        workflow=_base(elasticity=_elastic()),
        phases=(LoadPhase("steady", 4.0, 25.0),
                LoadPhase("drain", 2.0, 0.0)),
        faults=(Fault(t=1.0, kind="inject_straggler", target=0, value=0.05),
                Fault(t=1.0, kind="inject_straggler", target=1, value=0.05),
                Fault(t=3.0, kind="clear_straggler", target=0),
                Fault(t=3.0, kind="clear_straggler", target=1)),
        analysis_cost_s=0.002, payload_elems=_PAYLOAD, seed=seed)


def _drift_pipeline():
    """Keyed windowing whose hot key DRIFTS: the heavy key changes every
    20 steps, so keyed state ownership keeps migrating."""

    def key_fn(stream_key: str, rec) -> str:
        rank = int(stream_key.rsplit("/r", 1)[1])
        if rank < 3:                      # 3 of 4 ranks pool on the hot key
            return f"hot{(rec.step // 20) % 3}"
        return f"cold{rec.step % 5}"

    def factory() -> OperatorPipeline:
        return (OperatorPipeline()
                .key_by("drift", key_fn)
                .tumbling_window("win", 0.5, allowed_lateness_s=5.0)
                .aggregate("agg", lambda k, vals: sorted(
                    (r.rank, r.step) for r in vals))
                .sink("out"))

    return factory


def hot_key_drift(seed: int) -> Scenario:
    """80% of records concentrate on one key — and that key drifts every
    20 steps.  Exercises keyed-state migration under the operator plan."""
    return Scenario(
        workflow=_base(elasticity=_elastic()),
        phases=(LoadPhase("steady", 3.0, 30.0),
                LoadPhase("drain", 2.0, 0.0)),
        operators=_drift_pipeline(), payload_elems=_PAYLOAD, seed=seed)


def tenant_squeeze(seed: int) -> Scenario:
    """Two tenants with conflicting SLOs under a capacity squeeze:
    ``alerts`` (priority 2, p99 target, weight 4) shares the pipe with
    ``batch`` (priority 0, best-effort, 3x the traffic) while per-endpoint
    inbound bandwidth caps the drain rate below the offered load.  The QoS
    admission plane must park/evict batch first — never silently — and
    debt-weighted scaling must keep alerts under its target."""
    return Scenario(
        workflow=_base(
            queue_capacity=32, inbound_bw=4_000.0, max_batch_records=2,
            qos_high_water=0.3, tenants=_TENANTS,
            elasticity=_elastic(slo_debt=True, target_p99_s=1e9,
                                backlog_high=10**9, adapt_batch=False)),
        phases=(LoadPhase("calm", 1.0, 10.0),
                LoadPhase("squeeze", 2.0, 40.0),
                LoadPhase("recover", 1.0, 10.0),
                LoadPhase("drain", 4.0, 0.0)),
        tenant_traffic=(TenantTraffic("alerts", ranks=(0,), every=2),
                        TenantTraffic("batch", ranks=(1, 2, 3))),
        analysis_cost_s=0.001, payload_elems=_PAYLOAD, seed=seed)


def tenant_quota(seed: int) -> Scenario:
    """A quota'd tenant offering 3x its contracted rate: the token bucket
    rejects the excess at the front door (counted, not dropped downstream)
    while the unquota'd tenant is untouched."""
    tenants = (TenantSpec("alerts", priority=2, p99_target_s=1.0),
               TenantSpec("batch", priority=0, rate_quota_rps=30.0))
    return Scenario(
        workflow=_base(tenants=tenants, elasticity=_elastic()),
        phases=(LoadPhase("steady", 3.0, 30.0),
                LoadPhase("drain", 2.0, 0.0)),
        tenant_traffic=(TenantTraffic("alerts", ranks=(0,)),
                        TenantTraffic("batch", ranks=(1, 2, 3))),
        analysis_cost_s=0.001, payload_elems=_PAYLOAD, seed=seed)


def tenant_blackout(seed: int) -> Scenario:
    """Multi-tenant mix + endpoint blackout: the QoS plane and the fault
    plane collide.  Whatever is lost, the per-tenant loss ledger still
    closes — loss is attributed, never silent."""
    return Scenario(
        workflow=_base(queue_capacity=32, tenants=_TENANTS,
                       elasticity=_elastic(slo_debt=True)),
        phases=(LoadPhase("steady", 4.0, 30.0),
                LoadPhase("drain", 2.0, 0.0)),
        faults=(Fault(t=1.0, kind="fail_endpoint", target=0),
                Fault(t=2.5, kind="recover_endpoint", target=0)),
        tenant_traffic=(TenantTraffic("alerts", ranks=(0, 1)),
                        TenantTraffic("batch", ranks=(2, 3))),
        analysis_cost_s=0.001, payload_elems=_PAYLOAD, seed=seed)


SCENARIOS = {
    "diurnal": diurnal,
    "flash_crowd": flash_crowd,
    "endpoint_blackout": endpoint_blackout,
    "partition": partition,
    "straggler_storm": straggler_storm,
    "hot_key_drift": hot_key_drift,
    "tenant_squeeze": tenant_squeeze,
    "tenant_quota": tenant_quota,
    "tenant_blackout": tenant_blackout,
}


# ---------------------------------------------------------------------------
# the sweep runner

def build(name: str, seed: int) -> Scenario:
    try:
        builder = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown atlas scenario {name!r}; library has "
                       f"{sorted(SCENARIOS)}") from None
    return builder(seed)


def _run_row(name: str, seed: int) -> dict:
    trace = run_scenario(build(name, seed))
    s = trace.summary
    row = {
        "scenario": name,
        "seed": seed,
        "digest": trace.digest(),
        "written": s["written"],
        "sent": s["sent"],
        "dropped_by_policy": s["dropped_by_policy"],
        "analyzed": s["analyzed"],
        "latency_p99": s["latency_p99"],
        "executors_peak": s["executors_peak"],
        "virtual_duration_s": s["virtual_duration_s"],
        "controller_actions": s.get("controller_actions", {}),
    }
    if "tenants" in s:
        row["tenants"] = s["tenants"]
        row["tenant_ledger"] = s["tenant_ledger"]
    return row


def run_atlas(names=None, seeds=(0, 1, 2)) -> dict:
    """Sweep ``names`` (default: the full library) × ``seeds``; returns the
    atlas report — per-run rows plus the sweep-level gates.  Deterministic:
    same arguments, byte-identical :func:`report_json` output."""
    names = sorted(SCENARIOS) if names is None else list(names)
    runs = [_run_row(name, seed) for name in names for seed in seeds]
    ledger_failures = [
        f"{r['scenario']}/seed{r['seed']}: {e}"
        for r in runs if "tenant_ledger" in r
        for e in r["tenant_ledger"]["errors"]]
    silent = [f"{r['scenario']}/seed{r['seed']}" for r in runs
              if r["analyzed"] == 0]
    return {
        "atlas": {"scenarios": names, "seeds": list(seeds),
                  "n_runs": len(runs)},
        "runs": runs,
        "gates": {
            "ledgers_closed": not ledger_failures,
            "ledger_failures": ledger_failures,
            "all_runs_analyzed": not silent,
            "silent_runs": silent,
        },
    }


def report_json(report: dict) -> str:
    """Canonical serialization of an atlas report: sorted keys, one
    newline-terminated document — the byte-compare artifact CI gates on.
    NaN percentiles (a tenant with zero analyzed records) canonicalize to
    null so the artifact stays strict JSON."""
    return json.dumps(_sanitize(report), sort_keys=True, indent=1,
                      allow_nan=False) + "\n"


def _sanitize(v):
    if isinstance(v, dict):
        return {k: _sanitize(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_sanitize(x) for x in v]
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, (float, np.floating)):
        f = float(v)
        return f if f == f else None
    return v
