"""Deterministic scenario runner — chaos and load studies on virtual time.

SIM-SITU-style faithful simulation of the in-situ pipeline: a
:class:`Scenario` composes a synthetic load profile (:class:`LoadPhase`
rates and spike schedules, per-record analysis cost) with a seeded fault
plan (:class:`Fault`: kill/revive executors and endpoints, inject
stragglers, silently drop transport frames at time T) and drives a real
:class:`repro.workflow.Session` — broker, endpoints, engine, telemetry,
controller, all of it — under a :class:`repro.runtime.clock.VirtualClock`.

Because the virtual clock serializes participants and advances only on
quiescence, a run is **deterministic**: same seed ⇒ byte-identical
:class:`ScenarioTrace` (verify with :meth:`ScenarioTrace.digest`), and a
"20 second" load-spike study finishes in well under a second of wall time.
That makes the PR-3 elasticity loop, the straggler scan, and the
steal/ordering machinery assertable in milliseconds and replayable from a
seed — see ``tests/test_scenario_chaos.py`` and
``benchmarks/elasticity.py`` (virtual mode).

The trace records every load step, fault injection, analysis call (with the
exact step sequence per stream — the ordering oracle), controller action,
and engine result, each stamped with virtual time, plus a summary of the
delivery/loss accounting across all layers.
"""
from __future__ import annotations

import hashlib
import json
import struct
import tempfile
import threading
from dataclasses import dataclass, field

import numpy as np

from repro.checkpoint.session_store import SessionCheckpointStore
from repro.cloud.nodes import READY
from repro.runtime.clock import VirtualClock
from repro.runtime.wal import WalStore
from repro.streaming.engine import percentile_sorted
from repro.streaming.operators import WindowPane
from repro.tenancy import TENANT_COUNTERS, closure_errors
from repro.workflow.config import WorkflowConfig
from repro.workflow.session import Session


@dataclass(frozen=True)
class LoadPhase:
    """One segment of the load profile.  ``rate_hz`` is producer steps/s;
    each step writes one record per producer rank, so records/s =
    ``rate_hz * n_producers``.  ``rate_hz=0`` is an idle (drain) window."""

    name: str
    duration_s: float
    rate_hz: float


@dataclass(frozen=True)
class TenantTraffic:
    """One tenant's slice of the producer load: which ranks write under
    this QoS identity, thinned to every ``every``-th load step.  A scenario
    with ``tenant_traffic`` opens one FieldHandle per tenant, so records
    carry tenant identity end to end (broker admission → telemetry rollups
    → per-tenant trace events)."""

    tenant: str
    ranks: tuple = (0,)
    every: int = 1                     # write on steps where step % every == 0


@dataclass(frozen=True)
class Fault:
    """One scheduled fault, applied when virtual time reaches ``t``.

    kinds:
      ``kill_executor``      hard-kill executor ``target`` (queue reassigned)
      ``add_executor``       bring up a fresh executor
      ``inject_straggler``   slow executor ``target`` by ``value`` s/batch
      ``clear_straggler``    remove the slowdown from executor ``target``
      ``fail_endpoint``      endpoint ``target`` refuses pushes (retry path)
      ``recover_endpoint``   endpoint ``target`` accepts again
      ``drop_frames``        endpoint ``target`` silently discards the next
                             ``value`` accepted frames (acked, then lost —
                             invisible to the broker's retry logic)
      ``kill_broker``        crash the broker in place; a fresh one adopts
                             the same WAL and replays the unacked tail
                             (requires delivery="exactly-once")
      ``kill_session``       whole-session crash — broker, engine, endpoints
                             all die mid-flight — then ``Session.restore``
                             from the latest checkpoint + WAL tail replay
                             (requires delivery="exactly-once" and an
                             ``operators`` factory)
      ``provision_fail``     the next ``value`` CloudProvisioner power_on
                             attempts fail (retry/backoff/recover path;
                             requires elasticity.provision)
      ``boot_stall``         stretch cold starts by ``value`` s: nodes
                             currently booting are delayed, else the next
                             boot is (requires elasticity.provision)
      ``kill_node``          hard-fail the ``target``-th READY cloud node:
                             its endpoint and executors die atomically, its
                             cost-ledger record closes at death, and the
                             node lands in FAILED for provisioner.recover()
                             (requires elasticity.provision)
    """

    t: float
    kind: str
    target: int = 0
    value: float = 0.0


_FAULT_KINDS = ("kill_executor", "add_executor", "inject_straggler",
                "clear_straggler", "fail_endpoint", "recover_endpoint",
                "drop_frames", "kill_broker", "kill_session",
                "provision_fail", "boot_stall", "kill_node")
_KILL_KINDS = ("kill_broker", "kill_session")
_PROVISION_KINDS = ("provision_fail", "boot_stall", "kill_node")


@dataclass(frozen=True)
class Scenario:
    """A reproducible experiment: workflow wiring + load profile + fault
    plan + one seed controlling every source of scheduling randomness.

    ``operators``: optional factory returning a fresh
    :class:`repro.streaming.operators.OperatorPipeline` — the run then
    attaches it instead of the per-record analyze callback, and every
    operator-level event (window fires, late drops, sink emits) lands in
    the trace as an ``op`` event, with the plan's window loss ledger in
    ``summary["windows"]``.  A factory (not a prebuilt pipeline) so each
    run starts from empty keyed state.  Mutually exclusive with
    ``analysis_cost_s``/``record_latency`` (callback-path knobs — model
    cost inside the operator fns instead).

    ``record_latency``: emit one ``latency`` trace event PER RECORD at
    analysis time (callback scenarios) — the raw material for
    controller-policy regression curves, sweepable for free on virtual
    time."""

    workflow: WorkflowConfig
    phases: tuple = ()
    faults: tuple = ()
    seed: int = 0
    analysis_cost_s: float = 0.0       # simulated work per record
    payload_elems: int = 64
    field_name: str = "load"
    flush_timeout_s: float = 120.0     # virtual seconds, costs nothing real
    operators: object = None           # () -> OperatorPipeline factory
    record_latency: bool = False
    # take a Session.checkpoint() roughly every N virtual seconds of load
    # (0 = never).  Exactly-once only.  ``checkpoint_dir`` pins the store
    # on disk (CI artifact inspection); the default is a fresh temp dir per
    # run, which re-running the same Scenario requires — a reused dir would
    # make run #2 restore run #1's checkpoints.
    checkpoint_every_s: float = 0.0
    checkpoint_dir: str | None = None
    # multi-tenant load: per-tenant rank slices (requires workflow.tenants);
    # () keeps the single-handle load loop
    tenant_traffic: tuple = ()

    def validate(self) -> "Scenario":
        self.workflow.validate()
        for ph in self.phases:
            if ph.duration_s <= 0 or ph.rate_hz < 0:
                raise ValueError(f"bad phase {ph}")
        for f in self.faults:
            if f.kind not in _FAULT_KINDS:
                raise ValueError(f"unknown fault kind {f.kind!r} "
                                 f"(expected one of {_FAULT_KINDS})")
            if f.t < 0:
                raise ValueError(f"fault time must be >= 0, got {f.t}")
        if self.checkpoint_every_s < 0:
            raise ValueError("checkpoint_every_s must be >= 0")
        kinds = {f.kind for f in self.faults}
        if (kinds & set(_KILL_KINDS) or self.checkpoint_every_s) \
                and self.workflow.delivery != "exactly-once":
            raise ValueError(
                "kill_broker/kill_session faults and checkpoint_every_s "
                "require workflow.delivery='exactly-once' (there is nothing "
                "to replay from in at-most-once mode)")
        if kinds & set(_PROVISION_KINDS) \
                and not (self.workflow.elasticity.enabled
                         and self.workflow.elasticity.provision):
            raise ValueError(
                "provision_fail/boot_stall/kill_node faults require "
                "workflow.elasticity.enabled and .provision (there is no "
                "CloudProvisioner to fault otherwise)")
        if ("kill_session" in kinds or self.checkpoint_every_s) \
                and self.operators is None:
            raise ValueError(
                "kill_session and checkpoint_every_s require an operators "
                "factory: Session.restore/checkpoint rebuild plan state "
                "(window panes, sinks), which the callback path has none of")
        if self.tenant_traffic:
            reg = self.workflow.tenant_registry()
            if reg is None:
                raise ValueError("tenant_traffic requires workflow.tenants "
                                 "(records need a registry to be admitted "
                                 "under)")
            for tr in self.tenant_traffic:
                if tr.tenant not in reg:
                    raise ValueError(f"tenant_traffic names undeclared "
                                     f"tenant {tr.tenant!r}")
                if tr.every < 1:
                    raise ValueError("TenantTraffic.every must be >= 1")
                if not tr.ranks or any(
                        not (0 <= r < self.workflow.n_producers)
                        for r in tr.ranks):
                    raise ValueError(
                        f"TenantTraffic.ranks must be non-empty and within "
                        f"[0, n_producers={self.workflow.n_producers})")
        if self.operators is not None:
            if not callable(self.operators):
                raise ValueError("operators must be a zero-arg factory "
                                 "returning an OperatorPipeline (fresh state "
                                 "per run)")
            # these two live in the callback analyze path only; silently
            # ignoring them would skew any operator-vs-callback comparison
            if self.analysis_cost_s:
                raise ValueError(
                    "analysis_cost_s only applies to callback scenarios; "
                    "model cost inside the operator fns (clock.sleep)")
            if self.record_latency:
                raise ValueError(
                    "record_latency only applies to callback scenarios; "
                    "operator runs trace per-event 'op' records instead")
        return self


@dataclass
class ScenarioTrace:
    """The deterministic record of one run: events sorted by
    ``(t, kind, payload)`` so two same-seed runs serialize byte-for-byte."""

    seed: int
    events: list = field(default_factory=list)   # (t, kind, detail dict)
    summary: dict = field(default_factory=dict)
    phase_windows: list = field(default_factory=list)  # (name, t0, t1)

    def events_of(self, kind: str) -> list:
        return [(t, d) for t, k, d in self.events if k == kind]

    def per_stream_steps(self) -> dict[str, list[int]]:
        """Steps in ANALYSIS order per stream — the ordering oracle: any
        deviation from sorted order means a steal/reassign broke the
        per-stream sequence guarantee."""
        out: dict[str, list[int]] = {}
        for _, d in self.events_of("analyze"):
            out.setdefault(d["stream"], []).extend(d["steps"])
        return out

    def latency_curve(self) -> list[tuple[float, float]]:
        """Per-record ``(t_analyzed, latency)`` pairs in time order — the
        regression curve controller-policy sweeps compare (requires the
        scenario to have run with ``record_latency=True``)."""
        return sorted((t, d["latency"]) for t, d in self.events_of("latency"))

    def phase_p99(self, name: str, tenant: str | None = None) -> float:
        """p99 generation→analysis latency over results whose records were
        *generated* inside the named phase's window (paper §4.3 framing).

        With ``tenant``, only that tenant's slice of each result counts —
        its own oldest-record timestamp decides phase membership and its
        own latency feeds the percentile (multi-tenant scenarios emit a
        ``tenants`` map per result event)."""
        if tenant is None:
            lats = sorted(d["latency"] for _, d in self.events_of("result")
                          for (pn, a, b) in self.phase_windows
                          if pn == name and a <= d["t_generated"] < b)
        else:
            lats = sorted(d["tenants"][tenant][2]
                          for _, d in self.events_of("result")
                          if tenant in d.get("tenants", {})
                          for (pn, a, b) in self.phase_windows
                          if pn == name
                          and a <= d["tenants"][tenant][1] < b)
        return percentile_sorted(lats, 0.99)

    def to_jsonl(self) -> str:
        """Canonical serialization: one sorted-key JSON object per line.
        Byte-identical across same-seed runs (the CI determinism gate
        compares exactly this)."""
        lines = [json.dumps({"seed": self.seed, "summary": self.summary,
                             "phases": self.phase_windows}, sort_keys=True)]
        lines += [json.dumps({"t": t, "kind": k, **d}, sort_keys=True)
                  for t, k, d in self.events]
        return "\n".join(lines) + "\n"

    def digest(self) -> str:
        return hashlib.sha256(self.to_jsonl().encode()).hexdigest()


def _canon(v) -> bytes:
    """Canonical bytes of one sink value — type-tagged so e.g. 1 and 1.0
    and "1" cannot collide — for :func:`sink_digest`."""
    if isinstance(v, np.ndarray):
        return b"nd:" + str(v.dtype).encode() + str(v.shape).encode() \
            + v.tobytes()
    if isinstance(v, np.generic):
        v = v.item()
    if isinstance(v, bool):
        return b"b1" if v else b"b0"
    if isinstance(v, float):
        return b"f:" + struct.pack("!d", v)
    if isinstance(v, int):
        return b"i:" + str(v).encode()
    if isinstance(v, str):
        return b"s:" + v.encode()
    if isinstance(v, bytes):
        return b"y:" + v
    if isinstance(v, WindowPane):
        return b"w:" + struct.pack("!dd", v.start, v.end) \
            + _canon(v.key) + _canon(list(v.values))
    if isinstance(v, (tuple, list)):
        return b"l:" + b",".join(_canon(x) for x in v)
    if isinstance(v, dict):
        return b"d:" + b",".join(
            _canon(k) + b"=" + _canon(v[k]) for k in sorted(v, key=repr))
    return b"r:" + repr(v).encode()


def sink_digest(plan) -> str:
    """sha256 over every sink's per-key ordered value sequences, timestamps
    excluded — the "did the cloud see exactly the same analysis results"
    oracle.  A chaos run whose digest equals the fault-free same-seed run's
    delivered byte-identical results despite every injected death."""
    h = hashlib.sha256()
    for name in sorted(plan.sinks()):
        h.update(b"S:" + name.encode())
        per_key: dict[str, list] = {}
        for key, value, _t in plan.results(name):
            per_key.setdefault(key, []).append(value)
        for key in sorted(per_key):
            h.update(b"K:" + key.encode())
            for value in per_key[key]:
                h.update(_canon(value))
    return h.hexdigest()


class ScenarioRunner:
    """Drives one :class:`Scenario` to completion under a seeded
    ``VirtualClock`` and returns its :class:`ScenarioTrace`."""

    def __init__(self, scenario: Scenario):
        self.scenario = scenario.validate()

    # ---- fault application ----------------------------------------------
    @staticmethod
    def _apply_fault(sess: Session, f: Fault) -> None:
        eng = sess.engine
        if f.kind == "kill_executor":
            eng.kill_executor(f.target % len(eng.executors))
        elif f.kind == "add_executor":
            eng.add_executor()
        elif f.kind == "inject_straggler":
            eng.executors[f.target % len(eng.executors)].slowdown = float(f.value)
        elif f.kind == "clear_straggler":
            eng.executors[f.target % len(eng.executors)].slowdown = 0.0
        elif f.kind == "fail_endpoint":
            sess.endpoints[f.target % len(sess.endpoints)].handle.fail()
        elif f.kind == "recover_endpoint":
            sess.endpoints[f.target % len(sess.endpoints)].handle.recover()
        elif f.kind == "drop_frames":
            sess.endpoints[f.target % len(sess.endpoints)].handle \
                .drop_next_frames(int(f.value))
        elif f.kind == "provision_fail":
            sess.provisioner.inject_provision_failures(int(f.value))
        elif f.kind == "boot_stall":
            sess.provisioner.inject_boot_stall(float(f.value))
        elif f.kind == "kill_node":
            ready = sess.provisioner.nodes_in_state(READY)
            if not ready:
                raise LookupError("no READY cloud node to kill")
            sess.provisioner.fail_node(ready[f.target % len(ready)])

    # ---- the run ---------------------------------------------------------
    def run(self) -> ScenarioTrace:
        sc = self.scenario
        clock = VirtualClock(seed=sc.seed)
        clock.attach()                 # this thread drives the schedule
        trace = ScenarioTrace(seed=sc.seed)
        elock = threading.Lock()

        def emit(kind: str, **detail) -> None:
            with elock:
                trace.events.append((round(clock.now(), 9), kind, detail))

        def analyze(key, records):
            # simulated per-record cost on VIRTUAL time, plus the ordering
            # oracle: the exact step sequence each stream is analyzed in
            if sc.analysis_cost_s:
                clock.sleep(sc.analysis_cost_s * len(records))
            if sc.record_latency:
                now = clock.now()
                for r in records:
                    emit("latency", stream=key, step=r.step,
                         latency=round(now - r.t_generated, 9))
            emit("analyze", stream=key, steps=[r.step for r in records])
            return len(records)

        # ---- durable artifacts shared across session incarnations -------
        kinds = {f.kind for f in sc.faults}
        durable = sc.checkpoint_every_s > 0 or "kill_session" in kinds
        wal = ckpt_store = None
        if sc.workflow.delivery == "exactly-once":
            # retain="commit" keeps even acked entries until a checkpoint
            # commits them, so a whole-session crash can replay the tail
            wal = WalStore(capacity_bytes=sc.workflow.wal_capacity_bytes,
                           queue_capacity=sc.workflow.queue_capacity,
                           retain="commit" if durable else "ack")
            if durable:
                ckpt_store = SessionCheckpointStore(
                    sc.checkpoint_dir
                    or tempfile.mkdtemp(prefix="repro_scenario_ckpt_"))

        def op_emit(kind, **d):
            # operator-level trace events: window fires / late drops / sinks
            emit("op", event=kind, **d)

        if sc.operators is not None:
            sess = Session(sc.workflow, pipeline=sc.operators(), clock=clock,
                           wal=wal, checkpoints=ckpt_store)
            sess.exec_plan.on_event = op_emit
        else:
            sess = Session(sc.workflow, analyze=analyze, clock=clock,
                           wal=wal, checkpoints=ckpt_store)

        # every live reference routes through the box: kill_session swaps
        # the session (and its field handle) under the load loop's feet
        box = {"sess": sess, "handle": None, "actions": [],
               "recovery_counts": {}, "restores": 0, "prov_events": []}

        def absorb_dead(old: Session) -> None:
            # controller actions and recovery events die with a killed
            # incarnation — fold them out before the crash
            if old.controller is not None:
                box["actions"].extend(old.controller.actions_log)
            if old.recovery is not None:
                for k, v in old.recovery.summary().items():
                    box["recovery_counts"][k] = \
                        box["recovery_counts"].get(k, 0) + v
            if old.provisioner is not None:
                box["prov_events"].extend(old.provisioner.events)

        def open_handles(s: Session) -> None:
            box["handle"] = s.open_field(sc.field_name,
                                         shape=(sc.payload_elems,))
            box["handles"] = {
                tr.tenant: s.open_field(sc.field_name,
                                        shape=(sc.payload_elems,),
                                        tenant=tr.tenant)
                for tr in sc.tenant_traffic}

        def restore_session() -> None:
            old = box["sess"]
            absorb_dead(old)
            old.kill()
            new = Session.restore(sc.workflow, checkpoints=ckpt_store,
                                  wal=wal, pipeline=sc.operators(),
                                  clock=clock)
            new.exec_plan.on_event = op_emit
            box["sess"] = new
            open_handles(new)
            box["restores"] += 1

        try:
            open_handles(sess)
            n_ranks = sc.workflow.n_producers
            rng = np.random.RandomState(sc.seed)
            payloads = [rng.randn(sc.payload_elems).astype(np.float32)
                        for _ in range(n_ranks)]

            # fault plan runs on its own participant thread so injections
            # land at their exact virtual instants, independent of the
            # load loop's cadence
            faults = sorted(sc.faults, key=lambda f: (f.t, f.kind, f.target))

            def inject():
                for f in faults:
                    # sleep_until: the exact float deadline, so a fault at
                    # f.t ties (and tie-breaks deterministically) with any
                    # other waiter targeting the same instant
                    clock.sleep_until(f.t)
                    try:
                        if f.kind == "kill_broker":
                            box["sess"].restart_broker()
                        elif f.kind == "kill_session":
                            restore_session()
                        else:
                            self._apply_fault(box["sess"], f)
                        emit("fault", fault=f.kind, target=f.target,
                             value=f.value, ok=True)
                    except Exception as e:   # a mistargeted fault is a trace
                        emit("fault", fault=f.kind, target=f.target,
                             value=f.value, ok=False,
                             error=type(e).__name__)
                clock.detach()   # leave the schedule with no watchdog stall

            injector = threading.Thread(target=inject, daemon=True,
                                        name="fault-injector")
            clock.thread_started(injector)
            injector.start()

            next_ckpt = sc.checkpoint_every_s or None

            def maybe_checkpoint() -> None:
                nonlocal next_ckpt
                if next_ckpt is None or clock.now() < next_ckpt:
                    return
                try:
                    cid = box["sess"].checkpoint(timeout=sc.flush_timeout_s)
                    emit("checkpoint", ok=True, ckpt_id=cid)
                except Exception as e:
                    # a kill landing mid-quiesce aborts THIS checkpoint;
                    # the run continues from the previous committed one
                    emit("checkpoint", ok=False, error=type(e).__name__)
                next_ckpt = clock.now() + sc.checkpoint_every_s

            step = 0
            sched = 0.0   # nominal producer time: event timestamps follow
            #               the simulation schedule, not the (crash-delayed)
            #               virtual instant a write lands, so window
            #               membership is identical across recovery replays
            for ph in sc.phases:
                t0 = round(clock.now(), 9)
                emit("phase", name=ph.name, rate_hz=ph.rate_hz,
                     duration_s=ph.duration_s)
                n_steps = int(round(ph.duration_s * ph.rate_hz))
                if n_steps == 0:
                    sched += ph.duration_s
                    clock.sleep(ph.duration_s)
                else:
                    period = ph.duration_s / n_steps
                    for _ in range(n_steps):
                        if sc.tenant_traffic:
                            accepted = 0
                            for tr in sc.tenant_traffic:
                                if step % tr.every:
                                    continue
                                accepted += box["handles"][tr.tenant] \
                                    .write_batch(
                                        step,
                                        [payloads[r] for r in tr.ranks],
                                        ranks=list(tr.ranks),
                                        t=round(sched, 9))
                        else:
                            accepted = box["handle"].write_batch(
                                step, payloads, ranks=list(range(n_ranks)),
                                t=round(sched, 9))
                        emit("write", step=step, accepted=accepted)
                        step += 1
                        sched += period
                        clock.sleep(period)
                        maybe_checkpoint()
                trace.phase_windows.append((ph.name, t0,
                                            round(clock.now(), 9)))

            clock.join(injector)       # let trailing faults land
            box["sess"].flush(timeout=sc.flush_timeout_s)
        finally:
            box["sess"].close()
        sess = box["sess"]

        # post-run, single-threaded: merge the controller's action log (all
        # incarnations — killed sessions' logs were absorbed into the box)
        # and the engine's results into the trace at their virtual timestamps
        actions = list(box["actions"])
        if sess.controller is not None:
            actions.extend(sess.controller.actions_log)
        for t, a in actions:
            trace.events.append((round(t, 9), "action",
                                 {"kind": a.kind, "value": a.value,
                                  "group": a.group, "reason": a.reason}))
        prov_events = list(box["prov_events"])
        if sess.provisioner is not None:
            prov_events.extend(sess.provisioner.events)
        for t, d in prov_events:
            trace.events.append((round(t, 9), "provision", dict(d)))
        tenancy = bool(sc.workflow.tenants)
        for r in sess.results():
            detail = {"stream": r.stream_key,
                      "executor": r.executor,
                      "n_records": r.n_records,
                      "t_generated": round(r.t_generated_min, 9),
                      "latency": round(r.latency, 9)}
            rt = getattr(r, "tenants", None)
            if tenancy and rt:
                # per-tenant slice of the micro-batch: count, oldest
                # generation instant, and that slice's own latency
                detail["tenants"] = {
                    name: [n, round(tg, 9), round(r.t_analyzed - tg, 9)]
                    for name, (n, tg) in sorted(rt.items())}
            trace.events.append((round(r.t_analyzed, 9), "result", detail))
        trace.events.sort(key=lambda e: (e[0], e[1],
                                         json.dumps(e[2], sort_keys=True)))

        st = sess.stats
        eps = [e.handle.telemetry() for e in sess.endpoints]
        peak = max((s.alive_executors for s in sess.telemetry.history),
                   default=0) if sess.telemetry is not None else 0
        m = sess.engine.metrics() if sess.engine is not None else {}
        trace.summary = {
            "written": st.written, "sent": st.sent,
            "dropped_by_policy": st.dropped,
            "send_errors": st.send_errors, "rerouted": st.rerouted,
            "frames_sent": st.frames_sent,
            "endpoint_records_in": sum(e["records_in"] for e in eps),
            "frames_dropped_injected": sum(e["frames_dropped"] for e in eps),
            "records_dropped_injected": sum(e["records_dropped"] for e in eps),
            "analyzed": sum(d["n_records"]
                            for _, d in trace.events_of("result")),
            "executor_seconds": round(
                sess.engine.executor_seconds(), 9) if sess.engine else 0.0,
            "executors_peak": peak,
            "order_timeouts": m.get("order_timeouts", 0),
            "latency_p99": round(percentile_sorted(
                sorted(d["latency"]
                       for _, d in trace.events_of("result")), 0.99), 9),
            "virtual_duration_s": round(clock.now(), 9),
            "clock_wakeups": clock.wakeups,
        }
        if tenancy:
            # per-tenant QoS rollup + the loss-ledger closure verdict: after
            # an ordered close the broker backlog is empty, so every
            # admitted record must be accounted sent or evicted — per
            # tenant, in every scenario, chaos included
            by_tenant_lat: dict[str, list] = {}
            analyzed_by: dict[str, int] = {}
            for _, d in trace.events_of("result"):
                for name, (n, _tg, lat) in d.get("tenants", {}).items():
                    analyzed_by[name] = analyzed_by.get(name, 0) + n
                    by_tenant_lat.setdefault(name, []).append(lat)
            errs = closure_errors(st.tenants)
            rows = {}
            for name in sorted(set(st.tenants) | set(analyzed_by)):
                c = st.tenants.get(name, {})
                rows[name] = {k: c.get(k, 0) for k in TENANT_COUNTERS}
                rows[name]["analyzed"] = analyzed_by.get(name, 0)
                rows[name]["latency_p99"] = round(percentile_sorted(
                    sorted(by_tenant_lat.get(name, [])), 0.99), 9)
            trace.summary["tenants"] = rows
            trace.summary["tenant_ledger"] = {"closed": not errs,
                                              "errors": errs}
            if sess.provisioner is not None:
                trace.summary["cost_by_tenant"] = \
                    sess.provisioner.ledger.attribute(
                        {n: float(analyzed_by.get(n, 0)) for n in rows})
        if sess.controller is not None or actions:
            act_counts: dict[str, int] = {}
            for _, a in actions:
                act_counts[a.kind] = act_counts.get(a.kind, 0) + 1
            trace.summary["controller_actions"] = act_counts
        if sess.provisioner is not None:
            trace.summary["provisioning"] = sess.provisioner.summary()
        if sess.exec_plan is not None:
            trace.summary["windows"] = sess.exec_plan.accounting()
            # content oracle: per-sink, per-key ordered values (no times)
            trace.summary["sink_digest"] = sink_digest(sess.exec_plan)
        if sc.workflow.delivery == "exactly-once":
            rec = dict(box["recovery_counts"])
            if sess.recovery is not None:
                for k, v in sess.recovery.summary().items():
                    rec[k] = rec.get(k, 0) + v
            trace.summary["recovery"] = {
                "frames_abandoned": st.frames_abandoned,
                "frames_replayed": st.frames_replayed,
                "records_replayed": st.records_replayed,
                "frames_deduped": sum(e["frames_deduped"] for e in eps),
                "records_deduped": sum(e["records_deduped"] for e in eps),
                "checkpoints": sum(1 for _, d in
                                   trace.events_of("checkpoint") if d["ok"]),
                "session_restores": box["restores"],
                "events": rec,
            }
        return trace


def run_scenario(scenario: Scenario) -> ScenarioTrace:
    return ScenarioRunner(scenario).run()
