"""JAX incompressible-flow solver — the OpenFOAM/simpleFoam stand-in.

2-D wind-around-buildings on a staggered-ish collocated grid: Chorin
projection method (advect -> diffuse -> project), obstacle mask for the
"buildings", inflow on the left, free-slip top/bottom, outflow right.
Jacobi-iteration pressure solve (fixed iterations => fully jittable).

The domain is decomposed into ``n_regions`` horizontal slabs along the
Z/height axis, exactly like the paper ("divide the simulation problem domain
into different processes along the Z (height) axis") — each slab's velocity
field is one producer stream for the broker.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


@dataclass(frozen=True)
class CFDConfig:
    nx: int = 128                 # streamwise
    nz: int = 64                  # height
    dt: float = 0.05
    viscosity: float = 0.02
    inflow: float = 1.0
    pressure_iters: int = 40
    n_regions: int = 8            # slabs along z


def buildings_mask(cfg: CFDConfig) -> np.ndarray:
    """A few rectangular 'buildings' on the ground (z=0 bottom)."""
    m = np.zeros((cfg.nz, cfg.nx), bool)
    rng = np.random.RandomState(7)
    xs = np.linspace(cfg.nx * 0.2, cfg.nx * 0.8, 5).astype(int)
    for i, x0 in enumerate(xs):
        w = 4 + int(rng.randint(0, 4))
        h = int(cfg.nz * (0.2 + 0.4 * rng.rand()))
        m[:h, x0:x0 + w] = True
    return m


def init_state(cfg: CFDConfig):
    u = jnp.full((cfg.nz, cfg.nx), cfg.inflow, F32)   # streamwise vel
    w = jnp.zeros((cfg.nz, cfg.nx), F32)              # vertical vel
    p = jnp.zeros((cfg.nz, cfg.nx), F32)
    mask = jnp.asarray(~buildings_mask(cfg), F32)     # 1=fluid, 0=solid
    u = u * mask
    return {"u": u, "w": w, "p": p, "mask": mask}


def _advect(f, u, w, dt):
    """Semi-Lagrangian advection."""
    nz, nx = f.shape
    zz, xx = jnp.meshgrid(jnp.arange(nz, dtype=F32),
                          jnp.arange(nx, dtype=F32), indexing="ij")
    xb = jnp.clip(xx - dt * u, 0.0, nx - 1.0)
    zb = jnp.clip(zz - dt * w, 0.0, nz - 1.0)
    x0 = jnp.floor(xb).astype(jnp.int32)
    z0 = jnp.floor(zb).astype(jnp.int32)
    x1 = jnp.minimum(x0 + 1, nx - 1)
    z1 = jnp.minimum(z0 + 1, nz - 1)
    fx = xb - x0
    fz = zb - z0
    f00 = f[z0, x0]; f01 = f[z0, x1]; f10 = f[z1, x0]; f11 = f[z1, x1]
    return ((1 - fz) * ((1 - fx) * f00 + fx * f01)
            + fz * ((1 - fx) * f10 + fx * f11))


def _lap(f):
    return (jnp.roll(f, 1, 0) + jnp.roll(f, -1, 0)
            + jnp.roll(f, 1, 1) + jnp.roll(f, -1, 1) - 4 * f)


def _div(u, w):
    return ((jnp.roll(u, -1, 1) - jnp.roll(u, 1, 1))
            + (jnp.roll(w, -1, 0) - jnp.roll(w, 1, 0))) * 0.5


@partial(jax.jit, static_argnames=("cfg",))
def step(state: dict, cfg: CFDConfig) -> dict:
    u, w, p, mask = state["u"], state["w"], state["p"], state["mask"]
    dt, nu = cfg.dt, cfg.viscosity

    # advect + diffuse
    u = _advect(u, u, w, dt) + nu * dt * _lap(u)
    w = _advect(w, u, w, dt) + nu * dt * _lap(w)

    # boundary conditions
    u = u.at[:, 0].set(cfg.inflow)            # inflow
    w = w.at[:, 0].set(0.0)
    u = u.at[:, -1].set(u[:, -2])             # outflow
    w = w.at[:, -1].set(w[:, -2])
    u = u.at[0, :].set(0.0)                   # ground no-slip
    w = w.at[0, :].set(0.0)
    w = w.at[-1, :].set(0.0)                  # top free-slip
    u = u * mask
    w = w * mask

    # pressure projection (Jacobi)
    div = _div(u, w)

    def jacobi(p, _):
        p = (jnp.roll(p, 1, 0) + jnp.roll(p, -1, 0)
             + jnp.roll(p, 1, 1) + jnp.roll(p, -1, 1) - div) * 0.25
        p = p * mask
        return p, None

    p, _ = jax.lax.scan(jacobi, jnp.zeros_like(p), None,
                        length=cfg.pressure_iters)
    u = u - 0.5 * (jnp.roll(p, -1, 1) - jnp.roll(p, 1, 1))
    w = w - 0.5 * (jnp.roll(p, -1, 0) - jnp.roll(p, 1, 0))
    u = u * mask
    w = w * mask
    return {"u": u, "w": w, "p": p, "mask": mask}


def region_fields(state: dict, cfg: CFDConfig) -> list[np.ndarray]:
    """Per-slab velocity snapshots — one per producer 'rank' (paper §4.1:
    'The velocity fields of each process region are sent out through the
    broker')."""
    u = np.asarray(state["u"])
    w = np.asarray(state["w"])
    slabs = []
    per = cfg.nz // cfg.n_regions
    for r in range(cfg.n_regions):
        sl = slice(r * per, (r + 1) * per)
        slabs.append(np.stack([u[sl], w[sl]]).reshape(-1))
    return slabs


def divergence_norm(state: dict) -> float:
    """Projection quality: ||div(u)|| over fluid cells (property tests)."""
    d = np.asarray(_div(state["u"], state["w"]) * state["mask"])
    return float(np.sqrt((d ** 2).mean()))
