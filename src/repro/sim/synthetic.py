"""Synthetic data generator — the paper's §4.3 throughput/latency workload.

Groups of producer threads stand in for MPI ranks; each produces field
snapshots at a fixed rate and pushes them through the broker, exactly like the
paper's "synthetic data generator processes in HPC" stressing the
16:1:16 producer:endpoint:executor pipeline.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

from repro.core.broker import Broker
from repro.runtime.clock import Clock, ensure_clock
from repro.workflow.session import FieldHandle, Session


@dataclass
class GeneratorConfig:
    n_producers: int = 16
    field_elems: int = 2048            # floats per record
    rate_hz: float = 10.0              # records/s per producer
    n_steps: int = 50
    coupled_modes: int = 3             # latent oscillators -> DMD-findable


class SyntheticGenerator:
    """Runs n_producers threads; payloads follow a low-rank linear dynamical
    system (so downstream DMD finds real eigenstructure, not noise)."""

    def __init__(self, cfg: GeneratorConfig, session: Session | Broker, *,
                 clock: Clock | None = None):
        self.cfg = cfg
        broker = session.broker if isinstance(session, Session) else session
        self.broker = broker
        # inherit the session/broker clock so the generator's pacing runs on
        # the same (possibly virtual) schedule as the pipeline it feeds
        self.clock = ensure_clock(clock if clock is not None
                                  else getattr(broker, "clock", None))
        self._field = FieldHandle(broker, "synthetic",
                                  shape=(cfg.field_elems,))
        rng = np.random.RandomState(0)
        k = cfg.coupled_modes
        theta = rng.uniform(0.05, 0.3, size=k)
        self._decay = rng.uniform(0.97, 1.0, size=k)
        self._rot = theta
        self._mix = rng.randn(cfg.field_elems, 2 * k).astype(np.float32) * 0.5
        self._threads: list[threading.Thread] = []
        self.produced = 0
        self._lock = threading.Lock()

    def _payload(self, rank: int, step: int) -> np.ndarray:
        k = self.cfg.coupled_modes
        t = step + rank * 0.37
        amp = self._decay ** t
        ph = self._rot * t
        z = np.concatenate([amp * np.cos(ph), amp * np.sin(ph)])
        noise = np.random.RandomState((rank * 1009 + step)).randn(
            self.cfg.field_elems).astype(np.float32) * 0.01
        return self._mix @ z.astype(np.float32) + noise

    def _produce(self, rank: int):
        period = 1.0 / self.cfg.rate_hz
        for step in range(self.cfg.n_steps):
            t0 = self.clock.now()
            self._field.write(step, self._payload(rank, step), rank=rank)
            with self._lock:
                self.produced += 1
            dt = self.clock.now() - t0
            if dt < period:
                self.clock.sleep(period - dt)
        self.clock.detach()    # exit the schedule without a watchdog stall

    def run(self, wait: bool = True):
        self._threads = [
            threading.Thread(target=self._produce, args=(r,), daemon=True)
            for r in range(self.cfg.n_producers)
        ]
        t0 = self.clock.now()
        for t in self._threads:
            self.clock.thread_started(t)
            t.start()
        if wait:
            for t in self._threads:
                self.clock.join(t)
        return self.clock.now() - t0
