"""The paper's workflow, end to end: parallel CFD (WindAroundBuildings-like)
-> ElasticBroker -> Cloud endpoints -> stream engine -> per-region DMD
stability panel (paper Figs 4/5) — on the declarative Session API.

    PYTHONPATH=src python examples/cfd_insitu.py
"""
import time

import numpy as np

from repro.analysis.dmd import StreamingDMD
from repro.analysis.metrics import unit_circle_distance
from repro.sim.cfd import CFDConfig, buildings_mask, init_state, region_fields, step
from repro.workflow import OperatorPipeline, Session, WorkflowConfig

cfg = CFDConfig(nx=128, nz=64, n_regions=8, pressure_iters=50)
N_FEAT = 256
WRITE_INTERVAL = 5           # paper §4.2
N_STEPS = 200

# Cloud setup: 2 endpoints, 8 executors (8:2:8 ~ paper ratio scaled down) —
# the whole deployment is one declarative config.
workflow = WorkflowConfig(n_producers=cfg.n_regions, n_groups=2,
                          executors_per_group=4, compress="int8+zstd",
                          trigger_interval=1.0, n_executors=cfg.n_regions)

dmd = {}

def dmd_stage(key, records):
    sd = dmd.setdefault(key, StreamingDMD(n_features=N_FEAT, window=16, rank=6))
    # one device call per micro-batch (not per record)
    sd.update_batch([r.payload for r in sorted(records, key=lambda r: r.step)])
    return sd.eigenvalues()

# operator pipeline over whole micro-batches (granularity="batch"): the
# DMD stage is stateful per stream, so its contract is "ordered" — the
# engine keeps each stream's updates exactly sequenced
pipeline = (OperatorPipeline(granularity="batch")
            .map("dmd", dmd_stage, ordering="ordered")
            .map("stability", lambda k, eigs: unit_circle_distance(eigs),
                 ordering="unordered")
            .sink("stability_panel"))

session = Session(workflow, pipeline=pipeline)
velocity = session.open_field("velocity", shape=(N_FEAT,))

# visualize the scene
mask = buildings_mask(cfg)
print("WindAroundBuildings domain (# = building), flow ->")
for row in mask[::-8][:8]:
    print("  " + "".join("#" if c else "." for c in row[::2]))

state = init_state(cfg)
t0 = time.time()
for s in range(N_STEPS):
    state = step(state, cfg)
    if s % WRITE_INTERVAL == 0:
        fields = region_fields(state, cfg)
        # all regions of the step ride one aggregated frame per group
        velocity.write_batch(s, [f[:N_FEAT] for f in fields],
                             ranks=list(range(cfg.n_regions)))
sim_t = time.time() - t0
stats = session.close()      # broker.finalize() -> engine.drain_and_stop()
e2e = max((r.t_analyzed for r in session.results()), default=t0) - t0

print(f"\nsimulation: {N_STEPS} steps in {sim_t:.2f}s "
      f"(broker overhead included); workflow end-to-end {e2e:.2f}s")
print(f"broker: {stats.sent} records sent in {stats.frames_sent} frames, "
      f"{stats.dropped} dropped, "
      f"{stats.bytes_sent/1e6:.2f} MB on the wire")

print("\nper-region flow stability (paper Fig 5; 0 = neutrally stable):")
latest = session.exec_plan.latest("stability_panel")
for key in sorted(latest, key=lambda k: int(k.split("/r")[-1])):
    region = int(key.split("/r")[-1])
    v = latest[key]
    bar = "#" * int(min(v * 2000, 40))
    print(f"  z-slab {region} (height {region*8}-{region*8+7})  "
          f"{v:9.6f} {bar}")
print("\nlower slabs (building wakes) should be less stable than the "
      "free stream above — that is the paper's Fig-5 insight.")
