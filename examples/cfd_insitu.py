"""The paper's workflow, end to end: parallel CFD (WindAroundBuildings-like)
-> ElasticBroker -> Cloud endpoints -> stream engine -> per-region DMD
stability panel (paper Figs 4/5).

    PYTHONPATH=src python examples/cfd_insitu.py
"""
import time

import numpy as np

from repro.analysis.dmd import StreamingDMD
from repro.analysis.metrics import unit_circle_distance
from repro.core.api import broker_connect, broker_init, broker_write
from repro.core.broker import BrokerConfig
from repro.core.grouping import GroupPlan
from repro.sim.cfd import CFDConfig, buildings_mask, init_state, region_fields, step
from repro.streaming.endpoint import make_endpoints
from repro.streaming.engine import StreamEngine

cfg = CFDConfig(nx=128, nz=64, n_regions=8, pressure_iters=50)
N_FEAT = 256
WRITE_INTERVAL = 5           # paper §4.2
N_STEPS = 200

# Cloud setup: 2 endpoints, 8 executors (8:2:8 ~ paper ratio scaled down)
endpoints = make_endpoints(2)
broker = broker_connect(endpoints, n_producers=cfg.n_regions,
                        cfg=BrokerConfig(compress="int8+zstd"),
                        plan=GroupPlan(cfg.n_regions, 2, 4))
dmd = {}

def analyze(key, records):
    sd = dmd.setdefault(key, StreamingDMD(n_features=N_FEAT, window=16, rank=6))
    # one device call per micro-batch (not per record)
    sd.update_batch([r.payload for r in sorted(records, key=lambda r: r.step)])
    return unit_circle_distance(sd.eigenvalues())

engine = StreamEngine([e.handle for e in endpoints], analyze,
                      n_executors=cfg.n_regions, trigger_interval=1.0)
ctxs = [broker_init(f"velocity", r) for r in range(cfg.n_regions)]

# visualize the scene
mask = buildings_mask(cfg)
print("WindAroundBuildings domain (# = building), flow ->")
for row in mask[::-8][:8]:
    print("  " + "".join("#" if c else "." for c in row[::2]))

state = init_state(cfg)
t0 = time.time()
for s in range(N_STEPS):
    state = step(state, cfg)
    if s % WRITE_INTERVAL == 0:
        for r, field in enumerate(region_fields(state, cfg)):
            broker_write(ctxs[r], s, field[:N_FEAT])
sim_t = time.time() - t0
broker.flush()
engine.drain_and_stop()
e2e = max((r.t_analyzed for r in engine.collect()), default=t0) - t0

print(f"\nsimulation: {N_STEPS} steps in {sim_t:.2f}s "
      f"(broker overhead included); workflow end-to-end {e2e:.2f}s")
print(f"broker: {broker.stats.sent} records sent, "
      f"{broker.stats.dropped} dropped, "
      f"{broker.stats.bytes_sent/1e6:.2f} MB on the wire")

print("\nper-region flow stability (paper Fig 5; 0 = neutrally stable):")
latest = {}
for r in engine.collect():
    if not isinstance(r.value, Exception):
        latest[r.stream_key] = r.value
for key in sorted(latest, key=lambda k: int(k.split("/r")[-1])):
    region = int(key.split("/r")[-1])
    v = latest[key]
    bar = "#" * int(min(v * 2000, 40))
    print(f"  z-slab {region} (height {region*8}-{region*8+7})  "
          f"{v:9.6f} {bar}")
print("\nlower slabs (building wakes) should be less stable than the "
      "free stream above — that is the paper's Fig-5 insight.")
