"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps with
the full production stack — microbatched train step, async checkpointing with
mid-run restore, and broker-streamed DMD telemetry.

    PYTHONPATH=src python examples/train_100m.py            # full (~100M)
    PYTHONPATH=src python examples/train_100m.py --ci       # CPU-CI scale
"""
import argparse
import time
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np

import repro.configs as C
from repro.checkpoint.ckpt import CheckpointManager
from repro.core.api import broker_connect
from repro.core.broker import BrokerConfig
from repro.core.grouping import GroupPlan
from repro.core.taps import TapStreamer
from repro.data.pipeline import TokenPipeline
from repro.launch.train import dmd_analyzer
from repro.models import transformer as T
from repro.models.modules import materialize
from repro.models.steps import make_train_step
from repro.optim import adamw
from repro.streaming.endpoint import make_endpoints
from repro.streaming.engine import StreamEngine

p = argparse.ArgumentParser()
p.add_argument("--ci", action="store_true", help="CPU-CI scale (~8M, 40 steps)")
p.add_argument("--steps", type=int, default=None)
args = p.parse_args()

base = C.get("starcoder2-3b")
if args.ci:
    cfg = replace(base.reduced(), name="sc2-8m", d_model=256, n_layers=4,
                  d_ff=1024, vocab_size=2048, n_heads=8, n_kv_heads=2,
                  head_dim=32)
    steps, batch, seq, mb = args.steps or 40, 8, 128, 1
else:
    cfg = replace(base, name="sc2-100m", d_model=768, n_layers=12,
                  d_ff=3072, n_heads=12, n_kv_heads=2, head_dim=64,
                  vocab_size=32768, dtype=jax.numpy.float32, remat=False)
    steps, batch, seq, mb = args.steps or 300, 16, 512, 2

params = materialize(T.build_specs(cfg), jax.random.key(0), cfg.dtype)
n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
print(f"model: {cfg.name}, {n_params/1e6:.1f}M params, {steps} steps")

opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=max(10, steps // 10),
                            total_steps=steps)
opt = adamw.init_opt_state(opt_cfg, params)
step_fn = jax.jit(make_train_step(cfg, opt_cfg, mb))
pipe = TokenPipeline(cfg, batch=batch, seq=seq)
mgr = CheckpointManager(Path("/tmp/repro_ckpt") / cfg.name, keep=2)

# broker + cloud analysis plane
N_REGIONS = 4
eps = make_endpoints(1)
broker = broker_connect(eps, n_producers=N_REGIONS,
                        cfg=BrokerConfig(compress="int8+zstd"),
                        plan=GroupPlan(N_REGIONS, 1, 4))
engine = StreamEngine([e.handle for e in eps],
                      dmd_analyzer(cfg.tap_snapshot_dim),
                      n_executors=4, trigger_interval=1.0)
streamer = TapStreamer(broker, n_regions=N_REGIONS)

losses = []
t0 = time.time()
for s in range(steps):
    params, opt, metrics, taps = step_fn(params, opt, pipe.batch_at(s))
    losses.append(float(metrics["loss"]))
    streamer.publish(s, {"resid_norm": taps["resid_norm"],
                         "snapshot": taps["snapshot"]})
    if (s + 1) % 20 == 0:
        mgr.save(s + 1, {"params": params, "opt": opt})   # async
    if s % max(1, steps // 10) == 0:
        dt = (time.time() - t0) / (s + 1)
        print(f"  step {s:4d} loss {losses[-1]:.4f}  {dt:.2f}s/step")
mgr.wait()

# demonstrate checkpoint restore mid-history
restored, rstep = mgr.restore({"params": params, "opt": opt})
print(f"restored checkpoint from step {rstep} "
      f"({mgr.save_count} checkpoints written)")

broker.flush()
engine.drain_and_stop()
panel = {r.stream_key: r.value for r in engine.collect()
         if not isinstance(r.value, Exception)}
print(f"\nfinal loss {losses[-1]:.4f} (start {losses[0]:.4f}) — "
      f"{'LEARNING' if losses[-1] < losses[0] * 0.8 else 'check hyperparams'}")
print("DMD stability by region:",
      {k.split('/')[0] + '/' + k.split('/')[-1]: round(v, 4)
       for k, v in sorted(panel.items())})
assert losses[-1] < losses[0], "training must reduce loss"
