"""Batched serving example: prefill a batch of prompts, decode with a KV
cache, stream per-step telemetry — `python -m repro.launch.serve` wrapped
with elastic-endpoint failover demonstrated live.

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

from repro.launch.serve import main as serve_main
from repro.core.api import broker_connect, broker_init, broker_write
from repro.core.broker import BrokerConfig
from repro.core.grouping import GroupPlan
from repro.streaming.endpoint import make_endpoints

# 1) serve a batch
out = serve_main(["--arch", "gemma3-12b", "--preset", "ci",
                  "--batch", "4", "--prompt-len", "32", "--gen", "12"])
print(f"[example] generated token matrix shape: {out.shape}")

# 2) demonstrate endpoint failover on the telemetry plane
eps = make_endpoints(2)
broker = broker_connect(eps, n_producers=4,
                        cfg=BrokerConfig(compress="none", retry_limit=3),
                        plan=GroupPlan(4, 2, 2))
ctxs = [broker_init("decode_norm", r) for r in range(4)]
for step in range(5):
    for r in range(4):
        broker_write(ctxs[r], step, np.asarray([float(step)], np.float32))
eps[0].handle.fail()
print("[example] endpoint ep0 FAILED — broker re-routes group 0...")
for step in range(5, 10):
    for r in range(4):
        broker_write(ctxs[r], step, np.asarray([float(step)], np.float32))
broker.flush()
stats = broker.finalize()
print(f"[example] delivered {stats.sent}/40 records "
      f"({stats.rerouted} re-routed after failover, {stats.dropped} dropped)")
assert stats.sent == 40
