"""Windowed DMD + threshold alerting on the stream-operator API.

The paper's Cloud pipeline as a *typed* dataflow instead of bare callbacks:
four producer ranks stream 16-dim field snapshots — two with decaying
dynamics (unstable: eigenvalues off the unit circle), two rotating
(neutral) — and the operator graph

    records ─ KeyBy(rank) ─ TumblingWindow(0.5s event time)
                ─ BatchAggregate(batched_window_dmd) ─ Map(stability) ─ Sink(scores)
                                                └─ Map(alert, ORDERED) ─ Sink(alerts)

windows each rank's records by ``t_generated``, runs batch DMD per fired
pane, and raises ordered alerts for unstable ranks.  Everything upstream of
the alert is order-insensitive (``keyed``), so the engine fans one rank's
micro-batches across all executors — the windowed analysis runs
intra-stream parallel while alerts stay exactly sequenced.  The DMD stage
is a :class:`BatchAggregate`: when the watermark fires all four ranks'
panes together, they are solved in ONE vmapped device dispatch
(``analysis.dmd.batched_window_dmd``) instead of four (the summary's
``dmd_max_batch`` shows the coalescing).

Runs on VIRTUAL time by default: a multi-second study finishes in well
under a second of wall clock and is deterministic — same seed ⇒
byte-identical operator trace (the CI ``windowed-dmd-smoke`` job runs this
twice and diffs the traces).

    PYTHONPATH=src python examples/windowed_dmd.py [--seed N] [--trace PATH]
"""
import argparse
import json

import numpy as np

from repro.analysis.dmd import make_dmd_aggregate
from repro.analysis.metrics import unit_circle_distance
from repro.runtime.clock import VirtualClock
from repro.workflow import OperatorPipeline, Session, WorkflowConfig

N_RANKS = 4
DIM = 16
RATE_HZ = 20.0          # steps/s per rank
DURATION_S = 3.0        # virtual seconds of streaming
WINDOW_S = 0.5          # event-time tumbling window
# mean (|lambda|-1)^2 — a rank decaying at 0.55/step scores ~(0.55-1)^2
# ~= 0.2 on its true mode; rotating ranks sit on the unit circle (~0)
ALERT_THRESHOLD = 0.1


def build_pipeline() -> OperatorPipeline:
    def prepare(records):
        ordered = sorted(records, key=lambda r: (r.step, r.rank))
        return [r.payload for r in ordered]

    def stability(key, eigs):
        return round(unit_circle_distance(eigs), 9)

    def alert(key, score):
        if score > ALERT_THRESHOLD:
            return ("UNSTABLE", key, score)
        return None

    # one window of lateness keeps cross-stream watermark races from
    # dropping records: a pane with a step gap is no longer a clean
    # one-step time-shift and its DMD fit drifts off the true modes
    return (OperatorPipeline()
            .key_by("by_rank", lambda k, rec: f"r{rec.rank}")
            .tumbling_window("win", WINDOW_S, allowed_lateness_s=WINDOW_S)
            .batch_aggregate("dmd", make_dmd_aggregate(
                rank=4, n_features=DIM, prepare=prepare))
            .map("stability", stability, ordering="unordered")
            .sink("scores")
            .map("alert", alert, ordering="ordered")
            .sink("alerts"))


def main(seed: int = 0, trace_path: str | None = None) -> dict:
    clock = VirtualClock(seed=seed)
    clock.attach()                       # this thread drives the schedule
    events = []

    cfg = WorkflowConfig(n_producers=N_RANKS, n_groups=1,
                         executors_per_group=4, compress="none",
                         trigger_interval=0.05, min_batch=4,
                         clock="virtual", clock_seed=seed)
    sess = Session(cfg, pipeline=build_pipeline(), clock=clock)
    sess.exec_plan.on_event = lambda kind, **d: events.append(
        (round(clock.now(), 9), kind, d))

    # two decaying ranks (unstable), two rotating (neutral); same modal
    # mixing construction as tests/test_dag.py
    rng = np.random.RandomState(seed)
    mix = np.linalg.qr(rng.randn(DIM, 2))[0]
    h = sess.open_field("vel", shape=(DIM,))
    n_steps = int(DURATION_S * RATE_HZ)
    for step in range(n_steps):
        for rank in range(N_RANKS):
            if rank < 2:                                   # decaying
                snap = mix[:, 0] * (0.55 ** step)
            else:                                          # rotating
                ang = 0.3 * step
                snap = mix @ np.array([np.cos(ang), np.sin(ang)])
            h.write(step, snap.astype(np.float32), rank=rank)
        clock.sleep(1.0 / RATE_HZ)
    sess.flush(timeout=60.0)
    sess.close()

    scores = sess.exec_plan.latest("scores")
    alerts = sess.exec_plan.results("alerts")
    acct = sess.exec_plan.accounting()
    bstats = sess.exec_plan.batch_stats()["dmd"]
    unstable = sorted({key for key, _v, _t in alerts})
    summary = {
        "seed": seed,
        "records": sess.stats.sent,
        "panes_fired": acct["windows"]["win"]["panes_fired"],
        "late_dropped": acct["windows"]["win"]["late_dropped"],
        "accounting_closed": acct["closed"],
        "dmd_batches": bstats["batches"],
        "dmd_panes": bstats["items"],
        "dmd_max_batch": bstats["max_batch"],
        "scores": {k: scores[k] for k in sorted(scores)},
        "alerted": unstable,
    }
    print(json.dumps(summary, indent=2))

    assert summary["accounting_closed"], "window loss ledger must close"
    assert unstable == ["r0", "r1"], \
        f"decaying ranks must alert (and only them), got {unstable}"
    assert all(scores[k] <= ALERT_THRESHOLD for k in ("r2", "r3")), \
        "rotating ranks are neutral and must not alert"
    assert bstats["max_batch"] > 1, \
        "co-fired panes must coalesce into one batched DMD dispatch"

    if trace_path:
        lines = [json.dumps({"summary": summary}, sort_keys=True)]
        lines += [json.dumps({"t": t, "kind": k, **d}, sort_keys=True)
                  for t, k, d in sorted(
                      events, key=lambda e: (e[0], e[1],
                                             json.dumps(e[2], sort_keys=True)))]
        with open(trace_path, "w") as f:
            f.write("\n".join(lines) + "\n")
        print(f"# operator trace ({len(events)} events) -> {trace_path}")
    return summary


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--trace", default=None,
                   help="write the operator-level event trace (jsonl) here")
    args = p.parse_args()
    main(seed=args.seed, trace_path=args.trace)
