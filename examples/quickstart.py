"""Quickstart: the whole ElasticBroker-JAX loop in one small script.

Trains a tiny LM while streaming per-layer field taps through the broker to a
Cloud-style stream-processing engine running online DMD — you watch the
training dynamics' eigen-stability converge *while the job runs*, which is
the paper's whole point.  The entire HPC→Cloud deployment is one
``WorkflowConfig`` + ``Session``.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

import repro.configs as C
from repro.analysis.dmd import StreamingDMD
from repro.analysis.metrics import unit_circle_distance
from repro.core.taps import TapStreamer
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as T
from repro.models.modules import materialize
from repro.models.steps import make_train_step
from repro.optim import adamw
from repro.workflow import Session, WorkflowConfig

# ---- 1. the "HPC" side: a (tiny) LM training job --------------------------
cfg = C.get("starcoder2-3b").reduced()
params = materialize(T.build_specs(cfg), jax.random.key(0), cfg.dtype)
opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=5)
opt = adamw.init_opt_state(opt_cfg, params)
train_step = jax.jit(make_train_step(cfg, opt_cfg))
pipe = TokenPipeline(cfg, batch=8, seq=64)

# ---- 2. the "Cloud" side: one declarative workflow -------------------------
N_REGIONS = 4
workflow = WorkflowConfig(n_producers=N_REGIONS, n_groups=1,
                          executors_per_group=4, compress="int8+zstd",
                          trigger_interval=0.5)
dmd_states = {}

def analyze(key, records):
    sd = dmd_states.setdefault(
        key, StreamingDMD(n_features=cfg.tap_snapshot_dim, window=16, rank=4))
    for r in sorted(records, key=lambda r: r.step):
        sd.update(np.asarray(r.payload).reshape(-1)[: cfg.tap_snapshot_dim])
    return unit_circle_distance(sd.eigenvalues())

session = Session(workflow, analyze=analyze)
streamer = TapStreamer(session, n_regions=N_REGIONS)

# ---- 3. run the cross-ecosystem workflow -----------------------------------
print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) "
      f"with in-situ streaming analysis...")
for step in range(30):
    params, opt, metrics, taps = train_step(params, opt, pipe.batch_at(step))
    streamer.publish(step, {"resid_norm": taps["resid_norm"],
                            "snapshot": taps["snapshot"]})
    if step % 10 == 0:
        print(f"  step {step:3d}  loss {float(metrics['loss']):.4f}")

stats = session.close()

# ---- 4. realtime insights (paper Fig 5 analog) -----------------------------
print("\nper-region DMD stability of training dynamics "
      "(closer to 0 = more stable):")
panel = {r.stream_key: r.value for r in session.results()
         if not isinstance(r.value, Exception)}
for key in sorted(panel):
    bar = "#" * int(min(panel[key], 1.0) * 40)
    print(f"  {key:28s} {panel[key]:8.5f} {bar}")
lat = session.latency_stats()
print(f"\nstream latency mean={lat['mean']*1e3:.1f}ms p99={lat['p99']*1e3:.1f}ms"
      f"  (records: {stats.sent} in {stats.frames_sent} frames, "
      f"dropped: {stats.dropped})")
