"""Multi-tenant QoS study — does the tenancy plane actually protect SLOs?

One seeded capacity squeeze (per-endpoint inbound bandwidth capped well
below the offered load), two control planes, same records:

  debt      the QoS plane as shipped: ``alerts`` declares priority 2, a
            0.5s p99 target and weight 4; ``batch`` is best-effort
            priority 0 with 3x the traffic.  Priority admission parks and
            (park-overflow) evicts batch at the shard high-water mark, and
            the ``SloDebtScalePolicy`` weighs scale decisions by
            accumulated per-tenant SLO debt.

  global    the same traffic with tenancy neutralized: both tenants ride
            at priority 0 (nobody parks, eviction is plain oldest-first)
            and scaling follows the single global p99 target — the
            pre-tenancy behavior, with per-tenant accounting kept on so
            the damage is measurable.

Gates, per seed:

  * SLO hold: in debt mode, the p99-targeted tenant's squeeze-phase p99
    stays under its target AND it loses nothing (no drops, no evictions);
  * graceful degradation: debt mode parks/evicts ONLY best-effort batch
    traffic, and its loss ledger closes exactly
    (admitted == sent + evicted);
  * contrast: global mode breaches — the alerts tenant's squeeze-phase
    p99 crosses its target or its records get evicted with everyone
    else's;
  * closure: per-tenant ledgers close in BOTH modes (loss is always
    attributed, never silent).

CI runs this twice and byte-compares the emitted traces, so the whole
QoS plane is deterministic end to end.

  PYTHONPATH=src python benchmarks/tenancy.py
      [--seeds 0] [--trace PATH] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.sim.scenario import LoadPhase, Scenario, TenantTraffic, run_scenario
from repro.tenancy import TenantSpec
from repro.workflow import ElasticityConfig, WorkflowConfig

P99_TARGET_S = 0.5
SQUEEZE = "squeeze"
PHASES = (LoadPhase("calm", 1.0, 10.0),
          LoadPhase(SQUEEZE, 2.0, 40.0),
          LoadPhase("recover", 1.0, 10.0),
          LoadPhase("drain", 4.0, 0.0))
TRAFFIC = (TenantTraffic("alerts", ranks=(0,), every=2),
           TenantTraffic("batch", ranks=(1, 2, 3)))


def _workflow(mode: str) -> WorkflowConfig:
    if mode == "debt":
        tenants = (TenantSpec("alerts", priority=2,
                              p99_target_s=P99_TARGET_S, weight=4.0),
                   TenantSpec("batch", priority=0))
        # fleet-global thresholds out of reach: only per-tenant SLO debt
        # can drive scale-up in this mode
        elastic = ElasticityConfig(
            enabled=True, interval_s=0.1, slo_debt=True,
            target_p99_s=1e9, backlog_high=10**9, adapt_batch=False,
            min_executors=1, max_executors=8, cooldown_s=0.5,
            heartbeat_timeout_s=60.0, replace_stragglers=False)
    else:
        # same declared tenants, QoS neutralized: equal priority means
        # nobody parks and eviction is oldest-first across tenants; the
        # single global target drives scaling
        tenants = (TenantSpec("alerts", priority=0,
                              p99_target_s=P99_TARGET_S, weight=4.0),
                   TenantSpec("batch", priority=0))
        elastic = ElasticityConfig(
            enabled=True, interval_s=0.1, target_p99_s=P99_TARGET_S,
            backlog_high=10**9, adapt_batch=False,
            min_executors=1, max_executors=8, cooldown_s=0.5,
            heartbeat_timeout_s=60.0, replace_stragglers=False)
    return WorkflowConfig(
        n_producers=4, n_groups=2, compress="none",
        queue_capacity=32, max_batch_records=2, inbound_bw=4_000.0,
        backpressure="drop_oldest", qos_high_water=0.3,
        trigger_interval=0.05, min_batch=2, n_executors=2,
        clock="virtual", flush_timeout_s=120.0,
        tenants=tenants, elasticity=elastic)


def _run(seed: int, mode: str):
    sc = Scenario(workflow=_workflow(mode), phases=PHASES,
                  tenant_traffic=TRAFFIC, analysis_cost_s=0.001,
                  payload_elems=32, seed=seed)
    return run_scenario(sc)


def main(seeds: list[int], trace_path: str | None = None) -> dict:
    rows, traces = [], []
    for seed in seeds:
        debt = _run(seed, "debt")
        glob = _run(seed, "global")
        traces.append((seed, debt, glob))
        dt, gt = debt.summary["tenants"], glob.summary["tenants"]
        rows.append({
            "seed": seed,
            "debt_alerts_squeeze_p99": round(
                debt.phase_p99(SQUEEZE, tenant="alerts"), 6),
            "debt_batch_squeeze_p99": round(
                debt.phase_p99(SQUEEZE, tenant="batch"), 6),
            "global_alerts_squeeze_p99": round(
                glob.phase_p99(SQUEEZE, tenant="alerts"), 6),
            "debt_alerts_lost": (dt["alerts"]["dropped"]
                                 + dt["alerts"]["evicted"]),
            "global_alerts_lost": (gt["alerts"]["dropped"]
                                   + gt["alerts"]["evicted"]),
            "debt_batch_parked": dt["batch"]["parked_total"],
            "debt_batch_evicted": dt["batch"]["evicted"],
            "debt_batch_analyzed": dt["batch"]["analyzed"],
            "debt_ledger_closed": debt.summary["tenant_ledger"]["closed"],
            "global_ledger_closed": glob.summary["tenant_ledger"]["closed"],
        })
    if trace_path:
        with Path(trace_path).open("w") as fh:
            for seed, debt, glob in traces:
                fh.write(json.dumps({"seed": seed, "mode": "debt",
                                     "digest": debt.digest()}) + "\n")
                fh.write(debt.to_jsonl())
                fh.write(json.dumps({"seed": seed, "mode": "global",
                                     "digest": glob.digest()}) + "\n")
                fh.write(glob.to_jsonl())
        print(f"# tenancy event traces -> {trace_path}")
    verdict = {
        "seeds": seeds,
        "p99_target_s": P99_TARGET_S,
        "slo_held": all(r["debt_alerts_squeeze_p99"] <= P99_TARGET_S
                        and r["debt_alerts_lost"] == 0 for r in rows),
        "graceful": all((r["debt_batch_parked"] + r["debt_batch_evicted"]) > 0
                        and r["debt_batch_analyzed"] > 0 for r in rows),
        "global_breaches": all(
            r["global_alerts_squeeze_p99"] > P99_TARGET_S
            or r["global_alerts_lost"] > 0 for r in rows),
        "ledgers_closed": all(r["debt_ledger_closed"]
                              and r["global_ledger_closed"] for r in rows),
    }
    print("seed,debt_alerts_p99,global_alerts_p99,debt_alerts_lost,"
          "global_alerts_lost,batch_parked,batch_evicted")
    for r in rows:
        print(f"{r['seed']},{r['debt_alerts_squeeze_p99']},"
              f"{r['global_alerts_squeeze_p99']},{r['debt_alerts_lost']},"
              f"{r['global_alerts_lost']},{r['debt_batch_parked']},"
              f"{r['debt_batch_evicted']}")
    print(f"verdict: {verdict}")
    return {"rows": rows, "verdict": verdict}


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--seeds", default="0",
                   help="comma-separated VirtualClock seeds")
    p.add_argument("--trace", default=None,
                   help="write both modes' event traces (jsonl) here")
    p.add_argument("--json", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_tenancy.json"))
    args = p.parse_args()
    t0 = time.time()
    out = main([int(s) for s in args.seeds.split(",")],
               trace_path=args.trace)
    out["wall_seconds"] = round(time.time() - t0, 2)
    Path(args.json).write_text(json.dumps(out, indent=2) + "\n")
    v = out["verdict"]
    if not v["ledgers_closed"]:
        raise SystemExit("tenancy gate FAILED: a per-tenant loss ledger "
                         "did not close — loss went unattributed")
    if not v["slo_held"]:
        raise SystemExit("tenancy gate FAILED: debt-weighted control let "
                         "the protected tenant breach its p99 target or "
                         "lose records")
    if not v["graceful"]:
        raise SystemExit("tenancy gate FAILED: best-effort traffic was not "
                         "degraded gracefully (no parking/eviction, or "
                         "starved outright)")
    if not v["global_breaches"]:
        raise SystemExit("tenancy gate FAILED: the tenancy-neutralized "
                         "baseline held the SLO — the squeeze is not "
                         "actually squeezing")
