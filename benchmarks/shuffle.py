"""Keyed-shuffle / sharded fan-in benchmark — does the sharded data plane
actually relieve a hot-keyed single fan-in?

One seeded workload, two topologies, same records:

  single     the paper's single fan-in: 1 group -> 1 endpoint, no shards,
             producer-stream partitioning.  The lone endpoint's inbound
             bandwidth is the bottleneck.
  sharded    sharded data plane: N groups over N endpoints behind
             ``broker_shards`` group-owning broker shards, with the plan's
             shuffle edge re-partitioning records ACROSS producer streams
             by key (``shuffle_partitions``).  Per-shard telemetry feeds
             the controller (``shard_backlog_high``), whose scale-up
             decisions this study asserts.

The load is 1k virtual producer streams with deliberate hot-key skew:
80% of all records key onto 10% of the keys (10 hot keys out of 100), so
producer-partitioned dispatch concentrates work while keyed shuffle
spreads each hot key's records over one owned partition per key.

Gates, per seed:

  * throughput: the sharded run sustains >= 2x the single fan-in's
    records/virtual-second;
  * correctness: sink digests are byte-identical between the two
    topologies (same panes, same contents — sharding must not change
    results);
  * control loop: >= 1 controller scale-up decision in the sharded run is
    driven by per-shard telemetry (action reason ``shardN backlog=...``);
  * skew: the generated workload really is skewed (>= 80% of records on
    <= 10% of keys, measured from window-fire events).

CI runs this twice and byte-compares the emitted traces, so the sharded
path is deterministic end to end.

  PYTHONPATH=src python benchmarks/shuffle.py
      [--seeds 0] [--streams 1000] [--trace PATH] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.sim.scenario import LoadPhase, Scenario, run_scenario
from repro.streaming.operators import OperatorPipeline
from repro.workflow import ElasticityConfig, WorkflowConfig

HOT_KEYS = 10            # 10% of the key space...
COLD_KEYS = 90
HOT_FRACTION = 0.8       # ...receives 80% of the records
PHASES = (LoadPhase("steady", 2.0, 5.0), LoadPhase("drain", 0.5, 0.0))
N_SHARDS = 4
N_GROUPS = 8
SHUFFLE_PARTITIONS = 64
SHARD_BACKLOG_HIGH = 16
# per-endpoint inbound bandwidth (bytes/s): sized so the single fan-in is
# ingest-bound while the sharded fleet's aggregate (N_GROUPS endpoints)
# still has to queue — the per-shard backlog signal must actually fire
INBOUND_BW = 30_000.0


def make_key_fn(n_ranks: int):
    """Deterministic hot-key map, independent of group topology: the first
    HOT_FRACTION of ranks pool onto HOT_KEYS keys, the rest spread over
    COLD_KEYS keys.  Used by the plan's KeyBy — which is also the shuffle
    edge's routing function."""
    hot_ranks = int(n_ranks * HOT_FRACTION)

    def key_fn(stream_key: str, _rec) -> str:
        rank = int(stream_key.rsplit("/r", 1)[1])
        if rank < hot_ranks:
            return f"hot{rank % HOT_KEYS}"
        return f"cold{(rank - hot_ranks) % COLD_KEYS}"

    return key_fn


def make_pipeline(n_ranks: int):
    """Source KeyBy => the plan compiles to a shuffle edge.  The aggregate
    is order-insensitive and topology-blind ((rank, step, payload sum) —
    never group_id, which differs between the two modes) so sink digests
    compare across topologies."""
    key_fn = make_key_fn(n_ranks)

    def factory() -> OperatorPipeline:
        return (OperatorPipeline()
                .key_by("skew", key_fn)
                .tumbling_window("win", 0.5, allowed_lateness_s=5.0)
                .aggregate("agg", lambda k, vals: sorted(
                    (r.rank, r.step,
                     round(float(np.asarray(r.payload,
                                            np.float64).sum()), 6))
                    for r in vals))
                .sink("out"))

    return factory


def _workflow(n_ranks: int, sharded: bool) -> WorkflowConfig:
    base = dict(
        n_producers=n_ranks, compress="none", backpressure="block",
        queue_capacity=256, max_batch_records=32, inbound_bw=INBOUND_BW,
        trigger_interval=0.05, min_batch=4, n_executors=8,
        clock="virtual", flush_timeout_s=60.0)
    if not sharded:
        return WorkflowConfig(n_groups=1, n_endpoints=1, **base)
    return WorkflowConfig(
        n_groups=N_GROUPS, n_endpoints=N_GROUPS, broker_shards=N_SHARDS,
        shuffle_partitions=SHUFFLE_PARTITIONS,
        elasticity=ElasticityConfig(
            enabled=True, interval_s=0.05, cooldown_s=1.0,
            # fleet-level thresholds out of reach: ONLY the per-shard
            # signal can trigger scale-up in this study
            target_p99_s=1e9, backlog_high=10**9,
            shard_backlog_high=SHARD_BACKLOG_HIGH,
            min_executors=1, max_executors=12, adapt_batch=False,
            replace_stragglers=False, heartbeat_timeout_s=60.0),
        **base)


def _run(seed: int, n_ranks: int, sharded: bool):
    sc = Scenario(workflow=_workflow(n_ranks, sharded), phases=PHASES,
                  seed=seed, operators=make_pipeline(n_ranks),
                  payload_elems=16, flush_timeout_s=120.0)
    return run_scenario(sc)


def _skew_measured(trace) -> float:
    """Hot-key record share, measured from the window-fire events (every
    record lands in exactly one fired pane of the tumbling window)."""
    hot = total = 0
    for _, d in trace.events_of("op"):
        if d.get("event") != "window_fire":
            continue
        total += d["n"]
        if d["key"].startswith("hot"):
            hot += d["n"]
    return hot / total if total else 0.0


def _throughput(trace) -> float:
    return trace.summary["analyzed"] / trace.summary["virtual_duration_s"]


def main(seeds: list[int], n_ranks: int,
         trace_path: str | None = None) -> dict:
    rows, traces = [], []
    for seed in seeds:
        single = _run(seed, n_ranks, sharded=False)
        sharded = _run(seed, n_ranks, sharded=True)
        traces.append((seed, single, sharded))
        shard_scaleups = [
            d for _, d in sharded.events_of("action")
            if d["kind"] == "scale_up" and d["reason"].startswith("shard")]
        thr_single, thr_sharded = _throughput(single), _throughput(sharded)
        rows.append({
            "seed": seed,
            "streams": n_ranks,
            "records": sharded.summary["written"],
            "single_virtual_s": single.summary["virtual_duration_s"],
            "sharded_virtual_s": sharded.summary["virtual_duration_s"],
            "single_rps": round(thr_single, 3),
            "sharded_rps": round(thr_sharded, 3),
            "speedup": round(thr_sharded / thr_single, 3),
            "skew_hot_share": round(_skew_measured(sharded), 4),
            "shard_scale_ups": len(shard_scaleups),
            "shard_scale_reason": (shard_scaleups[0]["reason"]
                                   if shard_scaleups else None),
            "digest_match": (sharded.summary["sink_digest"]
                             == single.summary["sink_digest"]),
            "sink_digest": sharded.summary["sink_digest"][:16],
            "windows_closed": (single.summary["windows"]["closed"]
                               and sharded.summary["windows"]["closed"]),
            "dropped": (single.summary["dropped_by_policy"]
                        + sharded.summary["dropped_by_policy"]),
        })
    if trace_path:
        # both topologies' full event traces, concatenated across seeds —
        # CI's run-twice determinism gate byte-compares exactly this file
        with Path(trace_path).open("w") as fh:
            for seed, single, sharded in traces:
                fh.write(json.dumps({"seed": seed, "mode": "single",
                                     "digest": single.digest()}) + "\n")
                fh.write(single.to_jsonl())
                fh.write(json.dumps({"seed": seed, "mode": "sharded",
                                     "digest": sharded.digest()}) + "\n")
                fh.write(sharded.to_jsonl())
        print(f"# shuffle event traces -> {trace_path}")
    verdict = {
        "seeds": seeds,
        "streams": n_ranks,
        "min_speedup": min(r["speedup"] for r in rows),
        "speedup_ok": all(r["speedup"] >= 2.0 for r in rows),
        "digests_ok": all(r["digest_match"] for r in rows),
        "skew_ok": all(r["skew_hot_share"] >= HOT_FRACTION - 0.01
                       for r in rows),
        "shard_signal_ok": all(r["shard_scale_ups"] >= 1 for r in rows),
        "lossless": all(r["dropped"] == 0 and r["windows_closed"]
                        for r in rows),
    }
    print("seed,records,single_rps,sharded_rps,speedup,hot_share,"
          "shard_scale_ups,digest_match")
    for r in rows:
        print(f"{r['seed']},{r['records']},{r['single_rps']},"
              f"{r['sharded_rps']},{r['speedup']},{r['skew_hot_share']},"
              f"{r['shard_scale_ups']},{r['digest_match']}")
    print(f"verdict: {verdict}")
    return {"rows": rows, "verdict": verdict}


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--seeds", default="0",
                   help="comma-separated VirtualClock seeds")
    p.add_argument("--streams", type=int, default=1000,
                   help="virtual producer streams (paper scale: 1k-10k)")
    p.add_argument("--trace", default=None,
                   help="write both topologies' event traces (jsonl) here")
    p.add_argument("--json", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_shuffle.json"))
    args = p.parse_args()
    t0 = time.time()
    out = main([int(s) for s in args.seeds.split(",")], args.streams,
               trace_path=args.trace)
    out["wall_seconds"] = round(time.time() - t0, 2)
    Path(args.json).write_text(json.dumps(out, indent=2) + "\n")
    print(f"# results -> {args.json} ({out['wall_seconds']}s wall)")
    v = out["verdict"]
    if not v["digests_ok"]:
        raise SystemExit("shuffle gate FAILED: sharded sink digest differs "
                         "from the single fan-in run")
    if not v["speedup_ok"]:
        raise SystemExit(f"shuffle gate FAILED: speedup "
                         f"{v['min_speedup']}x < 2x")
    if not v["shard_signal_ok"]:
        raise SystemExit("shuffle gate FAILED: no controller scale-up was "
                         "driven by per-shard telemetry")
    if not (v["skew_ok"] and v["lossless"]):
        raise SystemExit("shuffle gate FAILED: workload skew or loss "
                         "accounting check")
