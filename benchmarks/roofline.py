"""Roofline table generator — reads the dry-run artifacts and emits the
EXPERIMENTS.md §Roofline table (per arch x shape x mesh: the three terms,
dominant bound, MODEL_FLOPS/HLO_FLOPs ratio, roofline fraction)."""
from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def load(mesh_filter: str | None = None) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(str(ART / "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        parts = Path(f).stem.split("__")
        r["tag"] = parts[3] if len(parts) > 3 else "baseline"
        rows.append(r)
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bound | useful | roofline |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['bound']} "
            f"| {rf.get('useful_ratio', 0):.2f} "
            f"| {100 * rf.get('roofline_fraction', 0):.2f}% |")
    return "\n".join(out)


def main(csv=True):
    rows = load()
    if not rows:
        print("no dry-run artifacts found — run repro.launch.dryrun --all")
        return []
    if csv:
        print("roofline_cell,compute_s,memory_s,collective_s,bound,useful,"
              "roofline_frac,adj_roofline_frac")
        for r in rows:
            rf = r["roofline"]
            rk = r.get("roofline_kernelized", rf)
            print(f"{r['arch']}/{r['shape']}/{r['mesh']}/{r['tag']},"
                  f"{rf['compute_s']:.5f},{rf['memory_s']:.5f},"
                  f"{rf['collective_s']:.5f},{rf['bound']},"
                  f"{rf.get('useful_ratio', 0):.3f},"
                  f"{rf.get('roofline_fraction', 0):.4f},"
                  f"{rk.get('roofline_fraction', 0):.4f}")
    return rows


if __name__ == "__main__":
    main()
