"""Paper Fig 6: simulation elapsed time under three I/O modes x write
intervals, plus workflow end-to-end time for the ElasticBroker mode.

Modes (paper §4.2):
  file-based      — synchronous np.save per write (the Lustre 'collated' write)
  elasticbroker   — async broker streaming to endpoints + DMD engine
  simulation-only — writes disabled

CPU-host proxy of the Karst/Jetstream run: same protocol, scaled problem.
The container has no parallel filesystem, so the file-based mode reports two
columns: ``file_raw`` (local page-cache writes — unrealistically fast) and
``file_pfs`` with an explicit shared-FS model (FS_LATENCY_S per file create +
FS_BW aggregate bandwidth; Lustre small-file latencies of 2–10 ms are
well-documented, we use the conservative low end).  The broker path gets no
such adjustment — if anything it is *penalized* here because its sender
threads share this host's single core with the simulation.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.analysis.dmd import StreamingDMD
from repro.analysis.metrics import unit_circle_distance
from repro.sim.cfd import CFDConfig, init_state, region_fields, step
from repro.workflow import Session, WorkflowConfig

N_STEPS = 120
INTERVALS = (5, 10, 20)
FS_LATENCY_S = 0.002          # per-file create+commit on a shared PFS
FS_BW = 500e6                 # aggregate PFS bandwidth (bytes/s)


def _make_analyzer(n_feat, batched: bool = True):
    """batched=True: one device call per micro-batch (update_batch);
    False: the seed per-record protocol, kept as the comparison baseline."""
    states = {}

    def analyze(key, records):
        sd = states.setdefault(key, StreamingDMD(n_features=n_feat, window=12,
                                                 rank=4))
        recs = sorted(records, key=lambda r: r.step)
        if batched:
            sd.update_batch([r.payload for r in recs])
        else:
            for r in recs:
                sd.update(r.payload.reshape(-1)[:n_feat])
        return unit_circle_distance(sd.eigenvalues())

    return analyze


def run_mode(mode: str, write_interval: int, cfg: CFDConfig,
             fs_model: bool = False, batched: bool = True):
    state = init_state(cfg)
    state = step(state, cfg)  # warm the jit outside the timed region
    n_feat = 256

    tmpdir = None
    session = velocity = None
    if mode == "file":
        tmpdir = Path(tempfile.mkdtemp(prefix="ebk_fig6_"))
    elif mode == "broker":
        workflow = WorkflowConfig(n_producers=cfg.n_regions,
                                  n_groups=max(1, cfg.n_regions // 4),
                                  executors_per_group=4,
                                  compress="int8+zstd",
                                  max_batch_records=32 if batched else 1,
                                  trigger_interval=0.25,
                                  n_executors=cfg.n_regions)
        session = Session(workflow,
                          analyze=_make_analyzer(n_feat, batched=batched))
        velocity = session.open_field("velocity")

    t0 = time.time()
    for s in range(N_STEPS):
        state = step(state, cfg)
        if s % write_interval == 0:
            fields = region_fields(state, cfg)
            if mode == "file":
                for r, f in enumerate(fields):
                    np.save(tmpdir / f"step{s}_r{r}.npy", f)
                    (tmpdir / f"step{s}_r{r}.npy").stat()
                    if fs_model:  # shared-PFS create latency + bandwidth
                        time.sleep(FS_LATENCY_S + f.nbytes / FS_BW)
            elif mode == "broker":
                for r, f in enumerate(fields):
                    velocity.write(s, f, rank=r)
    np.asarray(state["u"]).sum()  # block on device work
    sim_elapsed = time.time() - t0

    e2e = None
    if mode == "broker":
        session.flush()
        session.close()
        results = session.results()
        if results:
            e2e = max(r.t_analyzed for r in results) - t0
    if tmpdir:
        shutil.rmtree(tmpdir, ignore_errors=True)
    return sim_elapsed, e2e


def main(csv=True):
    cfg = CFDConfig(nx=192, nz=96, n_regions=16, pressure_iters=50)
    rows = []
    for interval in INTERVALS:
        times = {}
        e2e_t = None
        for mode, kw in (("simonly", {}), ("file_raw", {}),
                         ("file_pfs", {"fs_model": True}), ("broker", {}),
                         ("broker_rec", {"batched": False})):
            base = {"simonly": "none", "file_raw": "file",
                    "file_pfs": "file", "broker": "broker",
                    "broker_rec": "broker"}[mode]
            t, e2e = run_mode(base, interval, cfg, **kw)
            times[mode] = t
            if e2e and mode == "broker":
                e2e_t = e2e
        rows.append((interval, times["simonly"], times["file_raw"],
                     times["file_pfs"], times["broker"], times["broker_rec"],
                     e2e_t or float("nan")))
    if csv:
        print("fig6_interval,simonly_s,file_raw_s,file_pfs_s,broker_s,"
              "broker_perrecord_s,workflow_e2e_s")
        for r in rows:
            print(",".join(f"{v:.3f}" if isinstance(v, float) else str(v)
                           for v in r))
    return rows


if __name__ == "__main__":
    main()
