"""Re-run the HLO analyzer over saved dry-run artifacts (no recompilation).

Analyzer improvements (fusion cost model, dtype corrections, kernel regions)
apply retroactively to every cell's stored HLO; JSONs are rewritten in place
with refreshed `hlo_analysis`, `roofline`, `roofline_kernelized`.
"""
from __future__ import annotations

import glob
import json
import sys
from pathlib import Path

import zstandard

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import configs                              # noqa: E402
from repro.launch import hlo_analysis as H             # noqa: E402

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def reanalyze(path: Path) -> dict | None:
    js = json.loads(path.read_text())
    hlo_path = path.with_suffix("").with_suffix("")  # strip .json
    hlo_path = Path(str(path)[:-5] + ".hlo.zst")
    if not hlo_path.exists():
        return None
    text = zstandard.ZstdDecompressor().decompress(
        hlo_path.read_bytes()).decode()
    analysis = H.analyze(text)
    model_flops = js["roofline"].get("model_flops")
    cfg = configs.get(js["arch"])
    grad_f32 = js["kind"] == "train" and not cfg.opt_8bit
    js["hlo_analysis"] = analysis
    js["roofline"] = H.roofline_terms(analysis, model_flops)
    js["roofline_kernelized"] = H.roofline_terms(
        H.tpu_dtype_corrected(H.kernelized(analysis), grad_dtype_f32=grad_f32),
        model_flops)
    path.write_text(json.dumps(js, indent=1))
    return js


def main():
    pat = sys.argv[1] if len(sys.argv) > 1 else "*"
    n = 0
    for f in sorted(glob.glob(str(ART / f"{pat}.json"))):
        js = reanalyze(Path(f))
        if js is None:
            continue
        n += 1
        rb, rk = js["roofline"], js["roofline_kernelized"]
        print(f"{Path(f).stem:56s} base={100*rb.get('roofline_fraction',0):5.1f}% "
              f"adj={100*rk.get('roofline_fraction',0):5.1f}% "
              f"bound={rk['bound']}", flush=True)
    print(f"reanalyzed {n} artifacts")


if __name__ == "__main__":
    main()
