"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
from __future__ import annotations

import glob
import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"

SKIPPED_LONG = ["starcoder2-3b", "minitron-8b", "llama3-405b", "gemma3-12b",
                "llama4-scout-17b-a16e", "arctic-480b", "musicgen-large",
                "llama-3.2-vision-11b"]


def load(tag: str | None = None):
    rows = {}
    for f in sorted(glob.glob(str(ART / "*.json"))):
        r = json.load(open(f))
        stem = Path(f).stem
        parts = stem.split("__")
        t = parts[3] if len(parts) > 3 else None
        if t != tag:
            continue
        rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def dryrun_table(rows):
    out = ["| arch | shape | mesh | compile | HLO GFLOPs/chip | arg GB/chip | "
           "collective GB/chip (AR/AG/RS/A2A/CP) |",
           "|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        h = r["hlo_analysis"]
        c = h["collectives"]
        cs = "/".join(f"{c.get(k, 0)/1e9:.1f}" for k in
                      ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        out.append(f"| {a} | {s} | {m} | {r['compile_s']:.0f}s "
                   f"| {h['flops']/1e9:,.0f} "
                   f"| {r['memory']['argument_bytes_per_device']/1e9:.2f} "
                   f"| {cs} |")
    for a in SKIPPED_LONG:
        out.append(f"| {a} | long_500k | — | SKIP | — | — | full attention is "
                   "O(S²) at 524k (DESIGN.md §5) |")
    return "\n".join(out)


def roofline_table(rows, which="roofline_kernelized"):
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "bound | MODEL/HLO flops | roofline |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        rf = r[which]
        out.append(
            f"| {a} | {s} | {m} | {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['bound']} "
            f"| {rf.get('useful_ratio', 0):.2f} "
            f"| {100*rf.get('roofline_fraction', 0):.1f}% |")
    return "\n".join(out)


def perf_row(arch, tag):
    f = ART / f"{arch}__train_4k__pod_16x16{'__' + tag if tag else ''}.json"
    if not f.exists():
        return None
    r = json.load(open(f))
    return r


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    tag = sys.argv[2] if len(sys.argv) > 2 else None
    rows = load(tag)
    if which in ("all", "dryrun"):
        print("### Dry-run table (per-chip, post-SPMD)\n")
        print(dryrun_table(rows))
    if which in ("all", "roofline"):
        print("\n### Roofline (baseline accounting)\n")
        print(roofline_table(rows, "roofline"))
        print("\n### Roofline (TPU-adjusted: Pallas-fused + dtype-corrected)\n")
        print(roofline_table(rows, "roofline_kernelized"))
