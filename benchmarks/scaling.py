"""Paper Fig 7: latency + aggregated throughput vs producer count.

Synthetic generators at the paper's producer:endpoint:executor ratio
(16:1:16 there; a CPU-host-scaled 4:1:4 here, same protocol).  Latency =
record generated -> analyzed (Fig 7a); throughput = aggregated payload
bytes/s over the run (Fig 7b).
"""
from __future__ import annotations

import time

from repro.analysis.dmd import StreamingDMD
from repro.analysis.metrics import unit_circle_distance
from repro.sim.synthetic import GeneratorConfig, SyntheticGenerator
from repro.workflow import Session, WorkflowConfig

RATIO = 4                     # producers per endpoint (paper: 16)
SCALES = (4, 8, 16, 32)       # paper: 16..128


def _analyzer(n_feat):
    states = {}

    def analyze(key, records):
        sd = states.setdefault(key, StreamingDMD(n_features=n_feat,
                                                 window=8, rank=3))
        sd.update_batch([r.payload for r in
                         sorted(records, key=lambda r: r.step)])
        return unit_circle_distance(sd.eigenvalues())

    return analyze


def run_scale(n_producers: int, *, steps: int = 40, rate_hz: float = 20.0,
              field_elems: int = 1024):
    n_eps = max(1, n_producers // RATIO)
    workflow = WorkflowConfig(n_producers=n_producers, n_groups=n_eps,
                              executors_per_group=RATIO,
                              compress="int8+zstd", queue_capacity=1024,
                              backpressure="block", trigger_interval=0.25)
    session = Session(workflow, analyze=_analyzer(128))
    gen = SyntheticGenerator(
        GeneratorConfig(n_producers=n_producers, field_elems=field_elems,
                        rate_hz=rate_hz, n_steps=steps), session)
    t0 = time.time()
    gen.run(wait=True)
    session.flush(timeout=30)
    session.close()
    wall = time.time() - t0
    stats = session.latency_stats()
    payload_bytes = gen.produced * field_elems * 4
    return {
        "producers": n_producers,
        "endpoints": n_eps,
        "executors": session.plan.n_executors,
        "records": gen.produced,
        "dropped": session.stats.dropped,
        "latency_mean_s": stats.get("mean", float("nan")),
        "latency_p99_s": stats.get("p99", float("nan")),
        "throughput_MBps": payload_bytes / wall / 1e6,
        "throughput_rec_s": gen.produced / wall,
    }


def main(csv=True):
    rows = [run_scale(n) for n in SCALES]
    if csv:
        print("fig7_producers,endpoints,executors,records,dropped,"
              "latency_mean_s,latency_p99_s,throughput_MBps,throughput_rec_s")
        for r in rows:
            print(f"{r['producers']},{r['endpoints']},{r['executors']},"
                  f"{r['records']},{r['dropped']},{r['latency_mean_s']:.3f},"
                  f"{r['latency_p99_s']:.3f},{r['throughput_MBps']:.2f},"
                  f"{r['throughput_rec_s']:.1f}")
    return rows


if __name__ == "__main__":
    main()
