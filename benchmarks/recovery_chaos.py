"""Recovery chaos benchmark — is exactly-once delivery actually exact?

Per seed, two runs of the same seeded workload:

  fault-free   no faults, no checkpoints.  Produces the oracle: the sink
               contents (window aggregates per key) a correct run emits.
  chaos        kill an executor, crash the broker twice, fail an endpoint
               mid-replay, and kill the WHOLE session (checkpoint restore
               + WAL tail replay) — all mid-run, on virtual time.

The gate, per seed:

  * the loss ledger closes: analyzed == written, nothing dropped by
    policy, no frame ever abandoned;
  * the chaos run's sink digest is byte-identical to the fault-free
    run's — every record applied exactly once, in the same windows.

CI runs this twice and diffs the emitted event traces byte-for-byte, so
the recovery path itself (not just its end state) is deterministic.

  PYTHONPATH=src python benchmarks/recovery_chaos.py
      [--seeds 0,1,2] [--trace PATH] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.sim.scenario import Fault, LoadPhase, Scenario, run_scenario
from repro.streaming.operators import OperatorPipeline
from repro.workflow import ElasticityConfig, WorkflowConfig

N_RANKS = 4
PHASES = (LoadPhase("steady", 3.0, 20.0), LoadPhase("drain", 2.5, 0.0))
FAULTS = (Fault(t=0.45, kind="kill_executor", target=1),
          Fault(t=0.65, kind="kill_broker"),       # mid-window
          Fault(t=0.95, kind="fail_endpoint", target=0),
          Fault(t=1.55, kind="kill_session"),      # checkpoint restore
          Fault(t=2.1, kind="kill_executor", target=0),
          Fault(t=2.6, kind="kill_broker"))
CHECKPOINT_EVERY_S = 0.6


def _workflow() -> WorkflowConfig:
    return WorkflowConfig(
        n_producers=N_RANKS, n_groups=2, executors_per_group=2,
        compress="none", backpressure="block", queue_capacity=4096,
        trigger_interval=0.05, min_batch=4, n_executors=2,
        max_batch_records=8, delivery="exactly-once", clock="virtual",
        flush_timeout_s=60.0,
        elasticity=ElasticityConfig(
            enabled=True, interval_s=0.1, heartbeat_timeout_s=0.5,
            min_executors=1, max_executors=4, cooldown_s=0.3))


def _pipeline() -> OperatorPipeline:
    return (OperatorPipeline()
            .map("norm", lambda k, rec: (rec.step,
                 round(float(np.asarray(rec.payload,
                                        dtype=np.float64).sum()), 6)))
            .key_by("bygroup", lambda k, v: k.split("/")[1])
            .tumbling_window("win", 0.5, allowed_lateness_s=1.0)
            .aggregate("agg", lambda k, vals: sorted(vals))
            .sink("out"))


def _run(seed: int, chaos: bool):
    sc = Scenario(workflow=_workflow(), phases=PHASES, seed=seed,
                  operators=_pipeline,
                  faults=FAULTS if chaos else (),
                  checkpoint_every_s=CHECKPOINT_EVERY_S if chaos else 0.0)
    return run_scenario(sc)


def main(seeds: list[int], trace_path: str | None = None) -> dict:
    rows, traces = [], []
    for seed in seeds:
        clean = _run(seed, chaos=False)
        chaos = _run(seed, chaos=True)
        traces.append((seed, chaos))
        c, f = clean.summary, chaos.summary
        row = {
            "seed": seed,
            "written": f["written"],
            "analyzed": f["analyzed"],
            "dropped_by_policy": f["dropped_by_policy"],
            "frames_abandoned": f["recovery"]["frames_abandoned"],
            "frames_replayed": f["recovery"]["frames_replayed"],
            "records_replayed": f["recovery"]["records_replayed"],
            "records_deduped": f["recovery"]["records_deduped"],
            "checkpoints": f["recovery"]["checkpoints"],
            "session_restores": f["recovery"]["session_restores"],
            "ledger_closed": (f["analyzed"] == f["written"]
                              and f["dropped_by_policy"] == 0
                              and f["recovery"]["frames_abandoned"] == 0),
            "windows_closed": f["windows"]["closed"],
            "digest_match": f["sink_digest"] == c["sink_digest"],
            "sink_digest": f["sink_digest"][:16],
        }
        rows.append(row)
    if trace_path:
        # one concatenated jsonl across seeds, so CI's run-twice
        # determinism gate is a single byte-for-byte cmp
        with Path(trace_path).open("w") as fh:
            for seed, tr in traces:
                fh.write(json.dumps({"seed": seed,
                                     "digest": tr.digest()}) + "\n")
                fh.write(tr.to_jsonl())
        print(f"# chaos event traces -> {trace_path}")
    verdict = {
        "seeds": seeds,
        "exactly_once": all(r["ledger_closed"] and r["digest_match"]
                            and r["windows_closed"] for r in rows),
        "total_records_replayed": sum(r["records_replayed"] for r in rows),
        "total_session_restores": sum(r["session_restores"] for r in rows),
    }
    hdr = ("seed,written,analyzed,replayed,deduped,checkpoints,restores,"
           "ledger_closed,digest_match")
    print(hdr)
    for r in rows:
        print(f"{r['seed']},{r['written']},{r['analyzed']},"
              f"{r['records_replayed']},{r['records_deduped']},"
              f"{r['checkpoints']},{r['session_restores']},"
              f"{r['ledger_closed']},{r['digest_match']}")
    print(f"verdict: {verdict}")
    return {"rows": rows, "verdict": verdict}


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--seeds", default="0,1,2",
                   help="comma-separated VirtualClock seeds")
    p.add_argument("--trace", default=None,
                   help="write the chaos runs' event traces (jsonl) here")
    p.add_argument("--json", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_recovery_chaos.json"))
    args = p.parse_args()
    t0 = time.time()
    out = main([int(s) for s in args.seeds.split(",")],
               trace_path=args.trace)
    out["wall_seconds"] = round(time.time() - t0, 2)
    Path(args.json).write_text(json.dumps(out, indent=2) + "\n")
    print(f"# results -> {args.json} ({out['wall_seconds']}s wall)")
    if not out["verdict"]["exactly_once"]:
        raise SystemExit("exactly-once gate FAILED: a chaos run lost, "
                         "duplicated, or re-windowed records")
    if out["verdict"]["total_session_restores"] < len(out["rows"]):
        raise SystemExit("chaos plan did not exercise session restore")
