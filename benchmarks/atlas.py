"""Workload atlas sweep — the scenario matrix as a single CLI.

Runs every scenario in ``repro.sim.atlas`` (diurnal, flash crowd,
endpoint blackout, network partition, straggler storm, hot-key drift,
and the three multi-tenant mixes) across a seed set on virtual time and
writes one canonical, fully-sorted JSON report.  The report is the
determinism artifact: CI runs this twice and byte-compares the files.

Gates (computed inside ``run_atlas`` and echoed in the verdict):

  * every per-tenant loss ledger closes in every run;
  * every run analyzes at least one record (no silently-dead scenario).

  PYTHONPATH=src python benchmarks/atlas.py
      [--scenarios a,b] [--seeds 0,1,2] [--report PATH] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.sim.atlas import SCENARIOS, report_json, run_atlas

if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--scenarios", default=None,
                   help=f"comma-separated subset of {sorted(SCENARIOS)}")
    p.add_argument("--seeds", default="0,1,2",
                   help="comma-separated VirtualClock seeds")
    p.add_argument("--report",
                   default=str(Path(__file__).resolve().parents[1]
                               / "ATLAS_report.json"),
                   help="canonical report artifact (byte-compared in CI)")
    p.add_argument("--json", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_atlas.json"))
    args = p.parse_args()
    names = args.scenarios.split(",") if args.scenarios else None
    seeds = tuple(int(s) for s in args.seeds.split(","))
    t0 = time.time()
    report = run_atlas(names=names, seeds=seeds)
    text = report_json(report)
    Path(args.report).write_text(text)
    print(f"# atlas report ({len(report['runs'])} runs) -> {args.report}")
    print("scenario,seed,analyzed,latency_p99,executors_peak")
    for r in report["runs"]:
        print(f"{r['scenario']},{r['seed']},{r['analyzed']},"
              f"{r['latency_p99']},{r['executors_peak']}")
    verdict = dict(report["gates"])
    print(f"verdict: {verdict}")
    out = {"gates": verdict,
           "atlas": report["atlas"],
           "report_bytes": len(text),
           "wall_seconds": round(time.time() - t0, 2)}
    Path(args.json).write_text(json.dumps(out, indent=2) + "\n")
    if not verdict["ledgers_closed"]:
        raise SystemExit("atlas gate FAILED: per-tenant loss ledgers did "
                         f"not close: {verdict['ledger_failures']}")
    if not verdict["all_runs_analyzed"]:
        raise SystemExit("atlas gate FAILED: silent scenario runs "
                         f"(nothing analyzed): {verdict['silent_runs']}")
