"""Benchmark orchestrator — one section per paper table/figure.

  fig6        end-to-end simulation time: file vs broker vs sim-only (Fig 6)
  fig7        latency + aggregated throughput scaling (Fig 7a/7b)
  kernels     kernel-layer microbenchmarks
  roofline    the 40-cell dry-run roofline table (from artifacts)
  elasticity  closed-loop load-spike study (off by default; ~30s extra)

``python -m benchmarks.run [--only fig6,fig7,kernels,roofline,elasticity]
[--json PATH]``

Each section's rows are also written as JSON (default ``BENCH_run.json`` at
the repo root) so the BENCH trajectory is machine-readable PR over PR.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path


def _jsonable(obj):
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if hasattr(obj, "item"):       # numpy scalars
        return obj.item()
    return obj


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default="fig6,fig7,kernels,roofline")
    p.add_argument("--json", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_run.json"))
    args = p.parse_args()
    want = set(args.only.split(","))
    failures = 0
    collected: dict = {}

    sections = []
    if "fig6" in want:
        from benchmarks import end_to_end
        sections.append(("fig6_end_to_end", end_to_end.main))
    if "fig7" in want:
        from benchmarks import scaling
        sections.append(("fig7_scaling", scaling.main))
    if "kernels" in want:
        from benchmarks import kernels_bench
        sections.append(("kernels", kernels_bench.main))
    if "roofline" in want:
        from benchmarks import roofline
        sections.append(("roofline", roofline.main))
    if "elasticity" in want:
        from benchmarks import elasticity
        sections.append(("elasticity", lambda: elasticity.main(smoke=True)))

    for name, fn in sections:
        print(f"\n# ==== {name} ====", flush=True)
        t0 = time.time()
        try:
            collected[name] = _jsonable(fn())
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    if args.json and collected:
        Path(args.json).write_text(json.dumps(collected, indent=2) + "\n")
        print(f"# results -> {args.json}", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark sections failed")


if __name__ == "__main__":
    main()
