"""Benchmark orchestrator — one section per paper table/figure.

  fig6      end-to-end simulation time: file vs broker vs sim-only (Fig 6)
  fig7      latency + aggregated throughput scaling (Fig 7a/7b)
  kernels   kernel-layer microbenchmarks
  roofline  the 40-cell dry-run roofline table (from artifacts)

``python -m benchmarks.run [--only fig6,fig7,kernels,roofline]``
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default="fig6,fig7,kernels,roofline")
    args = p.parse_args()
    want = set(args.only.split(","))
    failures = 0

    sections = []
    if "fig6" in want:
        from benchmarks import end_to_end
        sections.append(("fig6_end_to_end", end_to_end.main))
    if "fig7" in want:
        from benchmarks import scaling
        sections.append(("fig7_scaling", scaling.main))
    if "kernels" in want:
        from benchmarks import kernels_bench
        sections.append(("kernels", kernels_bench.main))
    if "roofline" in want:
        from benchmarks import roofline
        sections.append(("roofline", roofline.main))

    for name, fn in sections:
        print(f"\n# ==== {name} ====", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark sections failed")


if __name__ == "__main__":
    main()
