"""Elasticity benchmark — does the closed loop actually hold QoS for less?

One load-spike profile (low → spike → low) is replayed against three
provisioning strategies:

  static_low   executors fixed at the quiet-phase size.  Underprovisioned
               during the spike: backlog grows, generation→analysis p99
               blows through the target.
  static_peak  executors fixed at the spike size.  Holds the target, but
               pays peak executor-seconds for the whole run.
  elastic      ElasticController (telemetry bus + LatencyScalePolicy +
               BatchCapPolicy), min=1, max=peak.  The claim under test:
               it holds the configured p99 target through the spike while
               spending measurably fewer executor-seconds than static peak.

By default the study runs on **virtual time** (``repro.sim.scenario`` under
a seeded ``VirtualClock``): the whole three-mode suite finishes in a couple
of wall seconds, is deterministic (``--trace`` dumps the elastic run's
event trace; two same-seed invocations are byte-identical — CI's
``scenario-smoke`` job diffs them), and still exercises the real broker /
endpoints / engine / controller stack.  ``--wall`` switches back to the
original real-sleep mode for calibration against actual hardware.

Per-phase p99 is computed from records *generated* inside the phase window,
executor cost from the engine's executor-seconds integral.  Results land in
``BENCH_elasticity.json``.

  PYTHONPATH=src python benchmarks/elasticity.py [--smoke] [--wall]
      [--seed N] [--trace PATH] [--latency-trace PATH] [--json PATH]

``--latency-trace`` additionally dumps a record-level generation→analysis
latency curve per mode (``PATH-<mode>.jsonl``) — the raw material for
controller-policy regression sweeps, which virtual time makes ~free.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import math

import numpy as np

from repro.cloud import DEFAULT_CATALOG
from repro.sim.scenario import LoadPhase, Scenario, ScenarioRunner
from repro.streaming.engine import percentile_sorted
from repro.workflow import ElasticityConfig, Session, WorkflowConfig

N_RANKS = 4
FIELD_ELEMS = 256
ANALYZE_COST_S = 0.008          # simulated per-record analysis work
TARGET_P99_S = 1.5              # sits between elastic (~0.2s) and the
                                # underprovisioned static run (~3.5s)
BASE_EXECUTORS = 1              # quiet-phase provisioning
PEAK_EXECUTORS = 4              # spike provisioning
NODE_CLASS = "standard"         # cloud billing unit for node-seconds


def node_seconds_from_actions(n_exec0: int, duration_s: float,
                              actions) -> float:
    """Bill the run as if its executors lived on ``NODE_CLASS`` nodes:
    reconstruct alive(t) from the controller's scale actions and integrate
    whole-node occupancy (``ceil(alive / executors-per-node)``) over the
    run.  Cloud capacity comes in nodes, not executors — executor-seconds
    understate what a provider would actually charge for the fleet."""
    per = DEFAULT_CATALOG[NODE_CLASS].executors
    t_prev, alive, total = 0.0, n_exec0, 0.0
    for t, d in sorted(actions, key=lambda e: e[0]):
        if d["kind"] not in ("scale_up", "scale_down"):
            continue
        t = min(max(t, 0.0), duration_s)
        total += math.ceil(alive / per) * (t - t_prev)
        t_prev = t
        step = int(d.get("value") or 1)
        alive = max(1, alive + (step if d["kind"] == "scale_up" else -step))
    total += math.ceil(alive / per) * (duration_s - t_prev)
    return round(total, 6)


def _profile(smoke: bool) -> list[tuple[str, float, float]]:
    """(phase name, duration s, producer steps/s).  Each step writes
    N_RANKS records, so records/s = rate * N_RANKS."""
    if smoke:
        return [("low", 2.0, 5.0), ("spike", 4.0, 60.0), ("low", 3.0, 5.0)]
    return [("low", 5.0, 5.0), ("spike", 10.0, 60.0), ("low", 8.0, 5.0)]


def _workflow(mode: str) -> WorkflowConfig:
    elastic = mode == "elastic"
    n_exec = {"static_low": BASE_EXECUTORS, "static_peak": PEAK_EXECUTORS,
              "elastic": BASE_EXECUTORS}[mode]
    return WorkflowConfig(
        n_producers=N_RANKS, n_groups=2, executors_per_group=2,
        compress="none", backpressure="block", queue_capacity=4096,
        trigger_interval=0.05, min_batch=4, n_executors=n_exec,
        max_batch_records=8,
        elasticity=ElasticityConfig(
            enabled=elastic, interval_s=0.1, target_p99_s=TARGET_P99_S,
            min_executors=1, max_executors=PEAK_EXECUTORS, scale_up_step=2,
            backlog_high=24, idle_scale_down_s=1.0, cooldown_s=0.3))


# --------------------------------------------------------------- virtual mode
def _run_mode_virtual(mode: str, smoke: bool, seed: int,
                      record_latency: bool = False):
    """One provisioning strategy on deterministic simulated time; returns
    (result row, full event trace)."""
    sc = Scenario(
        workflow=_workflow(mode),
        phases=tuple(LoadPhase(name, dur, rate)
                     for name, dur, rate in _profile(smoke)),
        seed=seed, analysis_cost_s=ANALYZE_COST_S,
        payload_elems=FIELD_ELEMS, record_latency=record_latency)
    trace = ScenarioRunner(sc).run()
    s = trace.summary
    row = {
        "mode": mode,
        "records": s["sent"],
        "dropped": s["dropped_by_policy"],
        "p99_overall_s": s["latency_p99"],
        "p99_spike_s": trace.phase_p99("spike"),
        "p99_low_s": trace.phase_p99("low"),
        "executor_seconds": s["executor_seconds"],
        "node_seconds": node_seconds_from_actions(
            sc.workflow.n_executors, s["virtual_duration_s"],
            trace.events_of("action")),
        "executors_configured": sc.workflow.n_executors,
        "executors_peak_observed": max(s["executors_peak"],
                                       sc.workflow.n_executors),
        "virtual_duration_s": s["virtual_duration_s"],
    }
    row["node_cost"] = round(
        row["node_seconds"] * DEFAULT_CATALOG[NODE_CLASS].cost_rate, 6)
    if mode == "elastic":
        row["controller_actions"] = s.get("controller_actions", {})
    return row, trace


# ------------------------------------------------------------------ wall mode
def _run_mode_wall(mode: str, smoke: bool) -> dict:
    """The original real-sleep study (hardware calibration path)."""
    cfg = _workflow(mode)               # the one place the mode table lives
    elastic = cfg.elasticity.enabled
    n_exec = cfg.n_executors

    def analyze(key, records):
        time.sleep(ANALYZE_COST_S * len(records))
        return len(records)

    payload = np.zeros(FIELD_ELEMS, np.float32)
    phase_windows: list[tuple[str, float, float]] = []
    with Session(cfg, analyze=analyze) as sess:
        h = sess.open_field("load", shape=(FIELD_ELEMS,))
        step = 0
        for name, dur, rate in _profile(smoke):
            t0 = time.time()
            period = 1.0 / rate
            while True:
                now = time.time()
                if now - t0 >= dur:
                    break
                h.write_batch(step, [payload] * N_RANKS,
                              ranks=list(range(N_RANKS)))
                step += 1
                time.sleep(max(0.0, period - (time.time() - now)))
            phase_windows.append((name, t0, time.time()))
        sess.flush(timeout=60)
    # after close(): the controller thread is stopped, so the telemetry
    # history deque is safe to iterate
    exec_peak = max((s.alive_executors for s in sess.telemetry.history),
                    default=n_exec) if sess.telemetry is not None else n_exec
    results = sess.results()
    exec_secs = sess.engine.executor_seconds()

    def _phase_p99(name: str) -> float:
        lats = sorted(r.latency for r in results
                      for (pn, a, b) in phase_windows
                      if pn == name and a <= r.t_generated_min < b)
        return percentile_sorted(lats, 0.99)

    # node-seconds on wall time: integrate whole-node occupancy over the
    # telemetry history when the controller ran, else the static fleet
    per = DEFAULT_CATALOG[NODE_CLASS].executors
    t0_run, t1_run = phase_windows[0][1], phase_windows[-1][2]
    hist = list(sess.telemetry.history) if sess.telemetry is not None else []
    if len(hist) >= 2:
        node_secs = sum(
            np.ceil(max(a.alive_executors, 1) / per) * (b.t - a.t)
            for a, b in zip(hist, hist[1:]))
        node_secs += np.ceil(max(hist[0].alive_executors, 1) / per) \
            * max(0.0, hist[0].t - t0_run)
        node_secs += np.ceil(max(hist[-1].alive_executors, 1) / per) \
            * max(0.0, t1_run - hist[-1].t)
    else:
        node_secs = np.ceil(n_exec / per) * (t1_run - t0_run)
    row = {
        "mode": mode,
        "records": sess.stats.sent,
        "dropped": sess.stats.dropped,
        "p99_overall_s": sess.latency_stats().get("p99", float("nan")),
        "p99_spike_s": _phase_p99("spike"),
        "p99_low_s": _phase_p99("low"),
        "executor_seconds": exec_secs,
        "node_seconds": round(float(node_secs), 6),
        "node_cost": round(float(node_secs)
                           * DEFAULT_CATALOG[NODE_CLASS].cost_rate, 6),
        "executors_configured": n_exec,
        "executors_peak_observed": exec_peak,
    }
    if elastic and sess.controller is not None:
        row["controller_actions"] = sess.controller.summary()["actions"]
    return row


def main(smoke: bool = False, wall: bool = False, seed: int = 0,
         trace_path: str | None = None,
         latency_trace_path: str | None = None) -> dict:
    rows = []
    for m in ("static_low", "static_peak", "elastic"):
        if wall:
            rows.append(_run_mode_wall(m, smoke))
        else:
            row, trace = _run_mode_virtual(
                m, smoke, seed, record_latency=bool(latency_trace_path))
            rows.append(row)
            if m == "elastic" and trace_path:
                Path(trace_path).write_text(trace.to_jsonl())
                print(f"# elastic event trace -> {trace_path} "
                      f"(sha256 {trace.digest()[:16]}…)")
            if latency_trace_path:
                # one record-level latency curve PER MODE: the raw material
                # for controller-policy regression sweeps on virtual time
                curve = trace.latency_curve()
                out_path = Path(latency_trace_path)
                path = out_path.with_name(
                    f"{out_path.stem}-{m}{out_path.suffix or '.jsonl'}")
                path.write_text("".join(
                    json.dumps({"t": t, "latency": lat}) + "\n"
                    for t, lat in curve))
                print(f"# {m} record-latency curve ({len(curve)} records) "
                      f"-> {path}")
    by = {r["mode"]: r for r in rows}
    verdict = {
        "target_p99_s": TARGET_P99_S,
        "clock": "wall" if wall else "virtual",
        "seed": None if wall else seed,
        # the headline claims:
        "elastic_holds_target": by["elastic"]["p99_spike_s"] <= TARGET_P99_S,
        "static_low_breaches": by["static_low"]["p99_spike_s"] > TARGET_P99_S,
        "elastic_vs_peak_exec_seconds_ratio": (
            by["elastic"]["executor_seconds"]
            / max(by["static_peak"]["executor_seconds"], 1e-9)),
        # the cloud bill arrives in whole node-seconds, not executor-seconds
        "node_class": NODE_CLASS,
        "elastic_vs_peak_node_seconds_ratio": (
            by["elastic"]["node_seconds"]
            / max(by["static_peak"]["node_seconds"], 1e-9)),
    }
    out = {"rows": rows, "verdict": verdict}
    hdr = ("mode,records,dropped,p99_spike_s,p99_overall_s,"
           "executor_seconds,node_seconds,executors_peak_observed")
    print(hdr)
    for r in rows:
        print(f"{r['mode']},{r['records']},{r['dropped']},"
              f"{r['p99_spike_s']:.3f},{r['p99_overall_s']:.3f},"
              f"{r['executor_seconds']:.1f},{r['node_seconds']:.1f},"
              f"{r['executors_peak_observed']}")
    print(f"verdict: {verdict}")
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="short CI profile (virtual: <2s wall; wall: ~10s/mode)")
    p.add_argument("--wall", action="store_true",
                   help="real-sleep mode (original study; minutes of wall "
                        "time) instead of deterministic virtual time")
    p.add_argument("--seed", type=int, default=0,
                   help="VirtualClock seed (virtual mode only)")
    p.add_argument("--trace", default=None,
                   help="write the elastic run's event trace (jsonl) here "
                        "(virtual mode only)")
    p.add_argument("--latency-trace", default=None,
                   help="write per-mode record-level latency curves "
                        "(PATH-<mode>.jsonl) for controller-policy "
                        "regression sweeps (virtual mode only)")
    p.add_argument("--json", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_elasticity.json"))
    args = p.parse_args()
    t0 = time.time()
    out = main(smoke=args.smoke, wall=args.wall, seed=args.seed,
               trace_path=args.trace, latency_trace_path=args.latency_trace)
    out["wall_seconds"] = round(time.time() - t0, 2)
    Path(args.json).write_text(json.dumps(out, indent=2) + "\n")
    print(f"# results -> {args.json} ({out['wall_seconds']}s wall)")
    if not out["verdict"]["elastic_holds_target"]:
        raise SystemExit("elastic run failed to hold the p99 target")
    if not out["verdict"]["static_low_breaches"]:
        raise SystemExit("static_low unexpectedly held the target — "
                         "the study lost its contrast")
