"""Kernel-layer microbenchmarks (CPU-host: wall time for the portable jnp
paths + host codec; the Pallas kernels are interpret-validated, their TPU
performance is captured structurally in the §Roofline VMEM analysis).

``bench_hotpath`` is the broker→DMD hot-path scoreboard: it times the seed
per-snapshot ``StreamingDMD`` protocol against the batched ``update_batch``
path (counting host↔device transfers and device calls via the instance
counters) and single-record ``encode`` against ``encode_batch``, then
writes ``BENCH_hotpath.json`` at the repo root so the trajectory is tracked
PR over PR.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.dmd import StreamingDMD
from repro.core.records import StreamRecord, encode, decode, encode_batch, \
    decode_batch
from repro.kernels import ref
from repro.models.layers import flash_attention

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_hotpath.json"


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))   # compile/warm
    t0 = time.time()
    for _ in range(reps):
        # block every rep: async backends otherwise queue all reps and only
        # the last one is awaited, under-reporting per-call latency
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6  # us


def bench_attention():
    rng = np.random.RandomState(0)
    B, S, H, D, Kh = 1, 1024, 8, 64, 2
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Kh, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Kh, D), jnp.float32)
    ke, ve = jnp.repeat(k, H // Kh, 2), jnp.repeat(v, H // Kh, 2)
    naive = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                    chunk=256))
    t_naive = _time(naive, q, ke, ve)
    t_flash = _time(flash, q, k, v)
    flops = 4 * B * S * S * H * D
    return [("attention_naive_1k", t_naive, f"{flops/t_naive/1e3:.1f}GF/s"),
            ("attention_flash_jnp_1k", t_flash, f"{flops/t_flash/1e3:.1f}GF/s")]


def bench_gram():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(512, 256), jnp.float32)
    y = jnp.asarray(rng.randn(512, 256), jnp.float32)
    g = jnp.zeros((256, 256), jnp.float32)
    a = jnp.zeros((256, 256), jnp.float32)
    f = jax.jit(lambda x, g: ref.gram_ref(x, g))
    fp = jax.jit(lambda x, y, g, a: ref.gram_pair_ref(x, y, g, a))
    t = _time(f, x, g)
    tp = _time(fp, x, y, g, a)
    flops = 2 * 512 * 256 * 256
    return [("gram_update_512x256", t, f"{flops/t/1e3:.1f}GF/s"),
            ("gram_pair_fused_512x256", tp, f"{2*flops/tp/1e3:.1f}GF/s")]


def bench_codec():
    rng = np.random.RandomState(0)
    payload = rng.randn(4096).astype(np.float32)
    rec = StreamRecord("f", 0, 0, 0, payload)
    out = []
    for comp in ("none", "zstd", "int8", "int8+zstd"):
        blob = encode(rec, compress=comp)
        t0 = time.time()
        n = 200
        for _ in range(n):
            decode(encode(rec, compress=comp))
        us = (time.time() - t0) / n * 1e6
        out.append((f"record_codec_{comp}", us,
                    f"{len(blob)}B/rec {4096*4/len(blob):.1f}x"))
    return out


def bench_ssd():
    rng = np.random.RandomState(0)
    from repro.models.mamba import ssd_chunked
    B, S, H, P, N = 1, 512, 4, 16, 32
    xh = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.randn(B, S, H), jnp.float32)) * 0.1
    A = -jnp.abs(jnp.asarray(rng.randn(H), jnp.float32))
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    f = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    t = _time(f, xh, dt, A, Bm, Cm)
    flops = 2 * B * S * 128 * (N + H * P)  # CB + masked matmul approx
    return [("ssd_chunked_512", t, f"{flops/t/1e3:.1f}GF/s")]


def bench_dmd():
    rng = np.random.RandomState(0)
    sd = StreamingDMD(n_features=128, window=16, rank=4)
    for i in range(20):
        sd.update(rng.randn(128).astype(np.float32))
    t0 = time.time()
    n = 20
    for i in range(n):
        sd.update(rng.randn(128).astype(np.float32))
        sd.eigenvalues()
    us = (time.time() - t0) / n * 1e6
    sb = StreamingDMD(n_features=128, window=16, rank=4)
    batch = [rng.randn(128).astype(np.float32) for _ in range(20)]
    sb.update_batch(batch)        # warm
    sb.eigenvalues()
    t0 = time.time()
    sb.update_batch(batch)
    sb.eigenvalues()
    us_b = (time.time() - t0) / n * 1e6
    return [("streaming_dmd_update+eigs_128", us, "per-snapshot"),
            ("streaming_dmd_batched_128", us_b, "per-snapshot, batch=20")]


def _run_dmd_protocol(snaps, batch: int | None, eigs: bool = True):
    """Run the update(+eigenvalues) protocol; returns (wall_s, counters).

    eigs=False isolates the update path: the full protocol also runs 16x
    fewer eigen-solves in batched mode (one per micro-batch instead of one
    per record), so the update-only numbers are what attribute the win to
    transfer/dispatch batching alone."""
    d = snaps.shape[1]
    sd = StreamingDMD(n_features=d, window=16, rank=4)
    t0 = time.time()
    if batch is None:              # seed protocol: one device round per record
        for s in snaps:
            sd.update(s)
            if eigs:
                sd.eigenvalues()
    else:                          # batched protocol: one round per micro-batch
        for i in range(0, len(snaps), batch):
            sd.update_batch(snaps[i: i + batch])
            if eigs:
                sd.eigenvalues()
    wall = time.time() - t0
    return wall, {"h2d": sd.h2d_transfers, "d2h": sd.d2h_transfers,
                  "device_calls": sd.device_calls}


def bench_hotpath(write_json: bool = True):
    """Batched-vs-unbatched scoreboard for the two hot paths."""
    rng = np.random.RandomState(0)
    d, total, batch = 128, 64, 16
    snaps = rng.randn(total, d).astype(np.float32)
    _run_dmd_protocol(snaps, None)        # warm jit for both protocols
    _run_dmd_protocol(snaps, batch)
    wall_seq, c_seq = _run_dmd_protocol(snaps, None)
    wall_bat, c_bat = _run_dmd_protocol(snaps, batch)
    t_seq = sum(c_seq.values()) - c_seq["device_calls"]
    t_bat = sum(c_bat.values()) - c_bat["device_calls"]
    # update-only: isolates transfer/dispatch batching from the eigen-solve
    # cadence (the full protocol also amortizes eigenvalues() per batch)
    wall_useq, c_useq = _run_dmd_protocol(snaps, None, eigs=False)
    wall_ubat, c_ubat = _run_dmd_protocol(snaps, batch, eigs=False)

    n_rec = 64
    recs = [StreamRecord("vel", 0, 1, s,
                         rng.randn(1024).astype(np.float32))
            for s in range(n_rec)]
    reps = 30
    t0 = time.time()
    for _ in range(reps):
        for r in recs:
            decode(encode(r, compress="int8+zstd"))
    us_single = (time.time() - t0) / reps * 1e6
    t0 = time.time()
    for _ in range(reps):
        decode_batch(encode_batch(recs, compress="int8+zstd"))
    us_batch = (time.time() - t0) / reps * 1e6
    bytes_single = sum(len(encode(r, compress="int8+zstd")) for r in recs)
    bytes_batch = len(encode_batch(recs, compress="int8+zstd"))

    result = {
        "config": {"d": d, "snapshots": total, "dmd_batch": batch,
                   "codec_records": n_rec, "backend": jax.default_backend()},
        "streaming_dmd": {
            "per_snapshot": {"wall_us": wall_seq * 1e6, "transfers": t_seq,
                             **c_seq},
            "batched": {"wall_us": wall_bat * 1e6, "transfers": t_bat,
                        **c_bat},
            "speedup": wall_seq / wall_bat,
            "transfer_ratio": t_seq / max(t_bat, 1),
            # eigen-solve cadence excluded: updates only
            "update_only": {
                "per_snapshot_us": wall_useq * 1e6,
                "batched_us": wall_ubat * 1e6,
                "speedup": wall_useq / wall_ubat,
                "device_calls": [c_useq["device_calls"],
                                 c_ubat["device_calls"]],
                "h2d": [c_useq["h2d"], c_ubat["h2d"]],
            },
        },
        "record_codec": {
            "single_x64_us": us_single,
            "batch_64_us": us_batch,
            "speedup": us_single / us_batch,
            "bytes_single_sum": bytes_single,
            "bytes_batch": bytes_batch,
        },
    }
    if write_json:
        BENCH_JSON.write_text(json.dumps(result, indent=2) + "\n")
    sd = result["streaming_dmd"]
    return [("hotpath_dmd_per_snapshot_64", sd["per_snapshot"]["wall_us"],
             f"{t_seq}xfers/{c_seq['device_calls']}calls"),
            ("hotpath_dmd_batched_64", sd["batched"]["wall_us"],
             f"{t_bat}xfers/{c_bat['device_calls']}calls "
             f"{sd['speedup']:.1f}x"),
            ("hotpath_dmd_update_only_64", sd["update_only"]["batched_us"],
             f"{sd['update_only']['speedup']:.1f}x vs per-snapshot"),
            ("hotpath_codec_single_x64", us_single, f"{bytes_single}B"),
            ("hotpath_codec_batch_64", us_batch,
             f"{bytes_batch}B {us_single/us_batch:.1f}x")]


SECTIONS = {"attention": bench_attention, "gram": bench_gram,
            "ssd": bench_ssd, "codec": bench_codec, "dmd": bench_dmd,
            "hotpath": bench_hotpath}


def main(csv=True, only: str | None = None):
    want = list(SECTIONS) if not only else only.split(",")
    unknown = [n for n in want if n not in SECTIONS]
    if unknown:
        raise SystemExit(f"unknown section(s) {unknown}; "
                         f"choose from: {','.join(SECTIONS)}")
    rows = []
    for name in want:
        rows.extend(SECTIONS[name]())
    if csv:
        print("kernel,us_per_call,derived")
        for name, us, d in rows:
            print(f"{name},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma list of: " + ",".join(SECTIONS))
    main(only=p.parse_args().only)
