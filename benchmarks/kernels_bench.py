"""Kernel-layer microbenchmarks (CPU-host: wall time for the portable jnp
paths + host codec; the Pallas kernels are interpret-validated, their TPU
performance is captured structurally in the §Roofline VMEM analysis).

``bench_hotpath`` is the broker→DMD hot-path scoreboard: it times the seed
per-snapshot ``StreamingDMD`` protocol against the batched ``update_batch``
path (counting host↔device transfers and device calls via the instance
counters) and single-record ``encode`` against ``encode_batch``, then
writes ``BENCH_hotpath.json`` at the repo root so the trajectory is tracked
PR over PR.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.dmd import StreamingDMD, batched_window_dmd, window_dmd
from repro.core.records import StreamRecord, encode, decode, encode_batch, \
    decode_batch
from repro.kernels import ref
from repro.models.layers import flash_attention

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_hotpath.json"
MULTIKEY_JSON = Path(__file__).resolve().parents[1] / "BENCH_multikey.json"


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))   # compile/warm
    t0 = time.time()
    for _ in range(reps):
        # block every rep: async backends otherwise queue all reps and only
        # the last one is awaited, under-reporting per-call latency
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6  # us


def bench_attention():
    rng = np.random.RandomState(0)
    B, S, H, D, Kh = 1, 1024, 8, 64, 2
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Kh, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Kh, D), jnp.float32)
    ke, ve = jnp.repeat(k, H // Kh, 2), jnp.repeat(v, H // Kh, 2)
    naive = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                    chunk=256))
    t_naive = _time(naive, q, ke, ve)
    t_flash = _time(flash, q, k, v)
    flops = 4 * B * S * S * H * D
    return [("attention_naive_1k", t_naive, f"{flops/t_naive/1e3:.1f}GF/s"),
            ("attention_flash_jnp_1k", t_flash, f"{flops/t_flash/1e3:.1f}GF/s")]


def bench_gram():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(512, 256), jnp.float32)
    y = jnp.asarray(rng.randn(512, 256), jnp.float32)
    g = jnp.zeros((256, 256), jnp.float32)
    a = jnp.zeros((256, 256), jnp.float32)
    f = jax.jit(lambda x, g: ref.gram_ref(x, g))
    fp = jax.jit(lambda x, y, g, a: ref.gram_pair_ref(x, y, g, a))
    t = _time(f, x, g)
    tp = _time(fp, x, y, g, a)
    flops = 2 * 512 * 256 * 256
    return [("gram_update_512x256", t, f"{flops/t/1e3:.1f}GF/s"),
            ("gram_pair_fused_512x256", tp, f"{2*flops/tp/1e3:.1f}GF/s")]


def bench_codec():
    rng = np.random.RandomState(0)
    payload = rng.randn(4096).astype(np.float32)
    rec = StreamRecord("f", 0, 0, 0, payload)
    out = []
    for comp in ("none", "zstd", "int8", "int8+zstd"):
        blob = encode(rec, compress=comp)
        t0 = time.time()
        n = 200
        for _ in range(n):
            decode(encode(rec, compress=comp))
        us = (time.time() - t0) / n * 1e6
        out.append((f"record_codec_{comp}", us,
                    f"{len(blob)}B/rec {4096*4/len(blob):.1f}x"))
    return out


def bench_ssd():
    rng = np.random.RandomState(0)
    from repro.models.mamba import ssd_chunked
    B, S, H, P, N = 1, 512, 4, 16, 32
    xh = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.randn(B, S, H), jnp.float32)) * 0.1
    A = -jnp.abs(jnp.asarray(rng.randn(H), jnp.float32))
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    f = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    t = _time(f, xh, dt, A, Bm, Cm)
    flops = 2 * B * S * 128 * (N + H * P)  # CB + masked matmul approx
    return [("ssd_chunked_512", t, f"{flops/t/1e3:.1f}GF/s")]


def bench_dmd():
    rng = np.random.RandomState(0)
    sd = StreamingDMD(n_features=128, window=16, rank=4)
    for i in range(20):
        sd.update(rng.randn(128).astype(np.float32))
    t0 = time.time()
    n = 20
    for i in range(n):
        sd.update(rng.randn(128).astype(np.float32))
        sd.eigenvalues()
    us = (time.time() - t0) / n * 1e6
    sb = StreamingDMD(n_features=128, window=16, rank=4)
    batch = [rng.randn(128).astype(np.float32) for _ in range(20)]
    sb.update_batch(batch)        # warm
    sb.eigenvalues()
    t0 = time.time()
    sb.update_batch(batch)
    sb.eigenvalues()
    us_b = (time.time() - t0) / n * 1e6
    return [("streaming_dmd_update+eigs_128", us, "per-snapshot"),
            ("streaming_dmd_batched_128", us_b, "per-snapshot, batch=20")]


def _run_dmd_protocol(snaps, batch: int | None, eigs: bool = True,
                      donate: bool = True):
    """Run the update(+eigenvalues) protocol; returns (wall_s, counters).

    eigs=False isolates the update path: the full protocol also runs 16x
    fewer eigen-solves in batched mode (one per micro-batch instead of one
    per record), so the update-only numbers are what attribute the win to
    transfer/dispatch batching alone."""
    d = snaps.shape[1]
    sd = StreamingDMD(n_features=d, window=16, rank=4, donate=donate)
    t0 = time.time()
    if batch is None:              # seed protocol: one device round per record
        for s in snaps:
            sd.update(s)
            if eigs:
                sd.eigenvalues()
    else:                          # batched protocol: one round per micro-batch
        for i in range(0, len(snaps), batch):
            sd.update_batch(snaps[i: i + batch])
            if eigs:
                sd.eigenvalues()
    wall = time.time() - t0
    return wall, {"h2d": sd.h2d_transfers, "d2h": sd.d2h_transfers,
                  "device_calls": sd.device_calls}


def bench_hotpath(write_json: bool = True):
    """Batched-vs-unbatched scoreboard for the two hot paths."""
    rng = np.random.RandomState(0)
    d, total, batch = 128, 64, 16
    snaps = rng.randn(total, d).astype(np.float32)
    _run_dmd_protocol(snaps, None)        # warm jit for both protocols
    _run_dmd_protocol(snaps, batch)
    wall_seq, c_seq = _run_dmd_protocol(snaps, None)
    wall_bat, c_bat = _run_dmd_protocol(snaps, batch)
    t_seq = sum(c_seq.values()) - c_seq["device_calls"]
    t_bat = sum(c_bat.values()) - c_bat["device_calls"]
    # update-only: isolates transfer/dispatch batching from the eigen-solve
    # cadence (the full protocol also amortizes eigenvalues() per batch)
    wall_useq, c_useq = _run_dmd_protocol(snaps, None, eigs=False)
    wall_ubat, c_ubat = _run_dmd_protocol(snaps, batch, eigs=False)

    # d=512 update-only: donation + the no-copy block path at the width the
    # paper's field snapshots actually arrive at (512 features/rank)
    d2 = 512
    snaps2 = rng.randn(total, d2).astype(np.float32)
    _run_dmd_protocol(snaps2, None, eigs=False)          # warm
    _run_dmd_protocol(snaps2, batch, eigs=False)
    _run_dmd_protocol(snaps2, batch, eigs=False, donate=False)
    w512_seq, c512_seq = _run_dmd_protocol(snaps2, None, eigs=False)
    w512_bat, c512_bat = _run_dmd_protocol(snaps2, batch, eigs=False)
    w512_nod, _ = _run_dmd_protocol(snaps2, batch, eigs=False, donate=False)

    n_rec = 64
    recs = [StreamRecord("vel", 0, 1, s,
                         rng.randn(1024).astype(np.float32))
            for s in range(n_rec)]
    reps = 30
    t0 = time.time()
    for _ in range(reps):
        for r in recs:
            decode(encode(r, compress="int8+zstd"))
    us_single = (time.time() - t0) / reps * 1e6
    t0 = time.time()
    for _ in range(reps):
        decode_batch(encode_batch(recs, compress="int8+zstd"))
    us_batch = (time.time() - t0) / reps * 1e6
    bytes_single = sum(len(encode(r, compress="int8+zstd")) for r in recs)
    bytes_batch = len(encode_batch(recs, compress="int8+zstd"))

    result = {
        "config": {"d": d, "snapshots": total, "dmd_batch": batch,
                   "codec_records": n_rec, "backend": jax.default_backend()},
        "streaming_dmd": {
            "per_snapshot": {"wall_us": wall_seq * 1e6, "transfers": t_seq,
                             **c_seq},
            "batched": {"wall_us": wall_bat * 1e6, "transfers": t_bat,
                        **c_bat},
            "speedup": wall_seq / wall_bat,
            "transfer_ratio": t_seq / max(t_bat, 1),
            # eigen-solve cadence excluded: updates only
            "update_only": {
                "per_snapshot_us": wall_useq * 1e6,
                "batched_us": wall_ubat * 1e6,
                "speedup": wall_useq / wall_ubat,
                "device_calls": [c_useq["device_calls"],
                                 c_ubat["device_calls"]],
                "h2d": [c_useq["h2d"], c_ubat["h2d"]],
            },
            "update_only_d512": {
                "per_snapshot_us": w512_seq * 1e6,
                "batched_us": w512_bat * 1e6,
                "batched_no_donate_us": w512_nod * 1e6,
                "speedup": w512_seq / w512_bat,
                "device_calls": [c512_seq["device_calls"],
                                 c512_bat["device_calls"]],
                "h2d": [c512_seq["h2d"], c512_bat["h2d"]],
            },
        },
        "record_codec": {
            "single_x64_us": us_single,
            "batch_64_us": us_batch,
            "speedup": us_single / us_batch,
            "bytes_single_sum": bytes_single,
            "bytes_batch": bytes_batch,
        },
    }
    if write_json:
        BENCH_JSON.write_text(json.dumps(result, indent=2) + "\n")
    sd = result["streaming_dmd"]
    return [("hotpath_dmd_per_snapshot_64", sd["per_snapshot"]["wall_us"],
             f"{t_seq}xfers/{c_seq['device_calls']}calls"),
            ("hotpath_dmd_batched_64", sd["batched"]["wall_us"],
             f"{t_bat}xfers/{c_bat['device_calls']}calls "
             f"{sd['speedup']:.1f}x"),
            ("hotpath_dmd_update_only_64", sd["update_only"]["batched_us"],
             f"{sd['update_only']['speedup']:.1f}x vs per-snapshot"),
            ("hotpath_dmd_update_only_d512", sd["update_only_d512"]["batched_us"],
             f"{sd['update_only_d512']['speedup']:.1f}x vs per-snapshot"),
            ("hotpath_codec_single_x64", us_single, f"{bytes_single}B"),
            ("hotpath_codec_batch_64", us_batch,
             f"{bytes_batch}B {us_single/us_batch:.1f}x")]


def bench_multikey(write_json: bool = True):
    """Per-pane ``window_dmd`` loop vs one vmapped ``batched_window_dmd``
    dispatch across k co-fired keys (the BatchAggregate fast path).  Pane
    lengths are ragged on purpose — bucketed padding must still coalesce
    them into O(distinct buckets) device calls, not O(k)."""
    rng = np.random.RandomState(0)
    d, rank = 256, 8
    lens = (8, 10, 12, 16)        # pads to the {8, 16} column buckets
    result = {"config": {"d": d, "rank": rank, "pane_lens": list(lens),
                         "backend": jax.default_backend()}, "k": {}}
    rows = []
    for k in (4, 16, 32):
        panes = [[rng.randn(d).astype(np.float32)
                  for _ in range(lens[i % len(lens)])] for i in range(k)]
        for p in panes:                                  # warm per-bucket jit
            window_dmd(p, rank=rank, n_features=d)
        batched_window_dmd(panes, rank=rank, n_features=d)

        # best-of-N: scheduler noise only ever ADDS time, and it penalizes
        # the short batched dispatch disproportionately
        def _best(fn, trials=7):
            best = float("inf")
            for _ in range(trials):
                t0 = time.perf_counter()
                fn()
                best = min(best, time.perf_counter() - t0)
            return best * 1e6

        us_loop = _best(lambda: [window_dmd(p, rank=rank, n_features=d)
                                 for p in panes])
        us_bat = _best(lambda: batched_window_dmd(panes, rank=rank,
                                                  n_features=d))
        result["k"][str(k)] = {"per_pane_us": us_loop, "batched_us": us_bat,
                               "speedup": us_loop / us_bat}
        rows.append((f"multikey_dmd_k{k}_d{d}", us_bat,
                     f"{us_loop / us_bat:.1f}x vs per-pane loop"))
    if write_json:
        MULTIKEY_JSON.write_text(json.dumps(result, indent=2) + "\n")
    return rows


def _gate_multikey(min_speedup: float = 3.0):
    """CI gate: the batched path must hold >= min_speedup at k >= 16."""
    data = json.loads(MULTIKEY_JSON.read_text())
    speedups = {int(k): v["speedup"] for k, v in data["k"].items()}
    bad = {k: round(s, 2) for k, s in speedups.items()
           if k >= 16 and s < min_speedup}
    if bad:
        raise SystemExit(
            f"multikey gate FAILED: batched speedup < {min_speedup}x at {bad}")
    print(f"# multikey gate OK: " + ", ".join(
        f"k={k}: {s:.1f}x" for k, s in sorted(speedups.items())))


SECTIONS = {"attention": bench_attention, "gram": bench_gram,
            "ssd": bench_ssd, "codec": bench_codec, "dmd": bench_dmd,
            "hotpath": bench_hotpath, "multikey": bench_multikey}


def main(csv=True, only: str | None = None, gate: bool = False):
    want = list(SECTIONS) if not only else only.split(",")
    unknown = [n for n in want if n not in SECTIONS]
    if unknown:
        raise SystemExit(f"unknown section(s) {unknown}; "
                         f"choose from: {','.join(SECTIONS)}")
    rows = []
    for name in want:
        rows.extend(SECTIONS[name]())
    if csv:
        print("kernel,us_per_call,derived")
        for name, us, d in rows:
            print(f"{name},{us:.1f},{d}")
    if gate and "multikey" in want:
        _gate_multikey()
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma list of: " + ",".join(SECTIONS))
    p.add_argument("--gate", action="store_true",
                   help="fail unless batched multikey DMD >= 3x at k >= 16")
    args = p.parse_args()
    main(only=args.only, gate=args.gate)
