"""Kernel-layer microbenchmarks (CPU-host: wall time for the portable jnp
paths + host codec; the Pallas kernels are interpret-validated, their TPU
performance is captured structurally in the §Roofline VMEM analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.dmd import StreamingDMD
from repro.core.records import StreamRecord, encode, decode
from repro.kernels import ref
from repro.models.layers import flash_attention


def _time(fn, *args, reps=5):
    fn(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    return (time.time() - t0) / reps * 1e6  # us


def bench_attention():
    rng = np.random.RandomState(0)
    B, S, H, D, Kh = 1, 1024, 8, 64, 2
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Kh, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Kh, D), jnp.float32)
    ke, ve = jnp.repeat(k, H // Kh, 2), jnp.repeat(v, H // Kh, 2)
    naive = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                    chunk=256))
    t_naive = _time(naive, q, ke, ve)
    t_flash = _time(flash, q, k, v)
    flops = 4 * B * S * S * H * D
    return [("attention_naive_1k", t_naive, f"{flops/t_naive/1e3:.1f}GF/s"),
            ("attention_flash_jnp_1k", t_flash, f"{flops/t_flash/1e3:.1f}GF/s")]


def bench_gram():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(512, 256), jnp.float32)
    g = jnp.zeros((256, 256), jnp.float32)
    f = jax.jit(lambda x, g: ref.gram_ref(x, g))
    t = _time(f, x, g)
    flops = 2 * 512 * 256 * 256
    return [("gram_update_512x256", t, f"{flops/t/1e3:.1f}GF/s")]


def bench_codec():
    rng = np.random.RandomState(0)
    payload = rng.randn(4096).astype(np.float32)
    rec = StreamRecord("f", 0, 0, 0, payload)
    out = []
    for comp in ("none", "zstd", "int8", "int8+zstd"):
        blob = encode(rec, compress=comp)
        t0 = time.time()
        n = 200
        for _ in range(n):
            decode(encode(rec, compress=comp))
        us = (time.time() - t0) / n * 1e6
        out.append((f"record_codec_{comp}", us,
                    f"{len(blob)}B/rec {4096*4/len(blob):.1f}x"))
    return out


def bench_ssd():
    rng = np.random.RandomState(0)
    from repro.models.mamba import ssd_chunked
    B, S, H, P, N = 1, 512, 4, 16, 32
    xh = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.randn(B, S, H), jnp.float32)) * 0.1
    A = -jnp.abs(jnp.asarray(rng.randn(H), jnp.float32))
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    f = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    t = _time(f, xh, dt, A, Bm, Cm)
    flops = 2 * B * S * 128 * (N + H * P)  # CB + masked matmul approx
    return [("ssd_chunked_512", t, f"{flops/t/1e3:.1f}GF/s")]


def bench_dmd():
    rng = np.random.RandomState(0)
    sd = StreamingDMD(n_features=128, window=16, rank=4)
    for i in range(20):
        sd.update(rng.randn(128).astype(np.float32))
    t0 = time.time()
    n = 20
    for i in range(n):
        sd.update(rng.randn(128).astype(np.float32))
        sd.eigenvalues()
    us = (time.time() - t0) / n * 1e6
    return [("streaming_dmd_update+eigs_128", us, "per-snapshot")]


def main(csv=True):
    rows = []
    for fn in (bench_attention, bench_gram, bench_ssd, bench_codec, bench_dmd):
        rows.extend(fn())
    if csv:
        print("kernel,us_per_call,derived")
        for name, us, d in rows:
            print(f"{name},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    main()
