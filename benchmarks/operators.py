"""Operator ordering-contract benchmark — what does the ticket cost?

One producer stream, four executors, a fixed per-record analysis cost, three
pipelines differing ONLY in the work stage's ordering contract:

  ordered     Map(ordering="ordered"): every micro-batch of the stream runs
              under the per-stream ordering ticket on its sticky executor —
              exactly-sequenced, hence serial per stream (the legacy
              AnalysisDAG behavior).
  unordered   Map(ordering="unordered"): the compiled plan has no ordered
              suffix, so the engine spreads the stream's micro-batches
              across ALL executors with no ticket — intra-stream parallel.
  keyed       KeyBy shards records, the work stage stays order-insensitive:
              same parallel dispatch, per-key state consistency.

Runs on deterministic virtual time (seeded VirtualClock), so the measured
contrast is pure scheduling, not machine noise.  The claim under test (CI
gates on it): unordered and keyed stages reach >= 2x the ordered baseline's
intra-stream throughput on a multi-executor run, while the ordered run's
sink sequence stays exactly step-ordered.

  PYTHONPATH=src python benchmarks/operators.py [--seed N] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.runtime.clock import VirtualClock
from repro.workflow import OperatorPipeline, Session, WorkflowConfig

N_RECORDS = 128
WRITE_RATE_HZ = 200.0        # producer steps/s (write window ~0.64 s)
COST_S = 0.02                # simulated analysis cost per record
N_EXECUTORS = 4
MIN_RATIO = 2.0              # the acceptance gate


def _pipeline(mode: str, clock) -> OperatorPipeline:
    def work(key, rec):
        clock.sleep(COST_S)          # simulated per-record analysis
        return rec.step

    pipe = OperatorPipeline()
    if mode == "keyed":
        pipe.key_by("shard", lambda k, rec: f"s{rec.rank % 4}/{k}")
    pipe.map("work", work,
             ordering="ordered" if mode == "ordered" else "unordered")
    pipe.sink("out")
    return pipe


def run_mode(mode: str, seed: int) -> dict:
    clock = VirtualClock(seed=seed)
    clock.attach()
    cfg = WorkflowConfig(n_producers=1, n_groups=1, compress="none",
                         backpressure="block", queue_capacity=4096,
                         trigger_interval=0.02, min_batch=4,
                         n_executors=N_EXECUTORS,
                         clock="virtual", clock_seed=seed)
    sess = Session(cfg, pipeline=_pipeline(mode, clock), clock=clock)
    h = sess.open_field("f", shape=(16,))
    payload = np.zeros(16, np.float32)
    t0 = clock.now()
    for step in range(N_RECORDS):
        h.write(step, payload)
        clock.sleep(1.0 / WRITE_RATE_HZ)
    sess.flush(timeout=300.0)
    sess.close()
    dur = clock.now() - t0
    out = sess.exec_plan.results("out")
    steps = [v for _k, v, _t in out]
    m = sess.engine.metrics()
    return {
        "mode": mode,
        "records": len(out),
        "virtual_duration_s": round(dur, 6),
        "throughput_rps": round(len(out) / dur, 3),
        "serial_floor_s": N_RECORDS * COST_S,
        "executors": N_EXECUTORS,
        "plan_contract": sess.exec_plan.contract,
        "order_timeouts": m["order_timeouts"],
        # only meaningful for the ordered run (single stream, single key):
        "sink_seq_exact": steps == sorted(steps),
    }


def main(seed: int = 0) -> dict:
    rows = [run_mode(m, seed) for m in ("ordered", "unordered", "keyed")]
    by = {r["mode"]: r for r in rows}
    verdict = {
        "seed": seed,
        "unordered_vs_ordered": round(
            by["unordered"]["throughput_rps"]
            / max(by["ordered"]["throughput_rps"], 1e-9), 3),
        "keyed_vs_ordered": round(
            by["keyed"]["throughput_rps"]
            / max(by["ordered"]["throughput_rps"], 1e-9), 3),
        "min_ratio": MIN_RATIO,
        "ordered_seq_exact": by["ordered"]["sink_seq_exact"],
        "records_complete": all(r["records"] == N_RECORDS for r in rows),
    }
    print("mode,records,virtual_s,throughput_rps,contract")
    for r in rows:
        print(f"{r['mode']},{r['records']},{r['virtual_duration_s']:.3f},"
              f"{r['throughput_rps']:.1f},{r['plan_contract']}")
    print(f"verdict: {verdict}")
    return {"rows": rows, "verdict": verdict}


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_operators.json"))
    args = p.parse_args()
    t0 = time.time()
    out = main(seed=args.seed)
    out["wall_seconds"] = round(time.time() - t0, 2)
    Path(args.json).write_text(json.dumps(out, indent=2) + "\n")
    print(f"# results -> {args.json} ({out['wall_seconds']}s wall)")
    v = out["verdict"]
    if not v["records_complete"]:
        raise SystemExit("lost records — the contracts must not drop work")
    if not v["ordered_seq_exact"]:
        raise SystemExit("ordered contract broke per-stream sequencing")
    if min(v["unordered_vs_ordered"], v["keyed_vs_ordered"]) < MIN_RATIO:
        raise SystemExit(
            f"intra-stream parallel speedup below {MIN_RATIO}x: "
            f"unordered {v['unordered_vs_ordered']}x, "
            f"keyed {v['keyed_vs_ordered']}x")
