"""Provisioning study — does cold-start-aware predictive provisioning beat
reactive scaling when capacity takes seconds to boot?

Per seed, two runs of the same seeded workload (ramp into a spike, then a
quiet tail), both driving the CloudProvisioner (``elasticity.provision``):

  reactive     LatencyScalePolicy only: capacity is requested when the
               backlog/p99 breach has already landed — the node-class cold
               start then puts the new executors seconds behind the spike.
  predictive   TrendScalePolicy in front (``predictive=True``): the
               controller floors its projection horizon at the node-class
               cold start + margin, so capacity is requested while the
               breach is still a projection and is READY when the spike
               arrives.

The gate, per seed:

  * predictive holds the p99 generation→analysis QoS target through the
    spike; reactive (same workload, same catalog) breaches it;
  * zero loss in BOTH runs (analyzed == written, nothing dropped) — the
    quiet tail scales back in through drain-before-poweroff;
  * both cost ledgers close: every node that ever powered on has a
    complete power_on→power_off billing record.

The emitted JSON puts the node-seconds bill next to the p99, including an
analytic "static at peak fleet" baseline — the paper's elasticity pitch in
one table: predictive pays a small node-seconds premium over reactive for
a p99 that actually meets the target, and both pay far less than static
peak provisioning.

CI runs this twice and byte-compares the traces (run-twice determinism).

  PYTHONPATH=src python benchmarks/provisioning.py
      [--seeds 0,1,2] [--trace PATH] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import math
import time
from pathlib import Path

from repro.cloud import DEFAULT_CATALOG
from repro.sim.scenario import LoadPhase, Scenario, run_scenario
from repro.workflow import ElasticityConfig, WorkflowConfig

N_RANKS = 4
ANALYZE_COST_S = 0.02          # simulated work per record
TARGET_P99_S = 1.0             # the QoS contract (paper §4.3 framing)
NODE_CLASS = "standard"        # 2 executors, 1.2s + U(0,0.4s) cold start

# capacity (1 executor @ 50 rec/s) saturates at rate_hz = 12.5: the ramp
# crosses it at "ramp2", giving the trend policy a rising-backlog series
# to project while the reactive policy still sees no breach
PHASES = (LoadPhase("low", 2.0, 4.0),
          LoadPhase("ramp1", 1.5, 8.0),
          LoadPhase("ramp2", 1.5, 12.0),
          LoadPhase("ramp3", 1.5, 16.0),
          LoadPhase("spike", 4.0, 22.0),
          LoadPhase("quiet", 5.0, 0.0))   # idle window: scale back in


def _workflow(predictive: bool) -> WorkflowConfig:
    return WorkflowConfig(
        n_producers=N_RANKS, n_groups=2, executors_per_group=1,
        compress="none", backpressure="block", queue_capacity=8192,
        trigger_interval=0.05, min_batch=1, n_executors=1,
        flush_timeout_s=120.0, clock="virtual",
        elasticity=ElasticityConfig(
            enabled=True, interval_s=0.1, target_p99_s=TARGET_P99_S,
            min_executors=1, max_executors=5, scale_up_step=2,
            backlog_high=24, idle_scale_down_s=1.0, cooldown_s=0.3,
            adapt_batch=False, heartbeat_timeout_s=2.0,
            predictive=predictive, trend_window=6, trend_horizon_s=0.5,
            provision=True, node_class=NODE_CLASS,
            cold_start_margin_s=0.5))


def _static_peak_node_seconds(peak_nodes: int, duration_s: float) -> dict:
    """What a fixed fleet sized for the peak would bill for the whole run."""
    cls = DEFAULT_CATALOG[NODE_CLASS]
    ns = round(peak_nodes * duration_s, 9)
    return {"nodes": peak_nodes, "node_seconds": ns,
            "cost": round(ns * cls.cost_rate, 9)}


def _run(seed: int, predictive: bool):
    sc = Scenario(workflow=_workflow(predictive), phases=PHASES, seed=seed,
                  analysis_cost_s=ANALYZE_COST_S)
    return run_scenario(sc)


def _mode_row(tr) -> dict:
    s = tr.summary
    prov = s["provisioning"]
    return {
        "spike_p99_s": round(tr.phase_p99("spike"), 6),
        "written": s["written"],
        "analyzed": s["analyzed"],
        "dropped_by_policy": s["dropped_by_policy"],
        "provisions": s["controller_actions"].get("provision", 0),
        "drains": s["controller_actions"].get("drain_node", 0),
        "nodes_ready": prov["nodes_ready"],
        "nodes_off": prov["nodes_off"],
        "ledger_closed": prov["ledger"]["closed"],
        "node_seconds": prov["ledger"]["node_seconds"],
        "total_node_seconds": prov["ledger"]["total_node_seconds"],
        "node_cost": prov["ledger"]["total_cost"],
    }


def main(seeds: list[int], trace_path: str | None = None) -> dict:
    duration = sum(p.duration_s for p in PHASES)
    rows, traces = [], []
    for seed in seeds:
        reactive = _run(seed, predictive=False)
        predictive = _run(seed, predictive=True)
        traces.append((seed, reactive, predictive))
        ra, pr = _mode_row(reactive), _mode_row(predictive)
        peak_nodes = max(
            math.ceil(m["nodes_ready"]) for m in (ra, pr)) or 1
        row = {
            "seed": seed,
            "reactive": ra,
            "predictive": pr,
            "static_peak": _static_peak_node_seconds(peak_nodes, duration),
            "predictive_holds": pr["spike_p99_s"] <= TARGET_P99_S,
            "reactive_breaches": ra["spike_p99_s"] > TARGET_P99_S,
            "zero_loss": all(m["analyzed"] == m["written"]
                             and m["dropped_by_policy"] == 0
                             for m in (ra, pr)),
            "ledgers_closed": ra["ledger_closed"] and pr["ledger_closed"],
        }
        rows.append(row)
    if trace_path:
        # one concatenated jsonl across seeds and modes, so CI's run-twice
        # determinism gate is a single byte-for-byte cmp
        with Path(trace_path).open("w") as fh:
            for seed, ra_tr, pr_tr in traces:
                for mode, tr in (("reactive", ra_tr), ("predictive", pr_tr)):
                    fh.write(json.dumps({"seed": seed, "mode": mode,
                                         "digest": tr.digest()}) + "\n")
                    fh.write(tr.to_jsonl())
        print(f"# provisioning event traces -> {trace_path}")
    verdict = {
        "seeds": seeds,
        "target_p99_s": TARGET_P99_S,
        "cold_start_beats_reactive": all(
            r["predictive_holds"] and r["reactive_breaches"] for r in rows),
        "zero_loss": all(r["zero_loss"] for r in rows),
        "ledgers_closed": all(r["ledgers_closed"] for r in rows),
        "scale_in_exercised": all(
            r["predictive"]["drains"] >= 1 for r in rows),
    }
    print("seed,mode,spike_p99_s,provisions,drains,node_seconds,node_cost,"
          "ledger_closed")
    for r in rows:
        for mode in ("reactive", "predictive"):
            m = r[mode]
            print(f"{r['seed']},{mode},{m['spike_p99_s']},{m['provisions']},"
                  f"{m['drains']},{m['total_node_seconds']},"
                  f"{m['node_cost']},{m['ledger_closed']}")
        sp = r["static_peak"]
        print(f"{r['seed']},static_peak,-,-,-,{sp['node_seconds']},"
              f"{sp['cost']},-")
    print(f"verdict: {verdict}")
    return {"rows": rows, "verdict": verdict}


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--seeds", default="0,1,2",
                   help="comma-separated VirtualClock seeds")
    p.add_argument("--trace", default=None,
                   help="write both modes' event traces (jsonl) here")
    p.add_argument("--json", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_provisioning.json"))
    args = p.parse_args()
    t0 = time.time()
    out = main([int(s) for s in args.seeds.split(",")],
               trace_path=args.trace)
    out["wall_seconds"] = round(time.time() - t0, 2)
    Path(args.json).write_text(json.dumps(out, indent=2) + "\n")
    print(f"# results -> {args.json} ({out['wall_seconds']}s wall)")
    v = out["verdict"]
    if not v["cold_start_beats_reactive"]:
        raise SystemExit("provisioning gate FAILED: predictive did not hold "
                         "the p99 target that reactive breaches")
    if not (v["zero_loss"] and v["ledgers_closed"]):
        raise SystemExit("provisioning gate FAILED: records were lost or a "
                         "node escaped its billing record")
    if not v["scale_in_exercised"]:
        raise SystemExit("provisioning gate FAILED: the quiet tail never "
                         "drained a node (scale-in path unexercised)")
