"""Exactly-once delivery under chaos: WAL-backed replay across endpoint
failover, broker restarts, and whole-session kill/restore — gated on the
two oracles the paper's realtime-insight story needs: the loss ledger
closes (nothing silently vanishes) and the sink contents are byte-identical
to a fault-free same-seed run (nothing is double-applied either)."""
import numpy as np
import pytest

from repro.checkpoint.session_store import SessionCheckpointStore
from repro.runtime.wal import WalStore
from repro.sim.scenario import (Fault, LoadPhase, Scenario, run_scenario,
                                sink_digest)
from repro.streaming.operators import OperatorPipeline
from repro.workflow import ElasticityConfig, WorkflowConfig
from repro.workflow.session import Session

SEEDS = [0, 1, 2]


def _wf(elastic=False, **kw):
    el = ElasticityConfig(enabled=elastic, interval_s=0.1,
                          heartbeat_timeout_s=0.5, min_executors=1,
                          max_executors=4, cooldown_s=0.3)
    base = dict(n_producers=4, n_groups=2, executors_per_group=2,
                compress="none", backpressure="block", queue_capacity=4096,
                trigger_interval=0.05, min_batch=4, n_executors=2,
                max_batch_records=8, delivery="exactly-once",
                clock="virtual", flush_timeout_s=60.0, elasticity=el)
    base.update(kw)
    return WorkflowConfig(**base)


def _pipe():
    return (OperatorPipeline()
            .map("norm", lambda k, rec: (rec.step,
                 round(float(np.asarray(rec.payload,
                                        dtype=np.float64).sum()), 6)))
            .key_by("bygroup", lambda k, v: k.split("/")[1])
            .tumbling_window("win", 0.5, allowed_lateness_s=1.0)
            .aggregate("agg", lambda k, vals: sorted(vals))
            .sink("out"))


PHASES = (LoadPhase("steady", 2.0, 20.0), LoadPhase("drain", 2.5, 0.0))


def _assert_exact(trace):
    s = trace.summary
    assert s["analyzed"] == s["written"] - s["dropped_by_policy"] \
        - s["records_dropped_injected"]
    assert s["order_timeouts"] == 0
    assert s["windows"]["closed"]


def _baseline(seed):
    return run_scenario(Scenario(workflow=_wf(), phases=PHASES, seed=seed,
                                 operators=_pipe))


# ------------------------------------------------------------- config gates
def test_exactly_once_config_constraints():
    with pytest.raises(ValueError, match="backpressure"):
        WorkflowConfig(delivery="exactly-once",
                       backpressure="drop_oldest").validate()
    with pytest.raises(ValueError, match="delta_encode"):
        WorkflowConfig(delivery="exactly-once", backpressure="block",
                       delta_encode=True).validate()
    with pytest.raises(ValueError, match="delivery"):
        WorkflowConfig(delivery="at-least-once").validate()
    with pytest.raises(ValueError, match="wal_capacity_bytes"):
        WorkflowConfig(wal_capacity_bytes=16).validate()


def test_scenario_kill_faults_require_exactly_once():
    amo = _wf(delivery="at-most-once")
    with pytest.raises(ValueError, match="exactly-once"):
        Scenario(workflow=amo, faults=(Fault(t=1, kind="kill_broker"),),
                 operators=_pipe).validate()
    with pytest.raises(ValueError, match="exactly-once"):
        Scenario(workflow=amo, checkpoint_every_s=1.0,
                 operators=_pipe).validate()
    with pytest.raises(ValueError, match="operators"):
        Scenario(workflow=_wf(),
                 faults=(Fault(t=1, kind="kill_session"),)).validate()


def test_broker_wal_requires_exactly_once():
    from repro.core.broker import Broker, BrokerConfig
    from repro.core.grouping import GroupPlan
    from repro.streaming.endpoint import make_endpoints
    eps = make_endpoints(1)
    with pytest.raises(ValueError, match="exactly-once"):
        Broker(GroupPlan(n_producers=1, n_groups=1, executors_per_group=1),
               eps, BrokerConfig(), wal=WalStore())


# ---------------------------------------------- abandonment is never silent
def test_retry_exhaustion_warns_and_counts_frames_abandoned():
    """At-most-once keeps its drop semantics, but dropping a frame at retry
    exhaustion now raises a RuntimeWarning and bumps frames_abandoned."""
    cfg = _wf(delivery="at-most-once", flush_timeout_s=0.5, retry_limit=2)
    with pytest.warns(RuntimeWarning, match="abandon"):
        with Session(cfg, analyze=lambda k, r: None) as sess:
            for ep in sess.endpoints:
                ep.handle.fail()
            h = sess.open_field("f", shape=(4,))
            h.write_batch(0, [np.zeros(4, dtype=np.float32)] * 4,
                          ranks=[0, 1, 2, 3])
    st = sess.stats
    assert st.frames_abandoned >= 1
    assert st.dropped >= 1                    # still counted as dropped


# -------------------------------------------------- virtual-time loopback
def test_virtual_clock_loopback_transport_validates_and_delivers():
    """PR-4's inprocess-only guard is gone: clock='virtual' now composes
    with transport='loopback' via VirtualLoopbackTransport."""
    cfg = _wf(transport="loopback")
    cfg.validate()                            # formerly raised ValueError
    seen = []
    with Session(cfg, analyze=lambda k, r: seen.append(len(r))) as sess:
        h = sess.open_field("f", shape=(4,))
        for s in range(12):
            h.write_batch(s, [np.full(4, s, dtype=np.float32)] * 4,
                          ranks=[0, 1, 2, 3])
        sess.flush(timeout=30.0)
    assert sum(seen) == 48
    assert sess.stats.sent == 48


def test_virtual_loopback_scenario_matches_inprocess_digest():
    t_in = _baseline(0)
    t_lb = run_scenario(Scenario(workflow=_wf(transport="loopback"),
                                 phases=PHASES, seed=0, operators=_pipe))
    _assert_exact(t_lb)
    assert t_lb.summary["sink_digest"] == t_in.summary["sink_digest"]


# --------------------------------------------------------- broker restart
@pytest.mark.parametrize("seed", SEEDS)
def test_kill_broker_replays_wal_tail(seed):
    trace = run_scenario(Scenario(
        workflow=_wf(), phases=PHASES, seed=seed, operators=_pipe,
        faults=(Fault(t=0.7, kind="kill_broker"),
                Fault(t=1.4, kind="kill_broker"))))
    _assert_exact(trace)
    s = trace.summary
    assert s["sink_digest"] == _baseline(seed).summary["sink_digest"]
    assert all(d["ok"] for _, d in trace.events_of("fault"))


# ------------------------------------------------- session kill + restore
@pytest.mark.parametrize("seed", SEEDS)
def test_kill_session_restores_from_checkpoint(seed):
    trace = run_scenario(Scenario(
        workflow=_wf(), phases=PHASES, seed=seed, operators=_pipe,
        checkpoint_every_s=0.6,
        faults=(Fault(t=1.5, kind="kill_session"),)))
    _assert_exact(trace)
    s = trace.summary
    assert s["recovery"]["session_restores"] == 1
    assert s["recovery"]["checkpoints"] >= 1
    assert s["sink_digest"] == _baseline(seed).summary["sink_digest"]


def test_kill_session_without_any_checkpoint_replays_everything():
    """Crash before the first checkpoint: restore starts from genesis and
    the whole WAL replays (retain='commit' holds even acked entries)."""
    trace = run_scenario(Scenario(
        workflow=_wf(), phases=PHASES, seed=0, operators=_pipe,
        faults=(Fault(t=0.4, kind="kill_session"),)))
    _assert_exact(trace)
    s = trace.summary
    assert s["recovery"]["session_restores"] == 1
    assert s["recovery"]["records_replayed"] > 0
    assert s["sink_digest"] == _baseline(0).summary["sink_digest"]


# --------------------------------------------------- the kill-anything gate
def _kill_anything(seed):
    return Scenario(
        workflow=_wf(elastic=True), phases=PHASES, seed=seed,
        operators=_pipe, checkpoint_every_s=0.6,
        faults=(Fault(t=0.45, kind="kill_executor", target=1),
                Fault(t=0.65, kind="kill_broker"),      # mid-window
                Fault(t=0.95, kind="fail_endpoint", target=0),
                Fault(t=1.25, kind="kill_session"),     # mid-checkpoint zone
                Fault(t=1.8, kind="kill_executor", target=0),
                Fault(t=2.1, kind="kill_broker")))


@pytest.mark.parametrize("seed", SEEDS)
def test_kill_anything_is_exactly_once(seed):
    """The PR gate: kill an executor, the broker (twice), an endpoint, and
    the whole session mid-run — the loss ledger still closes and the sink
    contents are byte-identical to the fault-free same-seed run."""
    trace = run_scenario(_kill_anything(seed))
    _assert_exact(trace)
    s = trace.summary
    assert s["dropped_by_policy"] == 0
    assert s["analyzed"] == s["written"]
    assert s["recovery"]["frames_abandoned"] == 0
    assert s["recovery"]["session_restores"] == 1
    assert s["sink_digest"] == _baseline(seed).summary["sink_digest"]


def test_kill_anything_replays_deterministically():
    a = run_scenario(_kill_anything(1))
    b = run_scenario(_kill_anything(1))
    assert a.digest() == b.digest()


# ----------------------------------- injected silent drops stay accounted
@pytest.mark.parametrize("seed", SEEDS)
def test_injected_drop_consumes_seqs_instead_of_resurrecting(seed):
    """drop_frames eats delivered frames AFTER the endpoint acked them.
    Exactly-once must not 'heal' that audited loss on a later replay — the
    endpoint marks the seqs consumed, so the ledger stays closed with the
    drop visible, and a broker restart does not resurrect the records."""
    trace = run_scenario(Scenario(
        workflow=_wf(), phases=PHASES, seed=seed, operators=_pipe,
        faults=(Fault(t=0.6, kind="drop_frames", target=0, value=2),
                Fault(t=0.61, kind="drop_frames", target=1, value=2),
                Fault(t=1.2, kind="kill_broker"))))
    _assert_exact(trace)
    s = trace.summary
    assert s["records_dropped_injected"] > 0
    assert s["analyzed"] == s["written"] - s["records_dropped_injected"]


# -------------------------------------------- direct Session-level restore
def test_session_checkpoint_restore_roundtrip(tmp_path):
    cfg = _wf()
    store = SessionCheckpointStore(tmp_path / "ckpts")
    wal = WalStore(capacity_bytes=cfg.wal_capacity_bytes,
                   queue_capacity=cfg.queue_capacity, retain="commit")

    def feed(sess, lo, hi):
        h = sess.open_field("f", shape=(8,))
        for s in range(lo, hi):
            h.write_batch(s, [np.full(8, s, dtype=np.float32)] * 4,
                          ranks=[0, 1, 2, 3], t=s * 0.05)
            sess.clock.sleep(0.05)

    sess = Session(cfg, pipeline=_pipe(), wal=wal, checkpoints=store)
    feed(sess, 0, 30)
    cid = sess.checkpoint(timeout=60.0)
    assert cid == 1
    feed(sess, 30, 45)
    sess.kill()                                # post-checkpoint tail in WAL

    sess2 = Session.restore(cfg, checkpoints=store, wal=wal,
                            pipeline=_pipe())
    feed(sess2, 45, 60)
    sess2.clock.sleep(2.0)                     # let trailing windows close
    sess2.flush(timeout=60.0)
    sess2.close()
    st = sess2.stats
    assert st.written == 240
    assert st.records_replayed > 0

    # oracle: one uninterrupted run over the same schedule
    ref = Session(cfg, pipeline=_pipe())
    feed(ref, 0, 60)
    ref.clock.sleep(2.0)
    ref.flush(timeout=60.0)
    ref.close()
    assert sink_digest(sess2.exec_plan) == sink_digest(ref.exec_plan)
    analyzed = sum(r.n_records for r in sess2.results())
    assert analyzed == 240


def test_restore_without_config_or_checkpoint_raises(tmp_path):
    store = SessionCheckpointStore(tmp_path / "empty")
    with pytest.raises(ValueError, match="no checkpoint and no config"):
        Session.restore(checkpoints=store, wal=WalStore(retain="commit"),
                        pipeline=_pipe())
