"""Seeded chaos scenarios on virtual time: executor kill mid-batch, endpoint
death during a spike, straggler injection — asserting per-stream sequence
ordering, closed loss accounting (nothing vanishes beyond the configured
drop policy + injected transport loss), and bounded-virtual-time controller
scale-up.  Each family runs over >= 3 seeds; every run is milliseconds of
wall time and byte-replayable from its seed."""
import pytest

from repro.sim.scenario import (Fault, LoadPhase, Scenario, ScenarioRunner,
                                run_scenario)
from repro.workflow import ElasticityConfig, WorkflowConfig

SEEDS = [0, 1, 2]


def _wf(n_executors=2, elastic=False, backpressure="block", **el_kw):
    el = dict(enabled=elastic, interval_s=0.1, target_p99_s=1.5,
              min_executors=1, max_executors=4, scale_up_step=2,
              backlog_high=24, idle_scale_down_s=1.0, cooldown_s=0.3)
    el.update(el_kw)
    return WorkflowConfig(
        n_producers=4, n_groups=2, executors_per_group=2,
        compress="none", backpressure=backpressure, queue_capacity=4096,
        trigger_interval=0.05, min_batch=4, n_executors=n_executors,
        max_batch_records=8,
        elasticity=ElasticityConfig(**el))


def _assert_ordered(trace):
    for key, steps in trace.per_stream_steps().items():
        assert steps == sorted(steps), f"stream {key} analyzed out of order"


def _assert_loss_closed(trace):
    """Every record is accounted for: analyzed + policy drops + injected
    transport loss == written.  No silent loss."""
    s = trace.summary
    assert s["analyzed"] == (s["written"] - s["dropped_by_policy"]
                             - s["records_dropped_injected"])
    assert s["order_timeouts"] == 0


# ----------------------------------------------------- executor kill mid-batch
@pytest.mark.parametrize("seed", SEEDS)
def test_executor_kill_mid_spike_keeps_order_and_records(seed):
    sc = Scenario(
        workflow=_wf(n_executors=3),
        phases=(LoadPhase("warm", 0.5, 10.0), LoadPhase("spike", 2.0, 50.0),
                LoadPhase("cool", 0.5, 5.0)),
        faults=(Fault(t=0.9, kind="kill_executor", target=0),
                Fault(t=1.4, kind="kill_executor", target=1)),
        seed=seed, analysis_cost_s=0.004)
    trace = run_scenario(sc)
    _assert_ordered(trace)
    _assert_loss_closed(trace)
    s = trace.summary
    assert s["dropped_by_policy"] == 0 and s["records_dropped_injected"] == 0
    assert s["analyzed"] == s["written"]   # survivors absorbed everything
    kills = [d for _, d in trace.events_of("fault")
             if d["fault"] == "kill_executor"]
    assert len(kills) == 2 and all(k["ok"] for k in kills)


# ----------------------------------------------- endpoint death during a spike
@pytest.mark.parametrize("seed", SEEDS)
def test_endpoint_death_during_spike_reroutes_without_loss(seed):
    sc = Scenario(
        workflow=_wf(n_executors=2, elastic=True, heartbeat_timeout_s=0.3),
        phases=(LoadPhase("warm", 0.5, 10.0), LoadPhase("spike", 2.0, 50.0),
                LoadPhase("cool", 1.0, 5.0)),
        faults=(Fault(t=1.0, kind="fail_endpoint", target=0),),
        seed=seed, analysis_cost_s=0.002)
    trace = run_scenario(sc)
    _assert_ordered(trace)
    _assert_loss_closed(trace)
    s = trace.summary
    # block backpressure + a healthy survivor: nothing may drop
    assert s["dropped_by_policy"] == 0
    assert s["analyzed"] == s["written"]
    assert s["rerouted"] >= 1, "group never moved off the dead endpoint"
    # the detector-driven proactive path fired (not just send-path retries)
    actions = [d["kind"] for _, d in trace.events_of("action")]
    assert "reroute_endpoint" in actions


# ------------------------------------------------------- straggler injection
@pytest.mark.parametrize("seed", SEEDS)
def test_straggler_injection_is_detected_and_replaced(seed):
    sc = Scenario(
        workflow=_wf(n_executors=3, elastic=True, heartbeat_timeout_s=10.0,
                     straggler_factor=2.5, target_p99_s=3600,
                     backlog_high=100_000, idle_scale_down_s=3600),
        phases=(LoadPhase("steady", 6.0, 25.0),),
        faults=(Fault(t=0.5, kind="inject_straggler", target=0, value=0.5),),
        seed=seed, analysis_cost_s=0.01)
    trace = run_scenario(sc)
    _assert_ordered(trace)
    _assert_loss_closed(trace)
    actions = [d["kind"] for _, d in trace.events_of("action")]
    assert "replace_executor" in actions, \
        "controller never replaced the injected straggler"
    assert trace.summary["analyzed"] == trace.summary["written"]


# ------------------------------------------- controller scale-up latency bound
@pytest.mark.parametrize("seed", SEEDS)
def test_scale_up_lands_within_bounded_virtual_seconds(seed):
    sc = Scenario(
        workflow=_wf(n_executors=1, elastic=True),
        phases=(LoadPhase("low", 1.0, 5.0), LoadPhase("spike", 3.0, 60.0),
                LoadPhase("low", 1.0, 5.0)),
        seed=seed, analysis_cost_s=0.008)
    trace = run_scenario(sc)
    _assert_ordered(trace)
    _assert_loss_closed(trace)
    spike_t0 = next(t0 for name, t0, _ in trace.phase_windows
                    if name == "spike")
    scale_ups = [t for t, d in trace.events_of("action")
                 if d["kind"] == "scale_up"]
    assert scale_ups, "spike never triggered a scale-up"
    # detection→actuation bound: within 1.0 virtual second of spike onset
    # (controller interval 0.1s + backlog accumulation to the threshold)
    assert min(scale_ups) - spike_t0 <= 1.0
    assert trace.summary["executors_peak"] >= 3


# ---------------------------------------------- injected frame loss is audited
@pytest.mark.parametrize("seed", SEEDS)
def test_dropped_frames_accounted_not_silent(seed):
    sc = Scenario(
        workflow=_wf(n_executors=2),
        phases=(LoadPhase("steady", 2.0, 30.0),),
        faults=(Fault(t=0.7, kind="drop_frames", target=0, value=3),
                Fault(t=1.2, kind="drop_frames", target=1, value=2)),
        seed=seed, analysis_cost_s=0.002)
    trace = run_scenario(sc)
    _assert_ordered(trace)
    _assert_loss_closed(trace)           # loss == exactly the injected drops
    s = trace.summary
    assert s["frames_dropped_injected"] == 5
    assert s["records_dropped_injected"] > 0
    assert s["analyzed"] == s["written"] - s["records_dropped_injected"]


# ------------------------------------------------------- replay determinism
@pytest.mark.parametrize("seed", SEEDS)
def test_same_seed_replays_byte_identical(seed):
    sc = Scenario(
        workflow=_wf(n_executors=2, elastic=True),
        phases=(LoadPhase("low", 0.5, 10.0), LoadPhase("spike", 1.5, 60.0)),
        faults=(Fault(t=0.8, kind="kill_executor", target=1),
                Fault(t=1.0, kind="drop_frames", target=0, value=1)),
        seed=seed, analysis_cost_s=0.005)
    t1, t2 = run_scenario(sc), run_scenario(sc)
    assert t1.digest() == t2.digest()
    assert t1.to_jsonl() == t2.to_jsonl()


def test_different_seeds_may_differ_but_all_hold_invariants():
    digests = set()
    for seed in range(5):
        sc = Scenario(
            workflow=_wf(n_executors=2),
            phases=(LoadPhase("steady", 1.0, 40.0),),
            faults=(Fault(t=0.5, kind="kill_executor", target=0),),
            seed=seed, analysis_cost_s=0.003)
        trace = run_scenario(sc)
        _assert_ordered(trace)
        _assert_loss_closed(trace)
        digests.add(trace.digest())
    # seeds explore interleavings; at least some must differ
    assert len(digests) > 1


def test_scenario_validation_rejects_bad_plans():
    with pytest.raises(ValueError, match="unknown fault kind"):
        ScenarioRunner(Scenario(workflow=_wf(),
                                faults=(Fault(t=0, kind="meteor"),)))
    with pytest.raises(ValueError, match="bad phase"):
        ScenarioRunner(Scenario(workflow=_wf(),
                                phases=(LoadPhase("p", -1.0, 5.0),)))
    with pytest.raises(ValueError, match="fault time"):
        ScenarioRunner(Scenario(workflow=_wf(),
                                faults=(Fault(t=-1, kind="add_executor"),)))
