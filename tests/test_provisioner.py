"""Cloud capacity plane: NodeClass catalog, CostLedger, CloudProvisioner
lifecycle (pending → booting → ready → draining → off), retry/backoff and
recover, fault injection, and the full Session integration — dynamic
endpoint attach, drain-before-poweroff with zero loss, and deterministic
provisioning scenarios under VirtualClock."""
import pytest

from repro.cloud import (DEFAULT_CATALOG, BOOTING, DRAINING, FAILED, OFF,
                         PENDING, READY, CloudProvisioner, CostLedger,
                         NodeClass)
from repro.runtime.clock import VirtualClock
from repro.runtime.controller import ElasticityConfig
from repro.sim.scenario import Fault, LoadPhase, Scenario, run_scenario
from repro.workflow import WorkflowConfig


class FakeFabric:
    """Records lifecycle calls; drain completion is test-controlled."""

    def __init__(self):
        self.attached = []
        self.drains = []
        self.offs = []
        self.drained_ids = set()

    def attach_node(self, node):
        self.attached.append(node)
        return len(self.attached) - 1, list(range(node.node_class.executors))

    def begin_drain(self, node):
        self.drains.append(node)

    def node_drained(self, node):
        return node.node_id in self.drained_ids

    def finish_poweroff(self, node):
        self.offs.append(node)


def _prov(clk, *, catalog=None, seed=0, retry_limit=3, backoff_s=0.5):
    fab = FakeFabric()
    prov = CloudProvisioner(fab, catalog=catalog, clock=clk, seed=seed,
                            retry_limit=retry_limit, backoff_s=backoff_s)
    return prov, fab


FAST = {"fast": NodeClass("fast", executors=2, cold_start_s=1.0,
                          cold_start_jitter_s=0.0, cost_rate=2.0)}


def test_node_lifecycle_happy_path():
    clk = VirtualClock()
    clk.attach()
    prov, fab = _prov(clk, catalog=FAST)
    node = prov.request_node("fast")
    assert node.state == PENDING
    assert prov.capacity_in_flight() == 2

    prov.process_pending_tasks()           # power_on succeeds
    assert node.state == BOOTING
    assert prov.ledger.open_count == 1

    prov.process_pending_tasks()           # boot not done yet
    assert node.state == BOOTING and not fab.attached

    clk.sleep(1.0)                         # past the cold start
    prov.process_pending_tasks()
    assert node.state == READY
    assert node.endpoint_idx == 0 and node.executor_idxs == [0, 1]
    assert prov.capacity_in_flight() == 0

    prov.request_poweroff(node)
    assert node.state == DRAINING and fab.drains == [node]
    prov.process_pending_tasks()           # not drained yet: task re-queued
    assert node.state == DRAINING and not fab.offs

    fab.drained_ids.add(node.node_id)
    clk.sleep(0.5)
    prov.process_pending_tasks()
    assert node.state == OFF and fab.offs == [node]
    assert prov.ledger.closed
    # billed from power_on (t=0) to power_off (t=1.5), at cost_rate 2.0
    assert prov.ledger.node_seconds() == {"fast": 1.5}
    assert prov.ledger.total_cost() == pytest.approx(3.0)
    clk.detach()


def test_poweroff_requires_ready():
    clk = VirtualClock()
    clk.attach()
    prov, _ = _prov(clk, catalog=FAST)
    node = prov.request_node("fast")
    with pytest.raises(ValueError, match="READY"):
        prov.request_poweroff(node)
    clk.detach()


def test_retry_backoff_then_failed_then_recover():
    clk = VirtualClock()
    clk.attach()
    prov, fab = _prov(clk, catalog=FAST, retry_limit=2, backoff_s=0.5)
    prov.inject_provision_failures(3)      # burn all attempts (1 + 2 retries)
    node = prov.request_node("fast")

    prov.process_pending_tasks()           # attempt 1 fails → retry at +0.5
    assert node.state == PENDING and node.attempts == 1
    prov.process_pending_tasks()           # backoff gate: nothing happens
    assert node.attempts == 1
    clk.sleep(0.5)
    prov.process_pending_tasks()           # attempt 2 fails → retry at +1.0
    assert node.attempts == 2
    clk.sleep(1.0)
    prov.process_pending_tasks()           # attempt 3 fails → FAILED
    assert node.state == FAILED
    assert prov.ledger.open_count == 0     # never powered on, never billed

    assert prov.recover() == 1             # requeue
    assert node.state == PENDING
    prov.process_pending_tasks()           # no injected failures left
    assert node.state == BOOTING
    s = prov.summary()
    assert s["provision_failures"] == 3 and s["retries"] == 2
    assert s["nodes_failed"] == 1 and s["recovered"] == 1
    clk.detach()


def test_cold_start_jitter_is_seed_deterministic():
    cat = {"j": NodeClass("j", cold_start_s=1.0, cold_start_jitter_s=0.5)}

    def boots(seed):
        clk = VirtualClock()
        clk.attach()
        prov, _ = _prov(clk, catalog=cat, seed=seed)
        for _ in range(3):
            prov.request_node("j")
        prov.process_pending_tasks()
        out = [d["boot_s"] for _, d in prov.events if d["event"] == "power_on"]
        clk.detach()
        return out

    a, b, c = boots(7), boots(7), boots(8)
    assert a == b                          # same seed → same jitter draws
    assert a != c                          # different seed → different boots
    assert all(1.0 <= x <= 1.5 for x in a)


def test_boot_stall_extends_current_and_next_boot():
    clk = VirtualClock()
    clk.attach()
    prov, fab = _prov(clk, catalog=FAST)
    n1 = prov.request_node("fast")
    prov.process_pending_tasks()
    assert n1.state == BOOTING
    prov.inject_boot_stall(2.0)            # extends the in-flight boot
    clk.sleep(1.5)                         # past nominal 1.0s cold start
    prov.process_pending_tasks()
    assert n1.state == BOOTING
    clk.sleep(1.5)                         # past 3.0s stalled deadline
    prov.process_pending_tasks()
    assert n1.state == READY

    prov.inject_boot_stall(1.0)            # nothing booting: stalls the next
    n2 = prov.request_node("fast")
    prov.process_pending_tasks()
    stall_boot = [d["boot_s"] for _, d in prov.events
                  if d["event"] == "power_on" and d["node"] == n2.name]
    assert stall_boot == [2.0]             # 1.0 cold start + 1.0 stall
    clk.detach()


def test_pick_poweroff_newest_ready_respecting_floor():
    clk = VirtualClock()
    clk.attach()
    prov, fab = _prov(clk, catalog=FAST)
    a = prov.request_node("fast")
    b = prov.request_node("fast")
    prov.process_pending_tasks()
    clk.sleep(1.0)
    prov.process_pending_tasks()
    assert a.state == READY and b.state == READY
    # newest first
    assert prov.pick_poweroff(lambda n: True) is b
    # predicate can veto the newest (e.g. min_executors floor)
    assert prov.pick_poweroff(lambda n: n.node_id == a.node_id) is a
    assert prov.pick_poweroff(lambda n: False) is None
    # draining/booting nodes are never candidates
    prov.request_poweroff(b)
    assert prov.pick_poweroff(lambda n: True) is a
    clk.detach()


def test_shutdown_closes_ledger_for_inflight_nodes():
    clk = VirtualClock()
    clk.attach()
    prov, fab = _prov(clk, catalog=FAST)
    prov.request_node("fast")              # will be BOOTING at shutdown
    prov.process_pending_tasks()
    pending = prov.request_node("fast")    # never processed: stays PENDING
    clk.sleep(0.2)
    prov.shutdown()
    assert prov.ledger.closed
    s = prov.summary()
    assert s["states"].get("off", 0) >= 1
    assert pending.state == PENDING        # never billed, never powered on
    assert s["pending_tasks"] == 0
    clk.detach()


def test_ledger_summary_rounds_and_closes():
    led = CostLedger()

    class N:
        node_id = 0
        node_class = DEFAULT_CATALOG["standard"]

    led.power_on(N, 1.0)
    assert not led.closed
    led.power_off(N, 3.5)
    assert led.closed
    s = led.summary()
    assert s["node_seconds"] == {"standard": 2.5}
    assert s["total_cost"] == pytest.approx(2.5 * 1.8)
    led.power_off(N, 9.0)                  # idempotent: no open record
    assert led.summary() == s


# ---------------------------------------------------------------------------
# Session integration: the controller drives the provisioner end to end
# ---------------------------------------------------------------------------

def _provisioned_workflow(delivery="at-most-once", **el_overrides):
    # target_p99_s is huge on purpose: the engine's latency window is 30
    # virtual seconds, so spike-era samples would keep a tight p99 target
    # breached (blocking scale-in) through the whole quiet tail.  Scaling
    # here is driven by backlog, the leading signal.
    el = dict(enabled=True, interval_s=0.1, target_p99_s=1000.0,
              min_executors=1, max_executors=3, scale_up_step=1,
              backlog_high=8, idle_scale_down_s=0.4, cooldown_s=0.2,
              adapt_batch=False, heartbeat_timeout_s=0.5,
              provision=True, node_class="small")
    el.update(el_overrides)
    return WorkflowConfig(
        n_producers=2, n_groups=1, executors_per_group=1,
        compress="none", backpressure="block", queue_capacity=1024,
        trigger_interval=0.05, min_batch=1, n_executors=1,
        flush_timeout_s=60.0, clock="virtual", delivery=delivery,
        elasticity=ElasticityConfig(**el))


def _spike_scenario(workflow, *, faults=(), seed=0, tail_s=4.0):
    return Scenario(
        workflow=workflow,
        phases=(LoadPhase("low", duration_s=1.0, rate_hz=2),
                LoadPhase("spike", duration_s=3.0, rate_hz=25),
                LoadPhase("quiet", duration_s=tail_s, rate_hz=1)),
        faults=tuple(faults), seed=seed, analysis_cost_s=0.03)


def test_session_provisions_capacity_and_drains_back():
    tr = run_scenario(_spike_scenario(_provisioned_workflow()))
    s = tr.summary
    prov = s["provisioning"]
    # the spike forced at least one async provision through to READY
    assert s["controller_actions"].get("provision", 0) >= 1
    assert prov["nodes_ready"] >= 1
    # the quiet tail drained at least one node back off — through the
    # drain-before-poweroff path, not the shutdown sweeper
    assert s["controller_actions"].get("drain_node", 0) >= 1
    assert any(d["event"] == "power_off"
               for _, d in tr.events_of("provision"))
    # zero loss across scale-out AND scale-in; cost books closed
    assert s["analyzed"] == s["written"] > 0
    assert s["dropped_by_policy"] == 0
    assert prov["ledger"]["closed"]
    assert prov["ledger"]["total_node_seconds"] > 0
    # lifecycle events all landed in the trace
    events = {d["event"] for _, d in tr.events_of("provision")}
    assert {"requested", "power_on", "ready", "drain"} <= events


def test_session_drains_live_endpoint_before_poweroff():
    """Force real traffic onto a provisioned endpoint (base endpoint dies),
    then scale back in: the group must be rerouted off the node and its
    buffered records analyzed before the node powers off — zero loss.
    Exactly-once delivery: the endpoint dies before the first node is READY,
    so the WAL must replay the orphaned tail onto the provisioned one."""
    faults = (Fault(t=2.2, kind="fail_endpoint", target=0),
              Fault(t=3.2, kind="recover_endpoint", target=0))
    tr = run_scenario(_spike_scenario(
        _provisioned_workflow(delivery="exactly-once"), faults=faults,
        tail_s=5.0))
    s = tr.summary
    # the group really moved onto the dynamic endpoint and back
    assert s["rerouted"] >= 1
    dyn_in = [d for _, d in tr.events_of("provision") if d["event"] == "ready"]
    assert dyn_in, "no node ever became ready"
    assert s["provisioning"]["ledger"]["closed"]
    assert s["analyzed"] == s["written"] > 0
    assert s["dropped_by_policy"] == 0


def test_provision_fail_and_boot_stall_faults():
    faults = (Fault(t=0.9, kind="provision_fail", value=2),
              Fault(t=1.4, kind="boot_stall", value=0.5))
    tr = run_scenario(_spike_scenario(
        _provisioned_workflow(provision_backoff_s=0.2), faults=faults))
    s = tr.summary
    assert all(d["ok"] for _, d in tr.events_of("fault"))
    prov = s["provisioning"]
    # both injected failures were consumed by power_on attempts, and the
    # retry/backoff path still delivered the capacity
    assert prov["provision_failures"] >= 2
    assert prov["retries"] >= 1
    assert prov["nodes_ready"] >= 1
    assert prov["ledger"]["closed"]
    assert s["analyzed"] == s["written"] > 0


def test_provisioning_scenario_is_deterministic():
    def run():
        tr = run_scenario(_spike_scenario(
            _provisioned_workflow(),
            faults=(Fault(t=0.9, kind="provision_fail", value=1),),
            seed=3))
        return tr.to_jsonl()

    assert run() == run()


def test_flap_suppression_counts_inflight_capacity():
    """While a node is still booting, repeated breaches must not request a
    second wave past max_executors' worth of capacity."""
    cat_slow = ElasticityConfig(
        enabled=True, interval_s=0.1, target_p99_s=1000.0, min_executors=1,
        max_executors=3, scale_up_step=4, backlog_high=8, cooldown_s=0.0,
        idle_scale_down_s=30.0, adapt_batch=False, heartbeat_timeout_s=0.5,
        provision=True, node_class="standard")   # 2 execs, 1.2-1.6s boot
    wf = WorkflowConfig(
        n_producers=2, n_groups=1, executors_per_group=1, compress="none",
        backpressure="block", queue_capacity=1024, trigger_interval=0.05,
        min_batch=1, n_executors=1, flush_timeout_s=60.0, clock="virtual",
        elasticity=cat_slow)
    tr = run_scenario(Scenario(
        workflow=wf,
        phases=(LoadPhase("spike", duration_s=2.0, rate_hz=30),
                LoadPhase("cool", duration_s=2.0, rate_hz=1)),
        seed=0, analysis_cost_s=0.03))
    prov = tr.summary["provisioning"]
    # alive=1, max=3, standard=2 execs → exactly ONE node ever fits;
    # cooldown_s=0 means the breach re-fires every tick during the boot,
    # but in-flight capacity suppresses every duplicate request
    assert prov["requests"] == 1
    assert tr.summary["controller_actions"].get("provision", 0) == 1
