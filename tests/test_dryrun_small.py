"""Dry-run machinery integration test on a tiny forced-device mesh.

Runs in a subprocess because jax locks the device count at first init; the
main pytest process must keep seeing 1 CPU device.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import pytest

REPO = Path(__file__).resolve().parents[1]

# These subprocess tests build meshes with jax.sharding.AxisType (explicit
# axis types, added in jax 0.6); on older jax builds (e.g. the 0.4.x in
# some containers) the attribute does not exist and the subprocess dies at
# import time — an environment capability gap, not a code regression.
requires_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType unavailable (needs jax >= 0.6 with "
           "explicit axis types)")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import repro.configs as C
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.shardings import make_constrain
from repro.launch import hlo_analysis
from repro.launch.inputs import input_specs
from repro.models.steps import step_for_shape

mesh = make_test_mesh((2, 4), ("data", "model"))
out = {}
for arch in ["starcoder2-3b", "jamba-1.5-large-398b"]:
    cfg = C.get(arch).reduced()
    # pad dims so the (2,4) mesh divides them
    from dataclasses import replace
    cfg = replace(cfg, d_model=128, d_ff=256, vocab_size=512)
    for shape in [ShapeConfig("t", 64, 8, "train", 2),
                  ShapeConfig("d", 64, 8, "decode")]:
        step = step_for_shape(cfg, shape, constrain=make_constrain(mesh))
        args = input_specs(cfg, shape, mesh)
        with mesh:
            lowered = jax.jit(step).lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        a = hlo_analysis.analyze(compiled.as_text())
        out[f"{arch}/{shape.kind}"] = {
            "flops": a["flops"],
            "collectives": {k: v for k, v in a["collectives"].items() if v},
            "arg_bytes": mem.argument_size_in_bytes,
        }
print(json.dumps(out))
"""


@requires_axis_type
def test_tiny_mesh_dryrun():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
                       cwd=str(REPO))
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert len(out) == 4
    for cell, info in out.items():
        assert info["flops"] > 0, cell
        assert info["arg_bytes"] > 0, cell
    # sharded training must communicate
    assert any(info["collectives"] for info in out.values())
