"""SessionCheckpointStore commit/prune contract and SeqLedger
snapshot/restore roundtrips — the two durable state carriers behind
Session.checkpoint()/restore()."""
import pytest

from repro.checkpoint.session_store import SessionCheckpointStore
from repro.runtime.wal import SeqLedger


# ------------------------------------------------------------ pruning (gc)
def test_keep_n_prunes_oldest_and_load_returns_latest(tmp_path):
    store = SessionCheckpointStore(tmp_path, keep=3)
    for i in range(1, 8):
        assert store.save({"step": i}) == i
    # only the newest `keep` survive
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["ckpt_00000005", "ckpt_00000006", "ckpt_00000007"]
    state, cid = store.load()
    assert (state, cid) == ({"step": 7}, 7)
    # pinned loads work for survivors, fail for pruned ids
    assert store.load(5)[0] == {"step": 5}
    with pytest.raises(FileNotFoundError):
        store.load(2)


def test_keep_one_always_single_survivor(tmp_path):
    store = SessionCheckpointStore(tmp_path, keep=1)
    for i in range(4):
        store.save({"i": i})
    assert [p.name for p in tmp_path.iterdir()] == ["ckpt_00000004"]
    assert store.latest_id() == 4


def test_keep_must_be_positive(tmp_path):
    with pytest.raises(ValueError, match="keep"):
        SessionCheckpointStore(tmp_path, keep=0)


def test_ids_continue_after_pruning(tmp_path):
    """gc must never recycle ids: the next save counts from the newest
    COMMITTED id even when older ones were pruned away."""
    store = SessionCheckpointStore(tmp_path, keep=1)
    for _ in range(3):
        store.save({})
    # a second store over the same directory continues the sequence
    again = SessionCheckpointStore(tmp_path, keep=1)
    assert again.save({}) == 4


def test_uncommitted_and_tmp_dirs_are_invisible_and_swept(tmp_path):
    store = SessionCheckpointStore(tmp_path, keep=2)
    store.save({"ok": True})
    # simulate two crash artifacts: a torn stage dir and a renamed dir
    # that never got its COMMITTED marker
    (tmp_path / ".tmp_ckpt_00000009").mkdir()
    torn = tmp_path / "ckpt_00000005"
    torn.mkdir()
    (torn / "state.pkl").write_bytes(b"garbage")
    # neither is loadable...
    assert store.latest_id() == 1
    with pytest.raises(FileNotFoundError):
        store.load(5)
    # ...and the next save sweeps both
    store.save({"ok": 2})
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["ckpt_00000001", "ckpt_00000002"]


def test_alien_dirnames_are_ignored(tmp_path):
    (tmp_path / "ckpt_notanumber").mkdir()
    (tmp_path / "ckpt_notanumber" / "COMMITTED").touch()
    store = SessionCheckpointStore(tmp_path, keep=2)
    assert store.latest_id() is None
    assert store.save({}) == 1               # alien dir never feeds the ids


def test_load_empty_store_raises(tmp_path):
    store = SessionCheckpointStore(tmp_path)
    with pytest.raises(FileNotFoundError, match="no committed"):
        store.load()


def test_format_mismatch_rejected(tmp_path):
    import json
    store = SessionCheckpointStore(tmp_path)
    cid = store.save({"x": 1})
    man = tmp_path / f"ckpt_{cid:08d}" / "manifest.json"
    man.write_text(json.dumps({"id": cid, "format": 99}))
    with pytest.raises(ValueError, match="format"):
        store.load(cid)


# ------------------------------------------------- SeqLedger snapshot cycle
def test_empty_ledger_snapshot_roundtrip():
    led = SeqLedger()
    snap = led.snapshot()
    assert snap == {"applied": {}}
    led2 = SeqLedger()
    led2.restore(snap)
    assert led2.applied(0) == 0              # untouched groups read as 0
    assert led2.admit(0, 1, 3) == 0          # and admit normally afterwards


def test_mid_replay_snapshot_restores_identical_dedupe():
    """Snapshot taken while a replay is half-applied: the restored ledger
    must dedupe the remaining replay exactly like the original would."""
    led = SeqLedger()
    led.admit(0, 1, 4)                       # frames 1..4 applied
    led.admit(0, 5, 2)                       # ...and 5..6
    led.admit(1, 1, 1)
    snap = led.snapshot()

    restored = SeqLedger()
    restored.restore(snap)
    for g in (0, 1):
        assert restored.applied(g) == led.applied(g)
    # replaying the full history: same skip counts on both ledgers
    for args in ((0, 1, 4), (0, 5, 2), (0, 7, 3), (1, 1, 1), (1, 2, 2)):
        assert restored.admit(*args) == led.admit(*args)
    assert restored.applied(0) == led.applied(0) == 9
    assert restored.applied(1) == led.applied(1) == 3


def test_snapshot_is_a_copy_not_a_view():
    led = SeqLedger()
    led.admit(0, 1, 2)
    snap = led.snapshot()
    led.admit(0, 3, 2)                       # mutate after snapshot
    assert snap["applied"][0] == 2           # snapshot frozen at capture time
    restored = SeqLedger()
    restored.restore(snap)
    assert restored.applied(0) == 2
