"""Keyed shuffle + sharded fan-in: the stable partition hash (property
tests), shuffle-edge plan semantics, broker shard routing/telemetry (incl.
the record-vs-item backlog regression), restore topology gating, and the
``kill_node`` chaos fault."""
import zlib

import numpy as np
import pytest

try:        # hypothesis gates only the property tests, not the whole module
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.cloud import (FAILED, PENDING, READY, CloudProvisioner, NodeClass)
from repro.core.broker import Broker, BrokerConfig
from repro.core.grouping import GroupPlan, partition_of
from repro.core.records import StreamRecord
from repro.runtime.clock import VirtualClock
from repro.sim.scenario import Fault, LoadPhase, Scenario, run_scenario
from repro.streaming.endpoint import make_endpoints
from repro.streaming.operators import OperatorPipeline
from repro.workflow import ElasticityConfig, WorkflowConfig


# ------------------------------------------------------- partition_of (hash)
def test_partition_golden_values():
    """Pinned crc32 outputs: the routing hash must never drift (a drift
    silently re-owns every key's shuffle partition, shard, and window
    stripe, breaking replay against recorded traces)."""
    assert zlib.crc32(b"hot0") == 4057399475
    assert partition_of("hot0", 64) == 51
    assert partition_of("cold42", 64) == 59
    assert partition_of("velocity_x/g0/r7", 64) == 25
    assert partition_of("", 64) == 0


def test_partition_rejects_nonpositive_n():
    with pytest.raises(ValueError, match="partitions"):
        partition_of("k", 0)
    with pytest.raises(ValueError, match="partitions"):
        partition_of("k", -3)


def test_partition_uniform_at_10k_keys():
    """10k distinct keys over 64 partitions stay within 30% of the ideal
    per-bucket load — no pathological clumping from the hash."""
    n = 64
    loads = [0] * n
    for i in range(10_000):
        loads[partition_of(f"stream-{i}/field", n)] += 1
    expected = 10_000 / n
    assert min(loads) >= 0.7 * expected
    assert max(loads) <= 1.3 * expected
    assert sum(loads) == 10_000


if HAS_HYPOTHESIS:
    @given(key=st.text(max_size=64), n=st.integers(1, 4096))
    @settings(max_examples=120, deadline=None)
    def test_partition_is_stable_crc32_never_hash(key, n):
        p = partition_of(key, n)
        assert 0 <= p < n
        # crc32 by definition — i.e. process-stable, PYTHONHASHSEED-free
        assert p == zlib.crc32(key.encode()) % n
        assert p == partition_of(key, n)          # idempotent


def test_partition_consistent_with_window_stripes_and_shuffle():
    """One hash family for every keyed ownership decision: the window's
    stripe index and the plan's shuffle partition agree with partition_of
    for the same key and modulus."""
    plan = (OperatorPipeline()
            .key_by("k", lambda sk, rec: sk.split("/")[-1])
            .tumbling_window("win", 1.0)
            .sink("out")).compile()
    win = plan.ops["win"]
    for key in ("r0", "r7", "hot3", "a/b/c", ""):
        assert win._stripe_of(key) == partition_of(key, win.n_stripes)
    plan.enable_shuffle(16)
    rec = StreamRecord(field_name="f", group_id=2, rank=7, step=0,
                       payload=np.zeros(2, dtype=np.float32))
    # stream key "f/g2/r7" -> KeyBy output "r7"
    assert plan.shuffle_partition(rec) == partition_of("r7", 16)


# ------------------------------------------------------------- shuffle edge
def test_record_keyby_source_compiles_to_shuffle_edge():
    plan = (OperatorPipeline()
            .key_by("k", lambda sk, rec: "x")
            .tumbling_window("win", 1.0)
            .sink("out")).compile()
    assert plan.shuffle_op is not None
    assert not plan.shuffled                      # off until enabled
    plan.enable_shuffle(8)
    assert plan.shuffled and plan.shuffle_partitions == 8
    with pytest.raises(ValueError, match="partitions"):
        plan.enable_shuffle(0)


def test_enable_shuffle_requires_keyby_source():
    plan = (OperatorPipeline()
            .map("m", lambda k, rec: rec.step)
            .sink("out")).compile()
    assert plan.shuffle_op is None
    with pytest.raises(ValueError, match="shuffle edge"):
        plan.enable_shuffle(8)


# --------------------------------------------------------- sharded fan-in
def _sharded_broker(n_groups=6, n_shards=4, n_producers=12, paused=False,
                    **cfg_kw):
    eps = make_endpoints(n_groups, transport="inprocess")
    plan = GroupPlan(n_producers=n_producers, n_groups=n_groups,
                     executors_per_group=1)
    cfg = BrokerConfig(compress="none", n_shards=n_shards, **cfg_kw)
    return Broker(plan, eps, cfg, paused=paused), eps


def test_groups_land_on_owning_shard():
    broker, eps = _sharded_broker()
    try:
        assert broker.n_shards == 4
        for shard in broker.shards:
            for g in shard.senders:
                assert g % broker.n_shards == shard.shard_id
        # the routing layer and the shards agree, and cover every group
        assert sorted(broker._senders) == list(range(6))
        for g in range(6):
            assert broker._sender(g) is \
                broker.shards[g % 4].senders[g]
    finally:
        broker.finalize()
        for e in eps:
            e.close()


def test_shard_telemetry_rolls_up_per_shard():
    broker, eps = _sharded_broker(backpressure="block")
    try:
        for rank in range(12):
            broker.write("f", rank, step=0,
                         payload=np.arange(4, dtype=np.float32))
        broker.flush()
        rows = broker.shard_telemetry()
        assert [r["shard"] for r in rows] == [0, 1, 2, 3]
        assert sum(r["groups"] for r in rows) == 6
        assert sum(r["sent"] for r in rows) == 12
        assert all(r["queue_depth"] == 0 for r in rows)   # drained
        # group rows carry their owning shard id
        assert all(r["shard"] == r["group"] % 4
                   for r in broker.group_telemetry())
    finally:
        broker.finalize()
        for e in eps:
            e.close()


def test_backlog_counts_records_not_queue_items():
    """Regression: a submit_batch list is ONE queue item; backlog/telemetry
    must still report the records inside it, or batched producers hide an
    arbitrarily deep backlog from the controller's shard signal."""
    broker, eps = _sharded_broker(n_groups=4, n_shards=2,
                                  backpressure="block", paused=True)
    try:
        ranks = [0, 4, 8]                    # rank % 4 == 0: all group 0
        n = broker.write_batch("f", ranks, [1] * 3,
                               [np.zeros(4, dtype=np.float32)] * 3)
        assert n == 3
        sender = broker._sender(0)
        assert sender.q.qsize() == 1         # one coalesced item...
        assert sender.backlog() == 3         # ...but three records of backlog
        shard0 = broker.shards[broker.shard_of(0)]
        assert shard0.telemetry()["queue_depth"] == 3
        broker.release()
        broker.flush()
        assert sender.backlog() == 0
        assert broker.stats.sent == 3
    finally:
        broker.finalize()
        for e in eps:
            e.close()


def test_backlog_counts_paced_inflight_chunk():
    """Records the sender has popped but not yet pushed through a slow
    endpoint still count as backlog — they are exactly the congestion the
    shard signal exists to see."""
    broker, eps = _sharded_broker(n_groups=1, n_shards=1, n_producers=2,
                                  backpressure="block", paused=True,
                                  max_batch_records=4)
    try:
        broker.write_batch("f", [0, 1], [0, 0],
                           [np.zeros(4, dtype=np.float32)] * 2)
        assert broker.shards[0].backlog() == 2
        broker.release()
        broker.flush()
        assert broker.shards[0].backlog() == 0
    finally:
        broker.finalize()
        for e in eps:
            e.close()


def test_attach_endpoint_keeps_shard_rings_aligned():
    broker, eps = _sharded_broker()
    extra = make_endpoints(1, transport="inprocess")
    try:
        idx = broker.attach_endpoint(extra[0])
        assert idx == len(eps)
        for shard in broker.shards:
            assert len(shard.endpoints) == len(eps) + 1
            assert shard.endpoints[idx] is extra[0]
    finally:
        broker.finalize()
        for e in [*eps, *extra]:
            e.close()


# ----------------------------------------- end-to-end digest equivalence
def _shuffle_pipeline():
    def factory():
        return (OperatorPipeline()
                .key_by("k", lambda sk, rec: f"b{rec.rank % 5}")
                .tumbling_window("win", 0.5, allowed_lateness_s=5.0)
                .aggregate("agg", lambda k, vals: sorted(
                    (r.rank, r.step,
                     round(float(np.asarray(r.payload,
                                            np.float64).sum()), 6))
                    for r in vals))
                .sink("out"))
    return factory


def _shuffle_wf(sharded):
    base = dict(n_producers=24, compress="none", backpressure="block",
                queue_capacity=1024, max_batch_records=8,
                trigger_interval=0.05, min_batch=2, n_executors=4,
                clock="virtual", flush_timeout_s=60.0)
    if not sharded:
        return WorkflowConfig(n_groups=1, n_endpoints=1, **base)
    return WorkflowConfig(n_groups=4, n_endpoints=4, broker_shards=2,
                          shuffle_partitions=16, **base)


@pytest.mark.parametrize("seed", [0, 1])
def test_sharded_shuffle_preserves_sink_digest(seed):
    """Same seed, two topologies (single fan-in vs sharded+shuffled): the
    sink must see identical panes with identical contents — re-partitioning
    records across streams may change WHERE work runs, never the results."""
    phases = (LoadPhase("steady", 1.0, 8.0), LoadPhase("drain", 0.5, 0.0))
    traces = {}
    for sharded in (False, True):
        sc = Scenario(workflow=_shuffle_wf(sharded), phases=phases,
                      seed=seed, operators=_shuffle_pipeline(),
                      payload_elems=8)
        traces[sharded] = run_scenario(sc)
    a, b = traces[False].summary, traces[True].summary
    assert a["written"] == b["written"] > 0
    assert a["dropped_by_policy"] == b["dropped_by_policy"] == 0
    assert a["sink_digest"] == b["sink_digest"]


def test_sharded_shuffle_replays_byte_identical():
    sc = Scenario(workflow=_shuffle_wf(True),
                  phases=(LoadPhase("steady", 1.0, 8.0),
                          LoadPhase("drain", 0.5, 0.0)),
                  seed=3, operators=_shuffle_pipeline(), payload_elems=8)
    assert run_scenario(sc).digest() == run_scenario(sc).digest()


# -------------------------------------------------- restore topology gate
def _eo_wf(**kw):
    base = dict(n_producers=4, n_groups=2, executors_per_group=2,
                compress="none", backpressure="block", queue_capacity=4096,
                trigger_interval=0.05, min_batch=4, n_executors=2,
                max_batch_records=8, delivery="exactly-once",
                clock="virtual", flush_timeout_s=60.0)
    base.update(kw)
    return WorkflowConfig(**base)


def _ckpt_pipe():
    return (OperatorPipeline()
            .map("norm", lambda k, rec: rec.step)
            .sink("out"))


def _checkpointed_session(tmp_path):
    from repro.checkpoint.session_store import SessionCheckpointStore
    from repro.runtime.wal import WalStore
    from repro.workflow.session import Session

    cfg = _eo_wf()
    store = SessionCheckpointStore(tmp_path / "ckpts")
    wal = WalStore(capacity_bytes=cfg.wal_capacity_bytes,
                   queue_capacity=cfg.queue_capacity, retain="commit")
    sess = Session(cfg, pipeline=_ckpt_pipe(), wal=wal, checkpoints=store)
    h = sess.open_field("f", shape=(4,))
    for s in range(10):
        h.write_batch(s, [np.full(4, s, dtype=np.float32)] * 4,
                      ranks=[0, 1, 2, 3], t=s * 0.05)
        sess.clock.sleep(0.05)
    sess.checkpoint(timeout=60.0)
    sess.kill()
    return cfg, store, wal


def test_restore_rejects_topology_mismatch(tmp_path):
    from repro.workflow.session import RestoreTopologyError, Session

    cfg, store, wal = _checkpointed_session(tmp_path)
    mismatched = _eo_wf(n_groups=1, executors_per_group=4)
    with pytest.raises(RestoreTopologyError, match="n_groups"):
        Session.restore(mismatched, checkpoints=store, wal=wal,
                        pipeline=_ckpt_pipe())
    # the error names every divergent axis, not just the first
    wider = _eo_wf(n_producers=8, n_groups=4, n_endpoints=6)
    with pytest.raises(RestoreTopologyError) as ei:
        Session.restore(wider, checkpoints=store, wal=wal,
                        pipeline=_ckpt_pipe())
    msg = str(ei.value)
    assert ("n_producers" in msg and "n_groups" in msg
            and "endpoint_count" in msg)
    # RestoreTopologyError is a ValueError: legacy callers that guard
    # restore with `except ValueError` keep working
    assert isinstance(ei.value, ValueError)


def test_restore_accepts_matching_or_adopted_topology(tmp_path):
    from repro.workflow.session import Session

    cfg, store, wal = _checkpointed_session(tmp_path)
    # same topology, explicitly passed: fine
    sess = Session.restore(_eo_wf(), checkpoints=store, wal=wal,
                           pipeline=_ckpt_pipe())
    sess.close()


def test_restore_adopts_checkpointed_config(tmp_path):
    from repro.workflow.session import Session

    cfg, store, wal = _checkpointed_session(tmp_path)
    sess = Session.restore(config=None, checkpoints=store, wal=wal,
                           pipeline=_ckpt_pipe())
    assert sess.config.n_groups == cfg.n_groups
    sess.close()


# ------------------------------------------------------- kill_node fault
class _FakeFabric:
    def __init__(self):
        self.attached, self.drains, self.failed, self.offs = [], [], [], []
        self.drained_ids = set()

    def attach_node(self, node):
        self.attached.append(node)
        return len(self.attached) - 1, [len(self.attached) - 1]

    def begin_drain(self, node):
        self.drains.append(node)

    def fail_node(self, node):
        self.failed.append(node)

    def node_drained(self, node):
        return node.node_id in self.drained_ids

    def finish_poweroff(self, node):
        self.offs.append(node)


_FAST = {"fast": NodeClass("fast", executors=1, cold_start_s=1.0,
                           cold_start_jitter_s=0.0, cost_rate=2.0)}


def test_fail_node_closes_books_and_recovers():
    clk = VirtualClock()
    clk.attach()
    try:
        fab = _FakeFabric()
        prov = CloudProvisioner(fab, catalog=_FAST, clock=clk)
        node = prov.request_node("fast")
        with pytest.raises(ValueError, match="READY"):
            prov.fail_node(node)              # only READY nodes can die
        prov.process_pending_tasks()
        clk.sleep(1.0)
        prov.process_pending_tasks()
        assert node.state == READY

        clk.sleep(0.5)
        prov.fail_node(node)
        assert node.state == FAILED
        assert fab.failed == [node]           # endpoint+executors died once
        # billing closed AT death, not at session teardown
        assert prov.ledger.closed
        assert prov.ledger.node_seconds() == {"fast": 1.5}
        assert prov.summary()["nodes_failed"] == 1
        # a second kill is rejected (no double ledger close, no re-fail)
        with pytest.raises(ValueError, match="READY"):
            prov.fail_node(node)

        # recover() requeues the node; the reboot gets a FRESH attachment
        assert prov.recover() == 1
        assert node.state == PENDING
        prov.process_pending_tasks()
        clk.sleep(1.0)
        prov.process_pending_tasks()
        assert node.state == READY
        assert node.endpoint_idx == 1         # new endpoint, not the corpse
        # the reboot opened a NEW billing record
        assert prov.ledger.open_count == 1
    finally:
        clk.detach()


def _provisioned_wf(**el_overrides):
    el = dict(enabled=True, interval_s=0.1, target_p99_s=1000.0,
              min_executors=1, max_executors=3, scale_up_step=1,
              backlog_high=8, idle_scale_down_s=0.4, cooldown_s=0.2,
              adapt_batch=False, heartbeat_timeout_s=0.5,
              provision=True, node_class="small")
    el.update(el_overrides)
    return WorkflowConfig(
        n_producers=2, n_groups=1, executors_per_group=1,
        compress="none", backpressure="block", queue_capacity=1024,
        trigger_interval=0.05, min_batch=1, n_executors=1,
        flush_timeout_s=60.0, clock="virtual",
        elasticity=ElasticityConfig(**el))


def test_kill_node_requires_provisioning():
    sc = Scenario(workflow=_eo_wf(),
                  phases=(LoadPhase("x", 1.0, 5.0),),
                  faults=(Fault(t=0.5, kind="kill_node"),))
    with pytest.raises(ValueError, match="kill_node"):
        sc.validate()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_kill_node_chaos_recovers_without_loss(seed):
    """Hard-kill a READY cloud node mid-spike: its endpoint and executors
    die atomically, the ledger closes the node's billing at death, traffic
    reroutes to survivors, and the loss ledger still closes."""
    sc = Scenario(
        workflow=_provisioned_wf(),
        phases=(LoadPhase("low", 1.0, 2.0), LoadPhase("spike", 3.0, 25.0),
                LoadPhase("quiet", 4.0, 1.0)),
        faults=(Fault(t=3.0, kind="kill_node", target=0),),
        seed=seed, analysis_cost_s=0.03)
    tr = run_scenario(sc)
    s = tr.summary
    kills = [d for _, d in tr.events_of("fault") if d["fault"] == "kill_node"]
    assert len(kills) == 1 and kills[0]["ok"], \
        f"kill_node did not land: {kills}"
    prov = s["provisioning"]
    assert prov["nodes_failed"] >= 1
    assert any(d["event"] == "node_failed"
               for _, d in tr.events_of("provision"))
    # cost books balance even though the node died instead of draining
    assert prov["ledger"]["closed"]
    assert prov["ledger"]["total_node_seconds"] > 0
    # survivors absorbed the work: nothing silently lost
    assert s["analyzed"] == s["written"] - s["dropped_by_policy"] > 0
    assert s["order_timeouts"] == 0
