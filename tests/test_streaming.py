"""Streaming engine: micro-batching, work stealing, executor failure,
elastic scaling — the Spark-side semantics the paper leans on."""
import time

import numpy as np

from repro.core.broker import Broker, BrokerConfig
from repro.core.grouping import GroupPlan
from repro.streaming.endpoint import make_endpoints
from repro.streaming.engine import StreamEngine


def _push(broker, n_ranks=4, steps=5):
    for s in range(steps):
        for r in range(n_ranks):
            broker.write("f", r, s, np.full(8, float(s), np.float32))


def _mk_engine(n_eps=1, n_exec=2, analyze=None, trigger=0.05, n_ranks=4):
    eps = make_endpoints(n_eps)
    plan = GroupPlan(n_producers=n_ranks, n_groups=n_eps, executors_per_group=2)
    broker = Broker(plan, eps, BrokerConfig(compress="none"))
    analyze = analyze or (lambda key, recs: len(recs))
    eng = StreamEngine([e.handle for e in eps], analyze, n_exec,
                       trigger_interval=trigger)
    return broker, eps, eng


def test_microbatches_and_collect():
    broker, eps, eng = _mk_engine()
    _push(broker, steps=6)
    broker.flush()
    eng.drain_and_stop()
    results = eng.collect()
    assert sum(r.n_records for r in results) == 24
    keys = {r.stream_key for r in results}
    assert len(keys) == 4                      # one stream per rank
    stats = eng.latency_stats()
    assert stats["n"] > 0 and stats["mean"] >= 0


def test_sticky_partition_assignment():
    broker, eps, eng = _mk_engine(n_exec=3)
    _push(broker, steps=10)
    broker.flush()
    eng.drain_and_stop()
    by_key = {}
    for r in eng.collect():
        by_key.setdefault(r.stream_key, set()).add(r.executor)
    # fixed subset mapping (allow steal-induced exceptions on at most 1 key)
    sticky = sum(1 for execs in by_key.values() if len(execs) == 1)
    assert sticky >= len(by_key) - 1


def test_work_stealing_absorbs_straggler():
    # manual triggering for determinism: the straggler's queue must be
    # visibly deep before the fast executor goes idle
    broker, eps, eng = _mk_engine(n_exec=2, trigger=30)
    straggler = eng.executors[0]
    straggler.slowdown = 0.3
    total = 0
    for wave in range(6):                      # many small micro-batches
        _push(broker, n_ranks=4, steps=1)
        broker.flush()
        total += eng.trigger_once()
        time.sleep(0.02)
    assert total > 0
    eng.drain_and_stop(timeout=30)
    stolen = sum(e.stolen for e in eng.executors)
    assert stolen > 0, "idle executor should have stolen work"
    assert sum(r.n_records for r in eng.collect()) == 24


def test_executor_failure_reassigns():
    broker, eps, eng = _mk_engine(n_exec=2, trigger=10)  # driver won't fire
    _push(broker, steps=4)
    broker.flush()
    n = eng.trigger_once()
    assert n > 0
    # kill the executor holding queued partitions
    victim = max(eng.executors, key=lambda e: e.q.qsize())
    eng.kill_executor(victim.idx)
    eng.drain_and_stop()
    assert sum(r.n_records for r in eng.collect()) == 16
    assert all(r.executor != victim.idx or True for r in eng.collect())


def test_per_stream_order_survives_stealing():
    """Regression for the steal ordering hazard: a stolen micro-batch must
    never be analyzed concurrently with — or ahead of — an earlier
    micro-batch of the same stream still on the sticky executor.  A slowed
    executor + single hot stream forces steals; per-stream sequence tickets
    must keep analysis order == dispatch order."""
    import threading
    broker, eps, eng = _mk_engine(n_exec=2, trigger=30, n_ranks=1)
    eng.min_batch = 1
    eng.executors[0].slowdown = 0.05
    order: dict[str, list[int]] = {}
    in_flight: dict[str, int] = {}
    overlap = []
    lock = threading.Lock()

    def analyze(key, recs):
        with lock:
            if in_flight.get(key):
                overlap.append(key)        # concurrent same-stream analysis
            in_flight[key] = in_flight.get(key, 0) + 1
        time.sleep(0.01)
        with lock:
            in_flight[key] -= 1
            order.setdefault(key, []).extend(r.step for r in recs)
        return len(recs)

    eng.analyze_fn = analyze
    for step in range(30):                 # many 1-record batches, one stream
        broker.write("f", 0, step, np.full(8, float(step), np.float32))
        broker.flush()
        eng.trigger_once()
    eng.drain_and_stop(timeout=30)
    stolen = sum(e.stolen for e in eng.executors)
    assert stolen > 0, "scenario must actually exercise stealing"
    assert not overlap, f"concurrent same-stream analysis on {overlap}"
    for key, steps in order.items():
        assert steps == sorted(steps), f"stream {key} reordered: {steps}"
    assert sum(len(s) for s in order.values()) == 30
    assert eng.order_timeouts == 0


def test_rebalance_releases_only_idle_streams():
    """Scale events must not migrate a backlogged stream away from the
    executor still holding its dispatched batches (ordering would stall);
    only fully-drained streams are released for reassignment."""
    broker, eps, eng = _mk_engine(n_exec=2, trigger=30)
    for e in eng.executors:
        e.slowdown = 0.3               # keep dispatched batches unfinished
    _push(broker, steps=4)
    broker.flush()
    assert eng.trigger_once() > 0
    with eng._tlock:
        assigned_before = dict(eng._assign)
    assert assigned_before
    released = eng.rebalance()
    assert released == 0, "busy streams must keep their assignment"
    with eng._tlock:
        assert eng._assign == assigned_before
    for e in eng.executors:
        e.slowdown = 0.0
    eng.drain_and_stop(timeout=30)
    # exiting executors hand back their queues and drop their assignments;
    # with everything drained a rebalance has nothing left to hold
    with eng._tlock:
        assert eng._assign == {}
    assert eng.rebalance() == 0


def test_elastic_scale_up_down():
    broker, eps, eng = _mk_engine(n_exec=1, trigger=0.02)
    assert len([e for e in eng.executors if e.alive]) == 1
    eng.add_executor()
    eng.add_executor()
    assert len([e for e in eng.executors if e.alive]) == 3
    _push(broker, steps=6)
    broker.flush()
    removed = eng.remove_executor()
    assert removed is not None
    eng.drain_and_stop()
    assert sum(r.n_records for r in eng.collect()) == 24
    assert len([e for e in eng.executors if e.alive]) == 0  # stopped
