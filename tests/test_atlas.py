"""Workload atlas: scenario matrix coverage, determinism of the report
artifact, and the multi-tenant squeeze's QoS contract."""
import json

import pytest

from repro.sim.atlas import SCENARIOS, build, report_json, run_atlas
from repro.sim.scenario import run_scenario


def test_atlas_covers_the_required_matrix():
    """The CI matrix promise: at least five scenarios spanning load shape,
    failure, skew, and multi-tenant mixes."""
    assert len(SCENARIOS) >= 5
    for required in ("diurnal", "endpoint_blackout", "partition",
                     "straggler_storm", "hot_key_drift", "tenant_squeeze"):
        assert required in SCENARIOS
    with pytest.raises(KeyError):
        build("no_such_scenario", seed=0)


def test_atlas_report_is_byte_identical_across_runs():
    """Same seeds, same scenarios -> byte-for-byte identical report: the
    property CI enforces with a run-twice + cmp gate over the full matrix
    (a fast subset here)."""
    names = ["endpoint_blackout", "tenant_quota"]
    a = report_json(run_atlas(names=names, seeds=(0, 1)))
    b = report_json(run_atlas(names=names, seeds=(0, 1)))
    assert a == b
    # and it is canonical JSON: keys sorted, no NaN smuggled through
    parsed = json.loads(a)
    assert parsed["atlas"]["n_runs"] == 4


def test_atlas_gates_close_every_ledger():
    report = run_atlas(names=["tenant_blackout"], seeds=(0,))
    assert report["gates"]["ledgers_closed"], report["gates"]["ledger_failures"]
    assert report["gates"]["all_runs_analyzed"]
    (run,) = report["runs"]
    assert run["tenant_ledger"]["closed"]
    assert run["analyzed"] > 0


def test_squeeze_holds_protected_slo_and_accounts_all_loss():
    """The headline QoS scenario: under a 4x capacity squeeze the
    p99-targeted tenant stays under its target with zero loss, while
    best-effort traffic degrades gracefully — parked/evicted with every
    record accounted for."""
    trace = run_scenario(build("tenant_squeeze", seed=0))
    assert trace.phase_p99("squeeze", tenant="alerts") < 0.5
    rows = trace.summary["tenants"]
    assert rows["alerts"]["dropped"] == 0 and rows["alerts"]["evicted"] == 0
    assert rows["batch"]["parked_total"] > 0
    assert rows["batch"]["evicted"] > 0
    assert rows["batch"]["analyzed"] > 0        # degraded, not starved
    ledger = trace.summary["tenant_ledger"]
    assert ledger["closed"], ledger["errors"]
    # per-tenant cost attribution closes over the provisioned fleet
    if "cost_by_tenant" in trace.summary:
        assert all(v >= 0 for v in trace.summary["cost_by_tenant"].values())
