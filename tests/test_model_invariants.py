"""Model correctness invariants (stronger than smoke tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T
from repro.models.modules import materialize
from repro.models.steps import make_prefill_step, make_decode_step

B, S = 2, 32


def _build(name):
    cfg = C.get(name).reduced()
    params = materialize(T.build_specs(cfg), jax.random.key(1), jnp.float32)
    return cfg, params


def _logits_full(cfg, params, tokens):
    ctx = T.Ctx(cfg=cfg, mode="train", positions=jnp.arange(tokens.shape[1]))
    h = T.embed_inputs(cfg, params, {"tokens": tokens}, ctx)
    h, _, _, _ = T.trunk(cfg, params, h, ctx)
    return T.lm_head(cfg, params, h)


@pytest.mark.parametrize("arch", ["starcoder2-3b", "gemma3-12b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b"])
def test_causality(arch, rng):
    """Changing token t+k must not affect logits at positions <= t.

    For the MoE hybrid we disable experts: GShard *capacity dropping* is
    batch-global by construction (a future token's routing can evict an
    earlier token's 2nd choice), so strict causality only holds for the
    non-MoE path — decode uses group_size=1 and is unaffected.  (Documented
    in DESIGN.md §10.)
    """
    cfg, params = _build(arch)
    if cfg.n_experts:
        from dataclasses import replace
        cfg = replace(cfg, n_experts=0, experts_per_token=0)
        params = materialize(T.build_specs(cfg), jax.random.key(1), jnp.float32)
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    tok2 = tok.at[:, S // 2:].set((tok[:, S // 2:] + 7) % cfg.vocab_size)
    l1 = np.asarray(_logits_full(cfg, params, tok), np.float32)
    l2 = np.asarray(_logits_full(cfg, params, tok2), np.float32)
    np.testing.assert_allclose(l1[:, : S // 2], l2[:, : S // 2],
                               atol=1e-4, rtol=1e-3)
    assert not np.allclose(l1[:, -1], l2[:, -1], atol=1e-4)


@pytest.mark.parametrize("arch", ["starcoder2-3b", "gemma3-12b", "mamba2-2.7b",
                                  "musicgen-large"])
def test_decode_matches_forward(arch, rng):
    """prefill(S) + decode(t_S) must equal full forward on S+1 tokens."""
    cfg, params = _build(arch)
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    batch = {}
    if cfg.frontend == "audio":
        # audio prefill uses frames; decode embeds tokens — compare via the
        # token-embedding path for both by feeding embeds==embed[tokens]
        frames = jnp.take(params["embed"], tok, axis=0)
        batch["frames"] = frames[:, :S]
    else:
        batch["tokens"] = tok[:, :S]
    _, cache, _ = jax.jit(make_prefill_step(cfg))(params, batch)

    def extend(c):
        if c.ndim == 5 and c.shape[2] == S:
            return jnp.pad(c, [(0, 0), (0, 0), (0, 4), (0, 0), (0, 0)])
        return c
    cache = jax.tree.map(extend, cache)
    nxt, _, _ = jax.jit(make_decode_step(cfg))(
        params, cache, tok[:, S:S + 1], jnp.asarray(S, jnp.int32))

    if cfg.frontend == "audio":
        ctx = T.Ctx(cfg=cfg, mode="train", positions=jnp.arange(S + 1))
        h = jnp.take(params["embed"], tok, axis=0)
        h, _, _, _ = T.trunk(cfg, params, h, ctx)
        full = T.lm_head(cfg, params, h)
    else:
        full = _logits_full(cfg, params, tok)
    want = np.argmax(np.asarray(full, np.float32)[:, S, : cfg.vocab_size], -1)
    assert np.array_equal(np.asarray(nxt), want)


def test_head_padding_exact(rng):
    """Padded q-heads (kv-group-major layout, wo pad slots masked) must not
    change outputs: compare Hp=16 vs Hp=n_heads models whose *real* head
    weights coincide.  Real slots live at h = k*Gp + g, g < G_real."""
    cfg16 = C.get("starcoder2-3b").reduced()              # H=4, K=2 -> Hp 16
    from dataclasses import replace
    cfg4 = replace(cfg16, head_pad_to=4)                  # Hp == 4
    assert cfg16.padded_heads == 16 and cfg4.padded_heads == 4
    p16 = materialize(T.build_specs(cfg16), jax.random.key(2), jnp.float32)

    K = cfg16.n_kv_heads
    gp, g_real = 16 // K, cfg16.n_heads // K
    real = np.concatenate([np.arange(k * gp, k * gp + g_real)
                           for k in range(K)])            # [0,1, 8,9]
    p4 = jax.tree.map(lambda x: x, p16)
    for slot in p4["slots"]:
        if "wq" in slot:
            slot["wq"] = slot["wq"][:, :, real]
            slot["wo"] = slot["wo"][:, real]
    tok = jnp.asarray(rng.randint(0, cfg16.vocab_size, (B, S)), jnp.int32)
    l16 = np.asarray(_logits_full(cfg16, p16, tok), np.float32)
    l4 = np.asarray(_logits_full(cfg4, p4, tok), np.float32)
    np.testing.assert_allclose(l16, l4, atol=1e-4, rtol=1e-3)


def test_local_equals_global_when_window_covers(rng):
    """Sliding-window attention == global attention when window >= seq."""
    from repro.models import layers as L
    q = jnp.asarray(rng.randn(2, 64, 4, 32), jnp.float32)
    k = jnp.asarray(rng.randn(2, 64, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(2, 64, 2, 32), jnp.float32)
    o_local = L.local_block_attention(q, k, v, window=64)
    o_global = L.flash_attention(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(o_local), np.asarray(o_global),
                               atol=1e-4, rtol=1e-3)


def test_moe_routes_and_balances(rng):
    from repro.models.moe import moe_mlp
    d, E, f = 32, 4, 64
    x = jnp.asarray(rng.randn(2, 128, d), jnp.float32)
    router = jnp.asarray(rng.randn(d, E), jnp.float32)
    wg = jnp.asarray(rng.randn(E, d, f) * 0.05, jnp.float32)
    wu = jnp.asarray(rng.randn(E, d, f) * 0.05, jnp.float32)
    wd = jnp.asarray(rng.randn(E, f, d) * 0.05, jnp.float32)
    y, aux = moe_mlp(x, router, wg, wu, wd, n_experts=E, k=2,
                     group_size=64)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert 0.0 < float(aux) < 10.0  # load-balance loss in sane range
    # capacity sufficiency: with cf=1.25 and uniform-ish routing most tokens
    # must be served (output nonzero)
    nz = np.mean(np.abs(np.asarray(y)) > 1e-8)
    assert nz > 0.5


def test_moe_scatter_equals_einsum(rng):
    """The scatter router must match the GShard einsum router exactly,
    including capacity drops (same assignment order)."""
    from repro.models.moe import moe_mlp, moe_mlp_scatter
    d, E, f, k = 32, 8, 64, 2
    x = jnp.asarray(rng.randn(2, 128, d), jnp.float32)
    router = jnp.asarray(rng.randn(d, E), jnp.float32)
    wg = jnp.asarray(rng.randn(E, d, f) * 0.05, jnp.float32)
    wu = jnp.asarray(rng.randn(E, d, f) * 0.05, jnp.float32)
    wd = jnp.asarray(rng.randn(E, f, d) * 0.05, jnp.float32)
    for cf in (1.25, 0.5):  # ample and drop-inducing capacity
        kw = dict(n_experts=E, k=k, group_size=64, capacity_factor=cf)
        y1, a1 = moe_mlp(x, router, wg, wu, wd, **kw)
        y2, a2 = moe_mlp_scatter(x, router, wg, wu, wd, **kw)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-5, rtol=1e-5)
        assert float(jnp.abs(a1 - a2)) < 1e-6


def test_remat_block_equivalence(rng):
    """remat_block=k must not change the training math (same loss & grads)."""
    from dataclasses import replace
    from repro.models.steps import make_train_step
    from repro.optim import adamw
    base = replace(C.get("minitron-8b").reduced(), n_layers=4, remat=True)
    tok = jnp.asarray(rng.randint(0, base.vocab_size, (2, 32)), jnp.int32)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    params = materialize(T.build_specs(base), jax.random.key(3), jnp.float32)
    opt_cfg = adamw.AdamWConfig()
    results = []
    for k in (1, 2, 4):
        cfg = replace(base, remat_block=k)
        opt = adamw.init_opt_state(opt_cfg, params)
        step = jax.jit(make_train_step(cfg, opt_cfg, 1))
        p2, _, m, _ = step(params, opt, batch)
        results.append((float(m["loss"]), float(m["grad_norm"]),
                        np.asarray(jax.tree.leaves(p2)[0])))
    for loss, gnorm, leaf in results[1:]:
        # f32 reduction order differs across the k-blocked HLOs
        assert abs(loss - results[0][0]) < 1e-4
        assert abs(gnorm - results[0][1]) / results[0][1] < 1e-3
        np.testing.assert_allclose(leaf, results[0][2], atol=1e-4)
