"""The closed elasticity loop: telemetry bus snapshots, race-free per-sender
broker stats, ElasticController policies (scale up/down, batch-cap
adaptation), Session-owned control-plane lifecycle, and detector-driven
endpoint failover.

Timing-sensitive tests run on a ``VirtualClock``: waits are condition polls
on simulated time (no real sleeping, no flake); where a real wall-clock
pipeline is the point, waits go through ``Clock.wait`` condition polling
instead of hand-rolled deadline/sleep loops."""
import threading

import numpy as np
import pytest

from repro.core.broker import Broker, BrokerConfig
from repro.core.grouping import GroupPlan
from repro.runtime.clock import VirtualClock, ensure_clock
from repro.runtime.controller import (Action, BatchCapPolicy,
                                      ElasticController, ElasticityConfig,
                                      LatencyScalePolicy, TrendScalePolicy)
from repro.runtime.fault import FailureDetector
from repro.runtime.telemetry import TelemetryBus, TelemetrySnapshot
from repro.streaming.endpoint import make_endpoints
from repro.streaming.engine import StreamEngine
from repro.workflow import Session, WorkflowConfig


# ------------------------------------------------- race-free per-sender stats
def test_broker_stats_exact_under_concurrent_writers():
    """All counters must be exact when many producer threads hammer the same
    group sender (the seed's shared unlocked dataclass lost += updates)."""
    eps = make_endpoints(1)
    broker = Broker(GroupPlan(8, 1, 1), eps,
                    BrokerConfig(compress="none", backpressure="block",
                                 queue_capacity=4096))
    n_threads, per_thread = 8, 400
    payload = np.zeros(16, np.float32)

    def hammer(rank):
        for s in range(per_thread):
            broker.write("f", rank, s, payload)

    threads = [threading.Thread(target=hammer, args=(r,))
               for r in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stats = broker.finalize()
    total = n_threads * per_thread
    assert stats.written == total
    assert stats.sent + stats.dropped == total
    assert stats.dropped == 0 and stats.sent == total
    assert eps[0].handle.records_in == total


def test_broker_group_telemetry_shape():
    eps = make_endpoints(2)
    broker = Broker(GroupPlan(4, 2, 1), eps, BrokerConfig(compress="none"))
    for r in range(4):
        broker.write("f", r, 0, np.zeros(4, np.float32))
    broker.flush()
    rows = broker.group_telemetry()
    assert [r["group"] for r in rows] == [0, 1]
    for row in rows:
        assert row["written"] == 2 and row["sent"] == 2
        assert row["batch_cap"] == broker.cfg.max_batch_records
        assert row["queue_depth"] == 0
    broker.finalize()


def test_broker_set_batch_cap_and_reroute():
    eps = make_endpoints(2)
    broker = Broker(GroupPlan(4, 2, 1), eps, BrokerConfig(compress="none"))
    broker.set_batch_cap(64)
    assert all(r["batch_cap"] == 64 for r in broker.group_telemetry())
    broker.set_batch_cap(4, group=1)
    assert [r["batch_cap"] for r in broker.group_telemetry()] == [64, 4]
    # proactive failover off a dead endpoint
    eps[0].handle.fail()
    moved = broker.reroute_from_endpoint(0)
    assert moved == 1                       # group 0's primary was endpoint 0
    assert all(s.primary == 1 for s in broker._senders.values())
    broker.finalize()


# ------------------------------------------------------------- telemetry bus
def _slow_analyzer(cost=0.005, clock=None):
    clk = ensure_clock(clock)

    def analyze(key, recs):
        if cost:
            clk.sleep(cost * len(recs))
        return len(recs)
    return analyze


def test_telemetry_snapshot_covers_all_layers():
    clk = VirtualClock()
    clk.attach()
    cfg = WorkflowConfig(n_producers=2, n_groups=1, executors_per_group=2,
                         compress="none", trigger_interval=0.05, min_batch=1)
    with Session(cfg, analyze=_slow_analyzer(0.0), clock=clk) as sess:
        h = sess.open_field("f", shape=(8,))
        bus = TelemetryBus(broker=sess.broker,
                           endpoints=[e.handle for e in sess.endpoints],
                           engine=sess.engine, clock=clk)
        for s in range(6):
            h.write_batch(s, [np.zeros(8, np.float32)] * 2, ranks=[0, 1])
        sess.flush()
        assert clk.wait(lambda: sess.engine.metrics()["n_results"] > 0,
                        timeout=5.0)
        snap = bus.sample()
    assert isinstance(snap, TelemetrySnapshot)
    assert len(snap.groups) == 1 and snap.groups[0].written == 12
    assert len(snap.endpoints) == 1 and snap.endpoints[0].records_in == 12
    assert snap.alive_executors == 2
    assert snap.latency_n > 0 and snap.latency_p99 >= 0
    assert bus.last() is snap and snap in bus.history


def test_telemetry_rates_from_sample_deltas():
    clk = VirtualClock()
    clk.attach()
    eps = make_endpoints(1, clock=clk)
    broker = Broker(GroupPlan(1, 1, 1), eps,
                    BrokerConfig(compress="none", queue_capacity=4,
                                 backpressure="drop_oldest"), clock=clk)
    bus = TelemetryBus(broker=broker, endpoints=[e.handle for e in eps],
                       clock=clk)
    bus.sample()
    eps[0].handle.fail()                    # queue fills -> drops accumulate
    for s in range(64):
        broker.write("f", 0, s, np.zeros(4, np.float32))
    clk.sleep(0.1)                          # a dt>0 between rate samples
    snap = bus.sample()
    assert snap.groups[0].dropped > 0
    assert snap.groups[0].drop_rate > 0
    eps[0].handle.recover()
    broker.finalize()
    clk.detach()


def test_endpoint_ingest_rate_counter():
    eps = make_endpoints(1)
    broker = Broker(GroupPlan(1, 1, 1), eps, BrokerConfig(compress="none"))
    for s in range(20):
        broker.write("f", 0, s, np.zeros(4, np.float32))
    broker.flush()
    h = eps[0].handle
    assert h.ingest_rate(window_s=10.0) > 0
    t = h.telemetry()
    assert t["records_in"] == 20 and t["healthy"] and t["pending"] == 20
    broker.finalize()


# ------------------------------------------------------- config block
def test_elasticity_config_validation():
    with pytest.raises(ValueError, match="min_executors"):
        ElasticityConfig(min_executors=5, max_executors=2).validate()
    with pytest.raises(ValueError, match="interval_s"):
        ElasticityConfig(interval_s=0).validate()
    with pytest.raises(ValueError, match="batch_cap"):
        ElasticityConfig(batch_cap_min=8, batch_cap_max=2).validate()
    with pytest.raises(ValueError, match="target_p99_s"):
        WorkflowConfig(elasticity=ElasticityConfig(target_p99_s=-1)).validate()


def test_workflow_config_roundtrip_with_elasticity():
    cfg = WorkflowConfig(
        n_producers=4, n_groups=2,
        elasticity=ElasticityConfig(enabled=True, target_p99_s=0.7,
                                    max_executors=9)).validate()
    d = cfg.to_dict()
    assert isinstance(d["elasticity"], dict)        # JSON-serializable
    back = WorkflowConfig.from_dict(d)
    assert back == cfg
    assert back.elasticity.max_executors == 9
    with pytest.raises(ValueError, match="unknown ElasticityConfig keys"):
        WorkflowConfig.from_dict(
            {"n_producers": 2, "elasticity": {"wat": 1}})


def test_workflow_config_clock_knob():
    cfg = WorkflowConfig(clock="virtual", clock_seed=7).validate()
    assert cfg.make_clock().virtual
    d = cfg.to_dict()
    assert d["clock"] == "virtual" and d["clock_seed"] == 7
    assert WorkflowConfig.from_dict(d) == cfg
    with pytest.raises(ValueError, match="clock"):
        WorkflowConfig(clock="sundial").validate()
    # virtual time now composes with the loopback transport (the frames go
    # through VirtualLoopbackTransport instead of real sockets)
    WorkflowConfig(clock="virtual", transport="loopback").validate()
    assert not WorkflowConfig().make_clock().virtual


# ------------------------------------------------------- controller policies
def _mk_loop(n_exec=1, cost=0.02, el=None, n_eps=1, clock=None):
    clk = ensure_clock(clock)
    eps = make_endpoints(n_eps, clock=clk)
    plan = GroupPlan(n_producers=2, n_groups=n_eps, executors_per_group=2)
    broker = Broker(plan, eps, BrokerConfig(compress="none",
                                            backpressure="block",
                                            queue_capacity=4096), clock=clk)
    eng = StreamEngine([e.handle for e in eps],
                       _slow_analyzer(cost, clock=clk),
                       n_exec, trigger_interval=0.02, min_batch=1, clock=clk)
    bus = TelemetryBus(broker=broker, endpoints=[e.handle for e in eps],
                       engine=eng, clock=clk)
    el = el or ElasticityConfig(enabled=True, interval_s=0.02,
                                target_p99_s=0.2, backlog_high=8,
                                min_executors=1, max_executors=4,
                                cooldown_s=0.0, idle_scale_down_s=0.05)
    ctl = ElasticController(bus, el, engine=eng, broker=broker, clock=clk)
    return broker, eps, eng, bus, ctl


def test_controller_scales_up_on_backlog_breach():
    clk = VirtualClock()
    clk.attach()
    broker, eps, eng, bus, ctl = _mk_loop(n_exec=1, cost=0.05, clock=clk)
    for s in range(40):
        broker.write("f", 0, s, np.zeros(8, np.float32))
    broker.flush()

    def pump():
        eng.trigger_once()
        ctl.tick()
        return eng.metrics()["alive_executors"] > 1

    assert clk.wait(pump, timeout=5.0, poll=0.02)
    kinds = [a.kind for _, a in ctl.actions_log]
    assert "scale_up" in kinds
    eng.drain_and_stop()
    broker.finalize()
    clk.detach()


def test_controller_scales_down_when_idle():
    clk = VirtualClock()
    clk.attach()
    broker, eps, eng, bus, ctl = _mk_loop(n_exec=3, cost=0.0, clock=clk)

    def pump():
        ctl.tick()
        return eng.metrics()["alive_executors"] <= 1

    assert clk.wait(pump, timeout=5.0, poll=0.03)
    assert eng.metrics()["alive_executors"] == 1      # min_executors floor
    assert [a.kind for _, a in ctl.actions_log].count("scale_down") == 2
    eng.drain_and_stop()
    broker.finalize()
    clk.detach()


def test_batch_cap_policy_follows_queue_depth():
    el = ElasticityConfig(enabled=True, batch_cap_min=1, batch_cap_max=128)
    policy = BatchCapPolicy(el, baseline=8)
    # a slow endpoint (token-bucket bandwidth model) makes the sender's
    # queue build up while everything still delivers eventually
    eps = make_endpoints(1, inbound_bw=20_000)
    broker = Broker(GroupPlan(1, 1, 1), eps,
                    BrokerConfig(compress="none", queue_capacity=2048,
                                 backpressure="block", max_batch_records=8))
    bus = TelemetryBus(broker=broker, endpoints=[e.handle for e in eps])
    for s in range(256):
        broker.write("f", 0, s, np.zeros(1024, np.float32))
    acts = policy.decide(bus.sample(), bus.history)
    assert acts and acts[0].kind == "set_batch_cap" and acts[0].value > 8
    broker.set_batch_cap(acts[0].value, group=acts[0].group)
    assert broker.group_telemetry()[0]["batch_cap"] == acts[0].value
    broker.flush(timeout=60)
    # queue drained: cap decays back toward the baseline
    acts = policy.decide(bus.sample(), bus.history)
    assert acts and acts[0].kind == "set_batch_cap"
    assert acts[0].value < broker.group_telemetry()[0]["batch_cap"]
    broker.finalize()


def test_latency_policy_cooldown_and_bounds():
    el = ElasticityConfig(enabled=True, target_p99_s=0.1, cooldown_s=3600,
                          max_executors=2)
    pol = LatencyScalePolicy(el)
    # small t (a virtual-time origin): the FIRST breach must scale even
    # though t < cooldown_s — cooldown only gates scale-to-scale gaps
    breach = TelemetrySnapshot(t=1.0, latency_p50=1.0,
                               latency_p99=1.0, latency_n=10,
                               alive_executors=1)
    acts = pol.decide(breach, [])
    assert len(acts) == 1 and acts[0].kind == "scale_up"
    # cooldown: immediate second breach does nothing
    assert pol.decide(breach, []) == []
    # at max_executors: no scale-up even on breach
    pol2 = LatencyScalePolicy(el)
    at_max = TelemetrySnapshot(t=1.0, latency_p99=1.0, latency_n=10,
                               alive_executors=2)
    assert pol2.decide(at_max, []) == []


def test_trend_policy_scales_before_the_breach():
    """Predictive scale-up: a rising-but-not-yet-breaching p99 series whose
    projection crosses the target within the horizon must trigger, while
    flat sub-target series must not."""
    el = ElasticityConfig(enabled=True, predictive=True, target_p99_s=1.0,
                          trend_window=6, trend_horizon_s=2.0,
                          cooldown_s=0.0, backlog_high=1_000_000)

    def snaps(vals, t0=0.0, dt=0.1):
        return [TelemetrySnapshot(t=t0 + i * dt, latency_p99=v,
                                  latency_n=10, alive_executors=1)
                for i, v in enumerate(vals)]

    pol = TrendScalePolicy(el)
    rising = snaps([0.3, 0.4, 0.5, 0.6, 0.7])      # slope 1.0/s, proj 2.7
    acts = pol.decide(rising[-1], rising)
    assert len(acts) == 1 and acts[0].kind == "scale_up"
    assert acts[0].reason.startswith("projected")
    assert rising[-1].latency_p99 < el.target_p99_s    # fired PRE-breach

    flat = snaps([0.5] * 5)
    assert TrendScalePolicy(el).decide(flat[-1], flat) == []
    falling = snaps([0.9, 0.8, 0.7, 0.6, 0.5])
    assert TrendScalePolicy(el).decide(falling[-1], falling) == []
    # too little history for a slope: no action
    short = snaps([0.3, 0.9])
    assert TrendScalePolicy(el).decide(short[-1], short) == []


def test_trend_policy_projects_backlog_growth():
    el = ElasticityConfig(enabled=True, predictive=True, backlog_high=64,
                          trend_window=5, trend_horizon_s=1.0, cooldown_s=10.0)
    grow = [TelemetrySnapshot(t=i * 0.1, alive_executors=1,
                              executors=(), held_records=i * 10)
            for i in range(5)]                      # backlog 0..40, +100/s
    pol = TrendScalePolicy(el)
    acts = pol.decide(grow[-1], grow)
    assert len(acts) == 1 and acts[0].kind == "scale_up"
    assert "backlog" in acts[0].reason
    # cooldown respected on the very next tick
    assert pol.decide(grow[-1], grow) == []


def test_predictive_plus_reactive_respect_max_executors():
    """Both scale policies deciding off the same stale snapshot must not
    double the step or push the fleet past max_executors: one scale-up per
    tick, and _apply clamps to the cap."""
    clk = VirtualClock()
    clk.attach()
    el = ElasticityConfig(enabled=True, interval_s=0.02, target_p99_s=0.01,
                          min_executors=1, max_executors=3, scale_up_step=2,
                          cooldown_s=0.0, predictive=True, trend_window=3,
                          trend_horizon_s=1.0, backlog_high=1)
    broker, eps, eng, bus, ctl = _mk_loop(n_exec=1, cost=0.05, el=el,
                                          clock=clk)
    assert len(ctl.policies) >= 3           # Trend + Latency + BatchCap
    for s in range(60):                     # saturate: p99 + backlog breach
        broker.write("f", 0, s, np.zeros(8, np.float32))
    broker.flush()
    for _ in range(30):
        eng.trigger_once()
        ctl.tick()
        assert eng.metrics()["alive_executors"] <= el.max_executors, \
            "scale-up overshot max_executors"
        clk.sleep(0.02)
    ups = [a for _, a in ctl.actions_log if a.kind == "scale_up"]
    assert ups, "saturated pipeline must scale up"
    assert eng.metrics()["alive_executors"] == el.max_executors
    eng.drain_and_stop()
    broker.finalize()
    clk.detach()


def test_trend_policy_validation():
    with pytest.raises(ValueError, match="trend_window"):
        ElasticityConfig(trend_window=2).validate()
    with pytest.raises(ValueError, match="trend_horizon_s"):
        ElasticityConfig(trend_horizon_s=0.0).validate()


def test_predictive_spike_scales_before_reactive_on_virtual_time():
    """The ROADMAP claim end-to-end: under a ramping load on virtual time,
    the predictive controller's first scale-up lands EARLIER than the
    reactive controller's, before the p99 target is breached."""
    from repro.sim.scenario import LoadPhase, Scenario, ScenarioRunner

    def run(predictive: bool):
        wf = WorkflowConfig(
            n_producers=4, n_groups=2, executors_per_group=2,
            compress="none", backpressure="block", queue_capacity=4096,
            trigger_interval=0.05, min_batch=4, n_executors=1,
            max_batch_records=8, clock="virtual",
            elasticity=ElasticityConfig(
                enabled=True, interval_s=0.1, target_p99_s=1.5,
                min_executors=1, max_executors=4, scale_up_step=2,
                backlog_high=24, idle_scale_down_s=2.0, cooldown_s=0.3,
                predictive=predictive, trend_window=5, trend_horizon_s=1.0))
        sc = Scenario(workflow=wf,
                      phases=(LoadPhase("low", 2.0, 5.0),
                              LoadPhase("ramp1", 1.5, 20.0),
                              LoadPhase("ramp2", 1.5, 40.0),
                              LoadPhase("spike", 3.0, 60.0),
                              LoadPhase("low", 2.0, 5.0)),
                      seed=0, analysis_cost_s=0.008, payload_elems=64)
        return ScenarioRunner(sc).run()

    reactive, predictive = run(False), run(True)
    def first_scale_up(trace):
        ts = [t for t, d in trace.events_of("action")
              if d["kind"] == "scale_up"]
        return min(ts) if ts else float("inf")

    t_pred, t_react = first_scale_up(predictive), first_scale_up(reactive)
    assert t_pred < float("inf"), "predictive run never scaled"
    assert t_pred < t_react, (
        f"predictive first scale-up at {t_pred}s not earlier than "
        f"reactive at {t_react}s")
    assert any(d["reason"].startswith("projected")
               for _t, d in predictive.events_of("action")
               if d["kind"] == "scale_up")
    # QoS: the predictive run must hold the target through the spike
    assert predictive.phase_p99("spike") <= 1.5


def test_slow_uniform_analysis_is_not_declared_dead():
    """A single analyze call longer than heartbeat_timeout_s must not get a
    healthy executor replaced: busy-mid-analysis is revived by the
    controller (up to stuck_analysis_s), and with uniformly slow peers the
    straggler median flags nobody.  Virtual time: the 4 "seconds" of slow
    uniform analysis cost milliseconds of wall time."""
    clk = VirtualClock()
    clk.attach()
    eps = make_endpoints(1, clock=clk)
    plan = GroupPlan(n_producers=2, n_groups=1, executors_per_group=1)
    broker = Broker(plan, eps, BrokerConfig(compress="none",
                                            backpressure="block",
                                            queue_capacity=4096), clock=clk)
    eng = StreamEngine([e.handle for e in eps],
                       _slow_analyzer(0.4, clock=clk),
                       n_executors=2, trigger_interval=0.03, min_batch=1,
                       clock=clk)
    bus = TelemetryBus(broker=broker, endpoints=[e.handle for e in eps],
                       engine=eng, clock=clk)
    el = ElasticityConfig(enabled=True, interval_s=0.05,
                          heartbeat_timeout_s=0.15, idle_scale_down_s=3600,
                          target_p99_s=3600, backlog_high=10_000)
    ctl = ElasticController(bus, el, engine=eng, broker=broker, clock=clk)
    deadline = clk.now() + 4.0
    step = 0
    while clk.now() < deadline:
        for r in range(2):
            broker.write("f", r, step, np.zeros(4, np.float32))
        step += 1
        ctl.tick()
        clk.sleep(0.05)
    assert not any(a.kind == "replace_executor"
                   for _, a in ctl.actions_log), \
        "healthy-but-slow executors must not be churned"
    assert all(e.alive for e in eng.executors)
    broker.flush()
    eng.drain_and_stop(timeout=30)
    broker.finalize()
    clk.detach()


# ------------------------------------------- Session-owned control plane
def test_session_owns_controller_lifecycle():
    cfg = WorkflowConfig(
        n_producers=2, n_groups=1, executors_per_group=1, compress="none",
        trigger_interval=0.05, min_batch=1,
        elasticity=ElasticityConfig(enabled=True, interval_s=0.05))
    sess = Session(cfg, analyze=_slow_analyzer(0.0))
    assert sess.controller is not None and sess.controller.is_alive()
    assert sess.telemetry is not None and sess.detector is not None
    h = sess.open_field("f", shape=(4,))
    for s in range(4):
        h.write(s, np.zeros(4, np.float32), rank=s % 2)
    sess.flush()
    stats = sess.close()
    # ordered teardown: controller stopped first, then broker drained
    assert not sess.controller.is_alive()
    assert stats.sent == 4 and stats.dropped == 0
    assert sess.close().sent == 4           # idempotent
    # telemetry accumulated while running
    assert len(sess.telemetry.history) > 0


def test_session_without_elasticity_has_no_control_plane():
    cfg = WorkflowConfig(n_producers=1, n_groups=1, executors_per_group=1,
                         compress="none")
    with Session(cfg, analyze=_slow_analyzer(0.0)) as sess:
        assert sess.controller is None and sess.telemetry is None


def test_endpoint_failure_detected_and_recovered_no_drops():
    """Acceptance: a mid-run endpoint death is detected via missed
    heartbeats (not just send-path retries), the controller proactively
    re-routes the group, and nothing is dropped under block backpressure.
    Runs on virtual time via the config's clock knob — deterministic and
    milliseconds of wall clock."""
    cfg = WorkflowConfig(
        n_producers=4, n_groups=2, executors_per_group=1, compress="none",
        backpressure="block", queue_capacity=1024, trigger_interval=0.05,
        min_batch=1, clock="virtual",
        elasticity=ElasticityConfig(enabled=True, interval_s=0.05,
                                    heartbeat_timeout_s=0.3,
                                    idle_scale_down_s=3600))
    seen: dict[str, list[int]] = {}
    lock = threading.Lock()

    def analyze(key, records):
        with lock:
            seen.setdefault(key, []).extend(r.step for r in records)
        return len(records)

    sess = Session(cfg, analyze=analyze)
    clk = sess.clock
    h = sess.open_field("f", shape=(8,))
    n_steps = 30
    for s in range(n_steps):
        h.write_batch(s, [np.full(8, float(s), np.float32)] * 4,
                      ranks=[0, 1, 2, 3])
        if s == n_steps // 2:
            sess.endpoints[0].handle.fail()
        clk.sleep(0.02)

    # detector flags the dead endpoint; controller reroutes proactively
    def ep0_flagged():
        node = sess.detector.nodes.get("ep0")
        return node is not None and not node.alive

    assert clk.wait(ep0_flagged, timeout=5.0, poll=0.02)
    sess.flush()
    stats = sess.close()
    assert any(a.kind == "reroute_endpoint"
               for _, a in sess.controller.actions_log)
    assert stats.dropped == 0
    assert stats.sent == stats.written == 4 * n_steps
    for key, steps in seen.items():
        assert steps == sorted(steps), f"stream {key} reordered"
        assert len(steps) == n_steps
