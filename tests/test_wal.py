"""WAL durability properties: append/ack/trim invariants under random
operation interleavings (property-style, seeded), torn-tail recovery of the
durable serialization, and the receive-side SeqLedger dedupe contract."""
import random
import zlib

import pytest

from repro.runtime.wal import FileWalStore, SeqLedger, WalSegment, WalStore


def _pointers_ordered(seg: WalSegment) -> None:
    p = seg.points()
    assert 0 <= p["base"] <= p["acked"] <= p["shipped"] <= p["last"]
    assert p["committed"] <= p["last"]
    # base never trims past the retention point
    point = p["acked"] if seg.retain == "ack" \
        else min(p["acked"], p["committed"])
    assert p["base"] <= point or p["last"] == 0


# --------------------------------------------------------------- basic cycle
def test_append_fetch_ack_roundtrip():
    seg = WalSegment(0, capacity_bytes=1 << 16, max_pending=64)
    blobs = [bytes([i]) * (i + 1) for i in range(10)]
    seqs = [seg.try_append(b) for b in blobs]
    assert seqs == list(range(1, 11))          # seqs start at 1, contiguous
    got = seg.fetch_unshipped(4)
    assert [e.seq for e in got] == [1, 2, 3, 4]
    assert [e.blob for e in got] == blobs[:4]
    assert seg.unshipped_count() == 6
    seg.ack(4)
    assert seg.unacked_count() == 6
    assert seg.points()["base"] == 4           # retain="ack": acked trimmed
    rest = seg.fetch_unshipped(100)
    assert [e.seq for e in rest] == [5, 6, 7, 8, 9, 10]
    seg.ack(10)
    assert seg.bytes_used() == 0 and seg.unacked_count() == 0
    _pointers_ordered(seg)


def test_capacity_and_pending_bounds_refuse_appends():
    seg = WalSegment(0, capacity_bytes=64, max_pending=4)
    assert seg.try_append(b"x" * 60) is not None
    assert seg.try_append(b"y" * 10) is None       # over byte capacity
    seg.ack(1)                                     # trim frees the bytes
    for i in range(4):
        assert seg.try_append(b"a") is not None
    assert seg.try_append(b"b") is None            # max_pending unshipped
    seg.fetch_unshipped(4)
    assert seg.try_append(b"b") is not None        # shipping frees the slot


def test_oversized_single_record_is_always_accepted():
    # a record larger than capacity must not wedge the log forever: the
    # bound applies to the *backlog*, a lone append always fits
    seg = WalSegment(0, capacity_bytes=16, max_pending=8)
    assert seg.try_append(b"z" * 100) is not None


def test_rewind_shipped_replays_unacked_tail():
    seg = WalSegment(0)
    for i in range(6):
        seg.try_append(bytes([i]))
    seg.fetch_unshipped(6)
    seg.ack(2)
    assert seg.rewind_shipped() == 4
    assert [e.seq for e in seg.fetch_unshipped(10)] == [3, 4, 5, 6]


def test_commit_retention_keeps_acked_tail_until_commit():
    seg = WalSegment(0, retain="commit")
    for i in range(8):
        seg.try_append(bytes([i]))
    seg.fetch_unshipped(8)
    seg.ack(8)
    assert seg.points()["base"] == 0               # acked but NOT committed
    assert seg.reset_acked_to_commit() == 8        # a restore replays all 8
    assert [e.seq for e in seg.fetch_unshipped(10)] == list(range(1, 9))
    seg.ack(8)
    seg.commit(5)
    assert seg.points()["base"] == 5               # min(acked, committed)
    _pointers_ordered(seg)


# ----------------------------------------------------- seeded property sweep
@pytest.mark.parametrize("seed", range(8))
def test_random_interleavings_preserve_invariants(seed):
    """Random append/fetch/ack/commit/rewind sequences: pointers stay
    ordered, fetched seqs are exactly the gap-free unshipped range, and
    every appended blob is either still retained or was acked past."""
    rng = random.Random(seed)
    retain = rng.choice(("ack", "commit"))
    seg = WalSegment(0, capacity_bytes=1 << 12, max_pending=32,
                     retain=retain)
    appended: dict[int, bytes] = {}
    shipped: list[int] = []
    for _ in range(400):
        op = rng.randrange(6)
        if op <= 1:
            blob = bytes(rng.randrange(256) for _ in range(rng.randrange(1, 24)))
            seq = seg.try_append(blob)
            if seq is not None:
                assert seq == max(appended, default=0) + 1   # monotonic
                appended[seq] = blob
        elif op == 2:
            before = seg.points()
            got = seg.fetch_unshipped(rng.randrange(1, 8))
            for e in got:
                assert appended[e.seq] == e.blob             # no corruption
            seqs = [e.seq for e in got]
            # a fetch hands out exactly the gap-free range above the
            # shipped pointer — never trimmed entries, never a skip
            want = min(len(seqs), before["last"] - before["shipped"])
            assert seqs == list(range(before["shipped"] + 1,
                                      before["shipped"] + 1 + want))
            assert all(s > before["base"] for s in seqs)
            shipped.extend(seqs)
        elif op == 3 and shipped:
            seg.ack(rng.choice(shipped))
        elif op == 4 and shipped:
            seg.commit(rng.choice(shipped))
        elif op == 5:
            seg.rewind_shipped()
        _pointers_ordered(seg)
    p = seg.points()
    # everything not yet trimmed must still be retrievable, in order
    seg.rewind_shipped()
    tail = seg.fetch_unshipped(10_000)
    assert [e.seq for e in tail] == list(range(p["acked"] + 1, p["last"] + 1))
    for e in tail:
        assert appended[e.seq] == e.blob


# -------------------------------------------------------- durable round-trip
def _filled_segment(retain="commit"):
    seg = WalSegment(3, retain=retain)
    for i in range(12):
        seg.try_append(bytes([i]) * (i + 3))
    seg.fetch_unshipped(12)
    seg.ack(7)
    seg.commit(4)
    return seg


def test_serialization_roundtrip_preserves_entries_and_pointers():
    seg = _filled_segment()
    back = WalSegment.from_bytes(seg.to_bytes(), retain="commit")
    assert back.points() == {**seg.points(), "shipped": seg.points()["acked"]}
    back.rewind_shipped()
    a = [(e.seq, e.blob) for e in back.fetch_unshipped(100)]
    seg.rewind_shipped()
    b = [(e.seq, e.blob) for e in seg.fetch_unshipped(100)]
    assert a == b


@pytest.mark.parametrize("seed", range(6))
def test_truncated_tail_recovers_prefix_not_garbage(seed):
    """Cut the serialized log at a random byte (a crash mid-write): recovery
    must yield a clean contiguous prefix — never an exception, never a
    record whose bytes differ from what was appended."""
    seg = _filled_segment()
    data = seg.to_bytes()
    rng = random.Random(seed)
    cut = rng.randrange(len(b"WALSEG1\n") + 28, len(data))
    back = WalSegment.from_bytes(data[:cut], retain="commit")
    p = seg.points()
    q = back.points()
    assert q["base"] == p["base"]
    assert q["last"] <= p["last"]                  # only the tail is lost
    assert q["acked"] <= p["acked"] and q["committed"] <= p["committed"]
    back.rewind_shipped()
    for e in back.fetch_unshipped(100):
        assert e.blob == bytes([e.seq - 1]) * (e.seq + 2)   # intact bytes


def test_corrupt_tail_crc_discards_only_the_bad_suffix():
    seg = _filled_segment()
    data = bytearray(seg.to_bytes())
    data[-1] ^= 0xFF                               # flip a payload byte
    back = WalSegment.from_bytes(bytes(data), retain="commit")
    assert back.points()["last"] == seg.points()["last"] - 1
    # CRC actually protects the payload, not just the length
    assert zlib.crc32 is not None


def test_from_bytes_rejects_bad_magic():
    with pytest.raises(ValueError, match="magic"):
        WalSegment.from_bytes(b"NOTAWAL\n" + b"\x00" * 64)


# ----------------------------------------------------------------- the store
def test_store_segments_share_limits_and_survive_reset():
    store = WalStore(capacity_bytes=1 << 12, queue_capacity=8,
                     retain="commit")
    a, b = store.segment(0), store.segment(1)
    assert store.segment(0) is a                   # create-on-demand, cached
    for i in range(5):
        a.try_append(b"a")
        b.try_append(b"b")
    a.fetch_unshipped(5)
    a.ack(5)
    b.fetch_unshipped(3)
    b.ack(3)
    assert store.unacked_records() == 2
    a.commit(2)
    assert store.reset_for_restore() == 3 + 5      # a: 5-2 committed, b: 5-0
    assert store.unacked_records() == 8
    assert sorted(store.points()) == [0, 1]


def test_store_rejects_bad_retain():
    with pytest.raises(ValueError, match="retain"):
        WalStore(retain="forever")


# --------------------------------------------------------- disk-backed store
def test_file_store_sync_then_adopt(tmp_path):
    store = FileWalStore(tmp_path, capacity_bytes=1 << 12, queue_capacity=8,
                         retain="commit")
    a, b = store.segment(0), store.segment(2)
    for i in range(5):
        a.try_append(bytes([i]) * 3)
        b.try_append(bytes([i + 16]))
    a.fetch_unshipped(5)
    a.ack(3)
    a.commit(2)
    assert store.sync() > 0
    assert not list(tmp_path.glob("*.tmp"))          # atomic: no temp debris
    assert sorted(p.name for p in tmp_path.glob("group-*.wal")) == \
        ["group-00000.wal", "group-00002.wal"]

    adopted = FileWalStore(tmp_path, capacity_bytes=1 << 12,
                           queue_capacity=8, retain="commit")
    assert adopted.groups() == [0, 2]
    pa = adopted.segment(0).points()
    # retain="commit" kept entries past commit=2; acked pointer survived,
    # shipped rewound to acked so the unacked tail is fetchable again
    assert (pa["base"], pa["acked"], pa["committed"], pa["last"]) == (2, 3, 2, 5)
    assert [e.blob for e in adopted.segment(0).fetch_unshipped(10)] == \
        [bytes([3]) * 3, bytes([4]) * 3]
    assert adopted.reset_for_restore() == 3 + 5      # acked rewinds to commit
    assert [e.seq for e in adopted.segment(0).fetch_unshipped(10)] == [3, 4, 5]
    assert [e.blob for e in adopted.segment(2).fetch_unshipped(10)] == \
        [bytes([i + 16]) for i in range(5)]


def test_file_store_torn_tail_recovers_prefix(tmp_path):
    store = FileWalStore(tmp_path)
    seg = store.segment(1)
    for i in range(8):
        seg.try_append(bytes([i]) * 50)
    store.sync()
    path = tmp_path / "group-00001.wal"
    data = path.read_bytes()
    path.write_bytes(data[:-20])                     # crash mid final record
    adopted = FileWalStore(tmp_path)
    recovered = adopted.segment(1)
    assert recovered.points()["last"] == 7           # prefix intact, tail gone
    assert [e.blob for e in recovered.fetch_unshipped(10)] == \
        [bytes([i]) * 50 for i in range(7)]


def test_file_store_skips_unreadable_and_alien_files(tmp_path):
    (tmp_path / "group-00004.wal").write_bytes(b"not a wal segment")
    (tmp_path / "group-bogus.wal").write_bytes(b"xx")
    (tmp_path / "notes.txt").write_text("ignore me")
    store = FileWalStore(tmp_path)
    assert store.groups() == []                      # fresh logs, no crash
    seg = store.segment(4)
    seg.try_append(b"clean")
    store.sync()
    assert FileWalStore(tmp_path).segment(4).points()["last"] == 1


def test_wal_dir_requires_exactly_once():
    from repro.workflow import WorkflowConfig
    with pytest.raises(ValueError, match="wal_dir"):
        WorkflowConfig(wal_dir="/tmp/x").validate()


def test_session_wal_dir_persists_log_across_sessions(tmp_path):
    """An exactly-once Session with wal_dir syncs its WAL at close; a new
    Session over the same directory adopts the surviving segments."""
    import numpy as np

    from repro.workflow import Session, WorkflowConfig
    cfg = WorkflowConfig(n_producers=2, n_groups=1, executors_per_group=1,
                         compress="none", backpressure="block",
                         trigger_interval=0.05, delivery="exactly-once",
                         wal_dir=str(tmp_path / "wal"))
    with Session(cfg, analyze=lambda k, recs: len(recs)) as sess:
        h = sess.open_field("f", shape=(4,))
        for s in range(5):
            for r in range(2):
                assert h.write(s, np.zeros(4, np.float32), rank=r)
        sess.flush()
    assert sum(r.n_records for r in sess.results()) == 10
    wal_files = list((tmp_path / "wal").glob("group-*.wal"))
    assert wal_files, "close() never synced the WAL to disk"
    adopted = FileWalStore(tmp_path / "wal")
    assert adopted.groups() == [0]
    # everything shipped and acked before close: nothing left to replay
    assert adopted.segment(0).points()["last"] == 10
    assert adopted.unacked_records() == 0


# ---------------------------------------------------------------- the ledger
def test_seq_ledger_dedupes_replayed_prefixes():
    led = SeqLedger()
    assert led.admit(0, 1, 4) == 0                 # fresh frame: apply all
    assert led.applied(0) == 4
    assert led.admit(0, 1, 4) == 4                 # exact replay: whole dup
    assert led.admit(0, 3, 4) == 2                 # overlap: skip 3,4
    assert led.applied(0) == 6
    assert led.admit(1, 1, 2) == 0                 # groups are independent
    snap = led.snapshot()
    led2 = SeqLedger()
    led2.restore(snap)
    assert led2.applied(0) == 6 and led2.applied(1) == 2


def test_seq_ledger_mark_consumed_blocks_resurrection():
    led = SeqLedger()
    led.mark_consumed(0, 1, 3)                     # injected drop ate 1..3
    assert led.admit(0, 1, 3) == 3                 # replay must NOT re-apply
    assert led.admit(0, 4, 2) == 0
