"""Checkpointing: roundtrip, async atomicity, Q8 leaves, GC, deterministic
restart with the data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as T
from repro.models.modules import materialize
from repro.models.steps import make_train_step
from repro.optim import adamw


def test_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(tmp_path)
    tree = {"a": jnp.asarray(rng.randn(4, 8), jnp.float32),
            "b": {"c": jnp.arange(10, dtype=jnp.int32)},
            "q": adamw.q8_encode(jnp.asarray(rng.randn(8, 256), jnp.float32))}
    mgr.save(3, tree, blocking=True)
    got, step = mgr.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    np.testing.assert_array_equal(np.asarray(got["q"].data),
                                  np.asarray(tree["q"].data))
    assert got["q"].q == tree["q"].q


def test_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"w": jnp.ones((64, 64))}
    for s in range(5):
        mgr.save(s, tree, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_uncommitted_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.ones((4,))}
    mgr.save(1, tree, blocking=True)
    # fake a torn write
    d = tmp_path / "step_00000002"
    d.mkdir()
    (d / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1


def test_restart_is_bitwise_deterministic(tmp_path):
    """Train 4 steps; restart from step-2 checkpoint; steps 3-4 identical."""
    cfg = C.get("starcoder2-3b").reduced()
    params = materialize(T.build_specs(cfg), jax.random.key(0), jnp.float32)
    opt_cfg = adamw.AdamWConfig()
    opt = adamw.init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, 1))
    pipe = TokenPipeline(cfg, batch=2, seq=32)
    mgr = CheckpointManager(tmp_path)

    losses_a = []
    p, o = params, opt
    for s in range(4):
        if s == 2:
            mgr.save(s, {"params": p, "opt": o}, blocking=True)
        p, o, m, _ = step_fn(p, o, pipe.batch_at(s))
        losses_a.append(float(m["loss"]))
    final_a = np.asarray(jax.tree.leaves(p)[0])

    restored, s0 = mgr.restore({"params": params, "opt": opt})
    p, o = restored["params"], restored["opt"]
    losses_b = []
    for s in range(s0, 4):
        p, o, m, _ = step_fn(p, o, pipe.batch_at(s))
        losses_b.append(float(m["loss"]))
    final_b = np.asarray(jax.tree.leaves(p)[0])

    assert losses_b == losses_a[2:]
    np.testing.assert_array_equal(final_a, final_b)
