"""int8 cross-pod gradient reduction: correctness in subprocess (multi-device)
and error-feedback unbiasedness in-process."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compress import ErrorFeedback, _q8_flat, _dq8_flat

# These subprocess tests build meshes with jax.sharding.AxisType (explicit
# axis types, added in jax 0.6); on older jax builds (e.g. the 0.4.x in
# some containers) the attribute does not exist and the subprocess dies at
# import time — an environment capability gap, not a code regression.
requires_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType unavailable (needs jax >= 0.6 with "
           "explicit axis types)")

REPO = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.optim.compress import compressed_pod_mean

mesh = jax.make_mesh((2, 4), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rng = np.random.RandomState(0)
g_np = rng.randn(2, 64, 32).astype(np.float32)  # leading dim = per-pod grads
g = jax.device_put(jnp.asarray(g_np),
                   NamedSharding(mesh, P()))  # replicated input per device

# fake per-pod partials: pod p sees g * (p+1)
def per_pod(local):
    idx = jax.lax.axis_index("pod").astype(jnp.float32)
    return local * (idx + 1.0)

from functools import partial
@partial(jax.shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
         check_vma=False)
def make_partials(x):
    return per_pod(x)

partials = make_partials(g)
out = compressed_pod_mean({"w": partials}, mesh)["w"]
want = g_np * 1.5  # mean of 1x and 2x
err = float(np.max(np.abs(np.asarray(out) - want)))
rel = err / float(np.abs(want).max())
print(json.dumps({"rel_err": rel}))
"""


@requires_axis_type
def test_compressed_pod_mean_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": str(REPO / "src"),
                            "PATH": "/usr/bin:/bin"}, cwd=str(REPO))
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["rel_err"] < 0.02  # int8 blockwise error bound


def test_roundtrip_and_error_feedback():
    rng = np.random.RandomState(1)
    g = {"w": jnp.asarray(rng.randn(1000).astype(np.float32))}
    res = ErrorFeedback.init(g)
    # accumulate many steps of the SAME gradient: with error feedback the
    # mean of sent values converges to the true gradient
    sent_sum = np.zeros(1000, np.float32)
    for i in range(20):
        sent, res = ErrorFeedback.apply(g, res)
        sent_sum += np.asarray(sent["w"])
    mean_sent = sent_sum / 20
    raw_q = _dq8_flat(*_q8_flat(g["w"]), g["w"].shape)
    err_ef = np.abs(mean_sent - np.asarray(g["w"])).max()
    err_raw = np.abs(np.asarray(raw_q) - np.asarray(g["w"])).max()
    assert err_ef <= err_raw + 1e-7
    assert err_ef < 0.01 * np.abs(np.asarray(g["w"])).max()
