"""repro.workflow: WorkflowConfig round-trip, Session lifecycle, Pipeline
builder, FieldHandle batching, the compat shim, and the broker regressions
fixed alongside the redesign (flush early-return, silent plan shrink,
failover with batched frames in flight)."""
import itertools
import time
import warnings

import numpy as np
import pytest

from repro.core import api
from repro.core.api import (broker_connect, broker_finalize, broker_init,
                            broker_write)
from repro.core.broker import Broker, BrokerConfig
from repro.core.grouping import GroupPlan
from repro.core.taps import TapStreamer
from repro.streaming.dag import AnalysisDAG, Stage
from repro.streaming.endpoint import make_endpoints
from repro.workflow import FieldHandle, Pipeline, Session, WorkflowConfig


# ------------------------------------------------------------- WorkflowConfig
def test_config_roundtrip_grid():
    """from_dict(to_dict()) is the identity over a deterministic sweep (the
    hypothesis-driven version lives in test_workflow_prop.py)."""
    for n, groups, compress, bp, transport, delta in itertools.product(
            (1, 3, 64), (None, 1, 2), ("none", "int8+zstd"),
            ("block", "drop_oldest", "sample"), ("inprocess", "loopback"),
            (False, True)):
        if groups is not None and groups > n:
            groups = n
        cfg = WorkflowConfig(n_producers=n, n_groups=groups, compress=compress,
                             backpressure=bp, transport=transport,
                             delta_encode=delta, trigger_interval=0.7,
                             inbound_bw=None if delta else 1e6).validate()
        assert WorkflowConfig.from_dict(cfg.to_dict()) == cfg


def test_config_rejects_bad_values():
    with pytest.raises(ValueError, match="unknown WorkflowConfig keys"):
        WorkflowConfig.from_dict({"n_producers": 2, "wat": 1})
    with pytest.raises(ValueError, match="backpressure"):
        WorkflowConfig(backpressure="yolo").validate()
    with pytest.raises(ValueError, match="transport"):
        WorkflowConfig(transport="carrier-pigeon").validate()
    with pytest.raises(ValueError, match="n_groups"):
        WorkflowConfig(n_producers=2, n_groups=5).validate()
    with pytest.raises(ValueError, match="endpoints"):
        WorkflowConfig(n_producers=8, n_groups=4, n_endpoints=2).validate()
    with pytest.raises(ValueError, match="endpoints"):
        # auto-planned group count must respect a declared endpoint budget too
        WorkflowConfig(n_producers=64, n_endpoints=2).validate()
    with pytest.raises(ValueError, match="sample_keep"):
        WorkflowConfig(backpressure="sample", sample_keep=0).validate()


def test_config_derived_subconfigs():
    cfg = WorkflowConfig(n_producers=8, n_groups=2, executors_per_group=3,
                         compress="none", queue_capacity=17)
    plan = cfg.group_plan()
    assert (plan.n_producers, plan.n_groups, plan.n_executors) == (8, 2, 6)
    bcfg = cfg.broker_config()
    assert bcfg.compress == "none" and bcfg.queue_capacity == 17
    assert cfg.endpoint_count == 2
    # auto-planned group count comes from the bandwidth planner
    assert WorkflowConfig(n_producers=40).group_plan().n_groups >= 1


# ------------------------------------------------------------------ Session
def _count_analyzer():
    def analyze(key, records):
        return len(records)
    return analyze


def test_session_end_to_end_context_manager():
    cfg = WorkflowConfig(n_producers=4, n_groups=2, executors_per_group=2,
                         compress="none", trigger_interval=0.05)
    with Session(cfg, analyze=_count_analyzer()) as sess:
        h = sess.open_field("f", shape=(8,))
        assert sess.open_field("f", shape=(8,)) is h      # cached handle
        for s in range(6):
            for r in range(4):
                assert h.write(s, np.full(8, float(s), np.float32), rank=r)
        sess.flush()
    results = sess.results()
    assert sum(r.n_records for r in results) == 24
    assert {r.stream_key for r in results} == {
        f"f/g{r % 2}/r{r}" for r in range(4)}
    assert sess.stats.sent == 24 and sess.stats.dropped == 0
    assert sess.latency_stats()["n"] > 0
    # idempotent close
    assert sess.close().sent == 24


def test_session_field_handle_typing():
    with Session(WorkflowConfig(n_producers=1, n_groups=1, compress="none",
                                executors_per_group=1)) as sess:
        h = sess.open_field("typed", shape=(4,), dtype="float32")
        with pytest.raises(ValueError, match="declared shape"):
            h.write(0, np.zeros(5, np.float32))
        assert h.write(0, [1, 2, 3, 4])                 # coerced to float32
        loose = sess.open_field("loose")                # shape=(): unchecked
        assert loose.write(0, np.zeros(17))
    assert sess.stats.sent == 2


def test_session_attach_analyzer_swaps_engine_fn():
    cfg = WorkflowConfig(n_producers=1, n_groups=1, executors_per_group=1,
                         compress="none", trigger_interval=0.05)
    sess = Session(cfg, analyze=_count_analyzer())
    engine = sess.engine
    sess.attach_analyzer(lambda k, recs: "swapped")
    assert sess.engine is engine                        # same engine, new fn
    h = sess.open_field("f")
    h.write(0, np.zeros(4, np.float32))
    sess.flush()
    sess.close()
    assert [r.value for r in sess.results()] == ["swapped"]


def test_session_init_failure_does_not_leak_threads():
    """A bad pipeline must not leak sender threads / loopback sockets from
    the already-constructed broker and endpoints."""
    import threading
    before = set(threading.enumerate())
    with pytest.raises(ValueError, match="empty pipeline"):
        Session(WorkflowConfig(n_producers=2, n_groups=1,
                               executors_per_group=1, compress="none",
                               transport="loopback"),
                pipeline=Pipeline())
    deadline = time.time() + 2.0
    while time.time() < deadline:
        leaked = [t for t in threading.enumerate() if t not in before
                  and (t.name.startswith("broker-g")
                       or t.name.startswith("loopback-"))]
        if not leaked:
            break
        time.sleep(0.02)
    assert not leaked, f"leaked threads: {[t.name for t in leaked]}"


# ----------------------------------------------------------------- Pipeline
def test_pipeline_builder_topology():
    pipe = (Pipeline()
            .stage("dmd", lambda k, recs: len(recs))
            .then("stability", lambda k, v: v * 2)
            .branch("trend", lambda k, v: -v)
            .at("stability").then("alert", lambda k, v: v if v > 2 else None))
    assert set(pipe.edges()) == {("dmd", "stability"), ("dmd", "trend"),
                                 ("stability", "alert")}
    dag = pipe.compile()
    assert isinstance(dag, AnalysisDAG)
    assert dag.source == "dmd"
    assert sorted(dag.stages["dmd"].downstream) == ["stability", "trend"]


def test_pipeline_builder_rejects_misuse():
    with pytest.raises(ValueError, match="already declared"):
        Pipeline().stage("a", None).stage("b", None)
    with pytest.raises(ValueError, match="duplicate stage"):
        Pipeline().stage("a", None).then("a", None)
    with pytest.raises(ValueError, match="before then"):
        Pipeline().then("a", None)
    with pytest.raises(ValueError, match="no parent"):
        Pipeline().stage("a", None).branch("b", None)
    with pytest.raises(ValueError, match="empty pipeline"):
        Pipeline().compile()
    with pytest.raises(ValueError, match="unknown stage"):
        Pipeline().stage("a", None).at("zz")
    with pytest.raises(ValueError, match="duplicate stage names"):
        AnalysisDAG([Stage("a", None), Stage("a", None)], source="a")


def test_pipeline_branch_after_at_fans_out_from_new_cursor():
    """branch() after at() must fan out from the repositioned cursor's
    parent, not the construction-order tail."""
    pipe = (Pipeline()
            .stage("src", lambda k, v: v)
            .then("a", lambda k, v: v)
            .then("deep", lambda k, v: v)
            .at("a").branch("b", lambda k, v: v))     # sibling of a (src->b)
    assert set(pipe.edges()) == {("src", "a"), ("a", "deep"), ("src", "b")}
    # at() the source: branch still has no parent to fan out from
    with pytest.raises(ValueError, match="no parent"):
        pipe.at("src").branch("c", lambda k, v: v)


def test_pipeline_at_cannot_introduce_cycle():
    """The builder only ever attaches NEW nodes below existing ones, so a
    back-edge is unreachable: re-adding an ancestor via then() after at()
    hits the duplicate check, and the compiled DAG always validates
    acyclic."""
    pipe = (Pipeline()
            .stage("a", lambda k, v: v)
            .then("b", lambda k, v: v))
    with pytest.raises(ValueError, match="duplicate stage"):
        pipe.at("b").then("a", lambda k, v: v)        # would be the back-edge
    pipe.at("b").then("c", lambda k, v: v)
    pipe.compile()                                    # still acyclic

    # a hand-assembled cyclic Stage list is rejected by AnalysisDAG itself
    with pytest.raises(ValueError, match="cycle"):
        AnalysisDAG([Stage("a", None, ["b"]), Stage("b", None, ["a"])],
                    source="a")


def test_pipeline_duplicate_names_rejected_across_all_verbs():
    with pytest.raises(ValueError, match="duplicate stage"):
        Pipeline().stage("x", None).then("y", None).branch("y", None)
    with pytest.raises(ValueError, match="duplicate stage"):
        Pipeline().stage("x", None).then("y", None).at("x").then("y", None)
    with pytest.raises(ValueError, match="non-empty"):
        Pipeline().stage("", None)


def test_pipeline_runs_in_session():
    cfg = WorkflowConfig(n_producers=2, n_groups=1, executors_per_group=2,
                         compress="none", trigger_interval=0.05)
    pipe = (Pipeline()
            .stage("count", lambda k, recs: len(recs))
            .then("double", lambda k, v: v * 2)
            .branch("flag", lambda k, v: "big" if v >= 3 else None))
    with Session(cfg, pipeline=pipe) as sess:
        h = sess.open_field("f")
        for s in range(3):
            h.write_batch(s, [np.zeros(4, np.float32)] * 2, ranks=[0, 1])
        sess.flush()
    doubles = sess.dag.latest("double")
    assert set(doubles) == {"f/g0/r0", "f/g0/r1"}
    assert all(v % 2 == 0 for v in doubles.values())
    assert sess.results("double") == sess.dag.results("double")
    # "flag" filtered: only micro-batches of >= 3 records sink
    assert all(v == "big" for _, v, _ in sess.results("flag"))


def test_engine_attach_dag_reroutes_microbatches():
    cfg = WorkflowConfig(n_producers=1, n_groups=1, executors_per_group=1,
                         compress="none", trigger_interval=0.05)
    sess = Session(cfg, analyze=_count_analyzer())
    dag = (Pipeline().stage("only", lambda k, recs: f"dag:{len(recs)}")
           .compile())
    sess.engine.attach_dag(dag)
    h = sess.open_field("f")
    h.write(0, np.zeros(2, np.float32))
    sess.flush()
    sess.close()
    assert [r.value for r in sess.results()] == ["dag:1"]


# -------------------------------------------------- FieldHandle.write_batch
def test_write_batch_validates_alignment():
    with Session(WorkflowConfig(n_producers=2, n_groups=1, compress="none",
                                executors_per_group=1)) as sess:
        h = sess.open_field("f")
        with pytest.raises(ValueError, match="aligned"):
            h.write_batch([0, 1], [np.zeros(2)] * 3)
        assert h.write_batch(7, [np.zeros(2)] * 3, ranks=[0, 1, 0]) == 3


def test_tap_publish_is_one_frame_per_field():
    """F fields x R regions per publish must produce <= F wire frames."""
    F, R = 2, 4
    cfg = WorkflowConfig(n_producers=R, n_groups=1, executors_per_group=1,
                         compress="none")
    sess = Session(cfg)
    streamer = TapStreamer(sess, n_regions=R)
    taps = {"resid_norm": np.random.randn(3, 8).astype(np.float32),
            "snapshot": np.random.randn(3, 8, 16).astype(np.float32)}
    assert streamer.publish(0, taps) == F * R
    sess.flush()
    ep = sess.endpoints[0].handle
    assert ep.records_in == F * R
    assert ep.frames_in <= F, (
        f"publish of {F} fields x {R} regions took {ep.frames_in} frames")
    sess.close()


def test_tapstreamer_still_accepts_bare_broker():
    eps = make_endpoints(1)
    broker = Broker(GroupPlan(2, 1, 1), eps, BrokerConfig(compress="none"))
    streamer = TapStreamer(broker, n_regions=2)
    n = streamer.publish(0, {"resid_norm": np.ones((2, 4), np.float32),
                             "snapshot": np.ones((2, 4, 8), np.float32)})
    assert n == 4
    broker.finalize()
    assert eps[0].handle.records_in == 4


# ----------------------------------- backpressure accounting with batch items
def _parked_sender(**cfg_kw):
    """A _GroupSender that is never start()ed: queue state and eviction
    accounting are fully deterministic (same construction as
    test_hotpath_batch's coalescing test)."""
    from repro.core.broker import _GroupSender
    eps = make_endpoints(1)
    sender = _GroupSender(0, eps, 0, BrokerConfig(compress="none", **cfg_kw))
    return sender, eps


def _rec(step, rank=0):
    from repro.core.records import StreamRecord
    return StreamRecord("f", 0, rank, step, np.full(4, float(step), np.float32))


def test_drop_oldest_eviction_counts_batch_records():
    """Evicting a queued submit_batch list must count all its records, or
    written-sent-dropped accounting skews and flush() spins to timeout."""
    sender, eps = _parked_sender(queue_capacity=2, backpressure="drop_oldest",
                                 max_batch_records=8)
    st = sender.stats
    for s in range(2):                      # fills the 2-item queue
        assert sender.submit_batch([_rec(s), _rec(s, 1), _rec(s)]) == 3
    assert st.written == 6 and st.dropped == 0
    # single-record submit evicts the OLDEST item — a 3-record batch
    assert sender.submit(_rec(99))
    assert st.written == 7
    assert st.dropped == 3, "batch eviction must count all records in the item"
    # batch submit evicts the other 3-record batch
    assert sender.submit_batch([_rec(100), _rec(101)]) == 2
    assert st.written == 9 and st.dropped == 6
    # accounting identity holds once the sender drains the survivors
    sender.start()
    sender.stop(timeout=5.0)
    assert st.written == st.sent + st.dropped == 9
    assert st.sent == 3                     # rec 99 + batch [100, 101]


def test_sample_backpressure_keeps_fresh_batches():
    """submit_batch under 'sample' keeps 1 of sample_keep batches (evicting
    stale ones) instead of dropping every new batch whole."""
    sender, eps = _parked_sender(queue_capacity=2, backpressure="sample",
                                 sample_keep=2, max_batch_records=8)
    st = sender.stats
    for s in range(8):
        sender.submit_batch([_rec(s), _rec(s, 1)])
    queued = []
    while not sender.q.empty():
        item = sender.q.get_nowait()
        queued.extend(item if isinstance(item, list) else [item])
    assert queued, "sample policy must admit some batches under pressure"
    assert max(r.step for r in queued) >= 4, \
        "fresh batches should displace stale ones"
    assert st.written == 16
    assert st.dropped + len(queued) == st.written - st.sent


def test_paper_api_wire_behavior_matches_seed():
    """The shim must hand payloads to the codec in their input dtype, exactly
    like the seed broker_write (the wire itself is float32 by codec design:
    encode() does astype(float32) on the raw path).  Guard both halves: the
    compat FieldHandle doesn't pre-coerce, and the delivered values match the
    seed's float32 wire semantics."""
    eps = make_endpoints(1)
    broker = Broker(GroupPlan(1, 1, 1), eps, BrokerConfig(compress="none"))
    ctx = broker_init("counters", rank=0, broker=broker)
    assert ctx.handle.coerce_dtype is False
    assert ctx.handle._coerce(np.arange(3, dtype=np.int64)).dtype == np.int64
    data = np.array([1.5, -2.25, 1e7], dtype=np.float64)
    assert broker_write(ctx, 0, data)
    broker_finalize(ctx)
    [rec] = eps[0].handle.drain("counters/g0/r0")
    assert rec.payload.dtype == np.float32       # codec-defined, as in seed
    np.testing.assert_allclose(rec.payload, data.astype(np.float32))


# ----------------------------------------------------- flush() early return
def test_flush_waits_out_recovered_endpoint():
    """Errors from a past failure episode must not make flush() bail while
    records written after recovery are still in flight."""
    eps = make_endpoints(1, inbound_bw=50_000)       # slow drain post-recovery
    broker = Broker(GroupPlan(1, 1, 1), eps,
                    BrokerConfig(compress="none", backpressure="block",
                                 retry_limit=2, queue_capacity=512,
                                 max_batch_records=1, flush_timeout_s=30.0))
    eps[0].handle.fail()
    for s in range(5):
        broker.write("f", 0, s, np.zeros(1024, np.float32))
    deadline = time.time() + 5.0
    while time.time() < deadline and broker.stats.dropped < 5:
        time.sleep(0.01)
    assert broker.stats.dropped == 5                 # failure episode over
    assert broker.stats.send_errors >= 10            # its errors linger
    eps[0].handle.recover()
    for s in range(5, 45):
        broker.write("f", 0, s, np.zeros(1024, np.float32))
    broker.flush()
    # flush must have outlasted the bandwidth-paced drain of all 40 records
    assert broker.stats.sent == 40
    assert all(s.q.empty() for s in broker._senders.values())
    broker.finalize()


# ------------------------------------------------------- plan-shrink warning
def test_connect_shrink_warns_and_records_effective_plan():
    eps = make_endpoints(2)
    with pytest.warns(RuntimeWarning, match="shrinking to 2"):
        broker = broker_connect(eps, n_producers=8,
                                plan=GroupPlan(8, 4, 2))
    assert broker.plan.n_groups == 2
    assert broker.stats.planned_groups == 4
    assert broker.stats.effective_groups == 2
    broker.finalize()


def test_connect_exact_fit_does_not_warn():
    eps = make_endpoints(2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        broker = broker_connect(eps, n_producers=4, plan=GroupPlan(4, 2, 2))
    assert broker.stats.planned_groups == broker.stats.effective_groups == 2
    broker.finalize()


# --------------------------------------------- failover with frames in flight
def test_failover_midstream_batched_no_loss_ordered():
    """Kill the primary endpoint while batched frames are in flight: traffic
    reroutes, nothing is lost under backpressure="block", and the engine's
    per-stream record order survives the re-route."""
    cfg = WorkflowConfig(n_producers=4, n_groups=2, executors_per_group=2,
                         compress="none", backpressure="block",
                         queue_capacity=512, max_batch_records=8,
                         trigger_interval=0.05, n_executors=1)
    seen: dict[str, list[int]] = {}

    def analyze(key, records):
        seen.setdefault(key, []).extend(r.step for r in records)
        return len(records)

    sess = Session(cfg, analyze=analyze)
    h = sess.open_field("f")
    n_steps = 40
    for s in range(n_steps):
        h.write_batch(s, [np.full(16, float(s), np.float32)] * 4,
                      ranks=[0, 1, 2, 3])
        if s == n_steps // 2:
            sess.endpoints[0].handle.fail()      # kill primary mid-stream
        time.sleep(0.002)
    sess.flush()
    stats = sess.close()
    assert stats.rerouted > 0
    assert stats.dropped == 0
    assert stats.sent == stats.written == 4 * n_steps    # no record loss
    assert set(seen) == {f"f/g{r % 2}/r{r}" for r in range(4)}
    for key, steps in seen.items():
        assert steps == sorted(steps), f"stream {key} reordered: {steps}"
        assert len(steps) == n_steps


# ----------------------------------------------------------- compat shim
def test_paper_api_is_session_backed():
    eps = make_endpoints(2)
    broker = broker_connect(eps, n_producers=4)
    assert api._shared_session is not None
    assert api._shared_session.broker is broker
    ctx = broker_init("pressure", rank=1, shape=(16,))
    assert isinstance(ctx.handle, FieldHandle)
    assert broker_write(ctx, step=0, data=np.zeros(16, np.float32))
    stats = broker_finalize(ctx)            # closes the shared Session
    assert stats.sent == 1
    assert api._shared_session._closed


def test_broker_init_with_external_broker():
    eps = make_endpoints(1)
    broker = Broker(GroupPlan(2, 1, 1), eps, BrokerConfig(compress="none"))
    ctx = broker_init("f", rank=1, broker=broker)
    assert broker_write(ctx, 0, np.arange(4, dtype=np.float32))
    stats = broker_finalize(ctx)
    assert stats.sent == 1


# ------------------------------------------------------- loopback transport
def test_loopback_transport_survives_broker_suite_smoke():
    cfg = WorkflowConfig(n_producers=4, n_groups=2, executors_per_group=2,
                         compress="int8+zstd", transport="loopback",
                         trigger_interval=0.05)
    with Session(cfg, analyze=_count_analyzer()) as sess:
        h = sess.open_field("f", shape=(32,))
        for s in range(5):
            h.write_batch(s, [np.random.randn(32).astype(np.float32)] * 4,
                          ranks=[0, 1, 2, 3])
        sess.flush()
    assert sess.stats.sent == 20 and sess.stats.dropped == 0
    assert sum(r.n_records for r in sess.results()) == 20
