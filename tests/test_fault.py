"""Fault-tolerance runtime: detection, stragglers, checkpoint-restart."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint.ckpt import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as T
from repro.models.modules import materialize
from repro.models.steps import make_train_step
from repro.optim import adamw
from repro.runtime.fault import FailureDetector, RestartPolicy


def test_heartbeat_failure_detection():
    det = FailureDetector(timeout_s=0.1)
    det.register("worker0", "producer")
    det.register("worker1", "producer")
    failed_names = []
    det.on_failure.append(lambda st: failed_names.append(st.name))
    for _ in range(3):
        det.beat("worker0")
        det.beat("worker1")
        time.sleep(0.02)
    det.beat("worker0")
    time.sleep(0.15)
    det.beat("worker0")
    failed = det.scan()
    assert [f.name for f in failed] == ["worker1"]
    assert failed_names == ["worker1"]
    assert det.nodes["worker0"].alive


def test_straggler_detection():
    det = FailureDetector(timeout_s=10, straggler_factor=3.0)
    flagged = []
    det.on_straggler.append(lambda st: flagged.append(st.name))
    for n in ["fast0", "fast1", "slow"]:
        det.register(n, "executor")
    for i in range(25):               # slow needs >=4 recorded intervals
        det.beat("fast0"); det.beat("fast1")
        time.sleep(0.01)
        if i % 5 == 4:
            det.beat("slow")
    det.scan()
    assert "slow" in flagged


def test_restart_policy_resumes_training(tmp_path):
    """Simulated preemption mid-run; training completes with identical final
    loss to an uninterrupted run."""
    cfg = C.get("mamba2-2.7b").reduced()
    params0 = materialize(T.build_specs(cfg), jax.random.key(0), jnp.float32)
    opt_cfg = adamw.AdamWConfig()
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, 1))
    pipe = TokenPipeline(cfg, batch=2, seq=32)
    total = 6

    def run_clean():
        p, o = params0, adamw.init_opt_state(opt_cfg, params0)
        for s in range(total):
            p, o, m, _ = step_fn(p, o, pipe.batch_at(s))
        return float(m["loss"])

    mgr = CheckpointManager(tmp_path)
    crashed = {"done": False}

    def train_fn(resume):
        if resume is None:
            p, o, s0 = params0, adamw.init_opt_state(opt_cfg, params0), 0
        else:
            tree, s0 = mgr.restore(
                {"params": params0,
                 "opt": adamw.init_opt_state(opt_cfg, params0)})
            p, o = tree["params"], tree["opt"]
        for s in range(s0, total):
            if s == 3 and not crashed["done"]:
                mgr.save(s, {"params": p, "opt": o}, blocking=True)
                crashed["done"] = True
                raise RuntimeError("simulated preemption")
            p, o, m, _ = step_fn(p, o, pipe.batch_at(s))
        train_fn.final_loss = float(m["loss"])
        return total

    policy = RestartPolicy()
    assert policy.run_with_restarts(train_fn, mgr) == total
    assert policy.restarts == 1
    assert train_fn.final_loss == pytest.approx(run_clean(), abs=1e-6)
