"""Fault-tolerance runtime: detection, stragglers, checkpoint-restart, and
the detector→controller→engine recovery loop.

Detector and recovery-loop tests run on a ``VirtualClock``: heartbeat gaps
and straggler cadences are exact simulated intervals instead of real
``time.sleep`` (deterministic, no flake, milliseconds of wall time)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint.ckpt import CheckpointManager
from repro.core.broker import Broker, BrokerConfig
from repro.core.grouping import GroupPlan
from repro.data.pipeline import TokenPipeline
from repro.models import transformer as T
from repro.models.modules import materialize
from repro.models.steps import make_train_step
from repro.optim import adamw
from repro.runtime.clock import VirtualClock
from repro.runtime.controller import ElasticController, ElasticityConfig
from repro.runtime.fault import FailureDetector, RestartPolicy
from repro.runtime.telemetry import TelemetryBus
from repro.streaming.endpoint import make_endpoints
from repro.streaming.engine import StreamEngine


def test_heartbeat_failure_detection():
    clk = VirtualClock()
    det = FailureDetector(timeout_s=0.1, clock=clk)
    det.register("worker0", "producer")
    det.register("worker1", "producer")
    failed_names = []
    det.on_failure.append(lambda st: failed_names.append(st.name))
    for _ in range(3):
        det.beat("worker0")
        det.beat("worker1")
        clk.sleep(0.02)
    det.beat("worker0")
    clk.sleep(0.15)                  # worker1 misses exactly this window
    det.beat("worker0")
    failed = det.scan()
    assert [f.name for f in failed] == ["worker1"]
    assert failed_names == ["worker1"]
    assert det.nodes["worker0"].alive


def test_straggler_detection():
    clk = VirtualClock()
    det = FailureDetector(timeout_s=10, straggler_factor=3.0, clock=clk)
    flagged = []
    det.on_straggler.append(lambda st: flagged.append(st.name))
    for n in ["fast0", "fast1", "slow"]:
        det.register(n, "executor")
    for i in range(25):               # slow needs >=4 recorded intervals
        det.beat("fast0"); det.beat("fast1")
        clk.sleep(0.01)
        if i % 5 == 4:
            det.beat("slow")          # exactly 5x its peers' beat interval
    det.scan()
    assert "slow" in flagged


def test_straggler_callback_drives_executor_replacement():
    """End-to-end over the real callbacks: a slowed executor's sparse
    heartbeats trip FailureDetector.on_straggler, the ElasticController
    replaces it, the engine rebalances, and every record still lands —
    previously test-only callbacks now close a real loop.  The 25 virtual
    seconds this may need cost well under a second of wall time."""
    clk = VirtualClock()
    clk.attach()
    eps = make_endpoints(1, clock=clk)
    plan = GroupPlan(n_producers=4, n_groups=1, executors_per_group=1)
    broker = Broker(plan, eps, BrokerConfig(compress="none",
                                            backpressure="block",
                                            queue_capacity=4096), clock=clk)

    import threading
    seen: dict[str, list[int]] = {}
    seen_lock = threading.Lock()

    def analyze(key, recs):
        clk.sleep(0.01 * len(recs))
        with seen_lock:
            seen.setdefault(key, []).extend(r.step for r in recs)
        return len(recs)

    eng = StreamEngine([e.handle for e in eps], analyze, n_executors=3,
                       trigger_interval=0.03, min_batch=1, clock=clk)
    straggler = eng.executors[0]
    straggler.slowdown = 0.5               # ~10x its peers' service time
    bus = TelemetryBus(broker=broker, endpoints=[e.handle for e in eps],
                       engine=eng, clock=clk)
    el = ElasticityConfig(enabled=True, interval_s=0.05,
                          heartbeat_timeout_s=10.0, straggler_factor=2.5,
                          min_executors=1, max_executors=8,
                          idle_scale_down_s=3600, target_p99_s=3600)
    ctl = ElasticController(bus, el, engine=eng, broker=broker, clock=clk)
    deadline = clk.now() + 25.0
    written = 0
    while clk.now() < deadline:
        for r in range(4):                 # keep every executor fed
            broker.write("f", r, written, np.zeros(8, np.float32))
        written += 1
        ctl.tick()
        if any(a.kind == "replace_executor" for _, a in ctl.actions_log):
            break
        clk.sleep(0.02)
    assert any(a.kind == "replace_executor" for _, a in ctl.actions_log), \
        "controller never replaced the straggler"
    assert ctl.detector.nodes["executor-0"].marked_straggler
    assert not straggler.alive                 # retired
    assert sum(1 for e in eng.executors if e.alive) >= 3   # replacement up
    broker.flush()
    eng.drain_and_stop(timeout=30)
    broker.finalize()
    clk.detach()
    assert sum(r.n_records for r in eng.collect()) == 4 * written
    for key, steps in seen.items():
        assert steps == sorted(steps), f"{key} reordered across replacement"


def test_dead_executor_heartbeat_timeout_triggers_replacement():
    """An executor whose thread dies (hard kill) stops beating entirely;
    the detector times it out and the controller replaces it."""
    clk = VirtualClock()
    clk.attach()
    eps = make_endpoints(1, clock=clk)
    plan = GroupPlan(n_producers=1, n_groups=1, executors_per_group=2)
    broker = Broker(plan, eps, BrokerConfig(compress="none"), clock=clk)
    eng = StreamEngine([e.handle for e in eps],
                       lambda k, recs: len(recs), n_executors=1,
                       trigger_interval=0.03, min_batch=1, clock=clk)
    bus = TelemetryBus(broker=broker, endpoints=[e.handle for e in eps],
                       engine=eng, clock=clk)
    el = ElasticityConfig(enabled=True, interval_s=0.05,
                          heartbeat_timeout_s=0.2, stuck_analysis_s=0.3,
                          idle_scale_down_s=3600, target_p99_s=3600)
    ctl = ElasticController(bus, el, engine=eng, broker=broker, clock=clk)
    ctl.tick()                                  # register + first beats
    # simulate a wedged (not cooperatively-killed) executor: alive flag on,
    # but it neither progresses nor empties its queue (the huge slowdown
    # parks it far beyond the test horizon on the virtual timeline)
    from repro.streaming.engine import MicroBatch
    victim = eng.executors[0]
    victim.slowdown = 1e9                       # never finishes anything
    victim.q.put(MicroBatch(stream_key="probe", records=[]))   # being "run"
    victim.q.put(MicroBatch(stream_key="probe", records=[]))   # stuck queued

    def pump():
        ctl.tick()
        return any(a.kind == "replace_executor"
                   for _, a in ctl.actions_log)

    assert clk.wait(pump, timeout=5.0, poll=0.05)
    eng.drain_and_stop(timeout=5)
    broker.finalize()
    clk.detach()


def test_restart_policy_resumes_training(tmp_path):
    """Simulated preemption mid-run; training completes with identical final
    loss to an uninterrupted run."""
    cfg = C.get("mamba2-2.7b").reduced()
    params0 = materialize(T.build_specs(cfg), jax.random.key(0), jnp.float32)
    opt_cfg = adamw.AdamWConfig()
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, 1))
    pipe = TokenPipeline(cfg, batch=2, seq=32)
    total = 6

    def run_clean():
        p, o = params0, adamw.init_opt_state(opt_cfg, params0)
        for s in range(total):
            p, o, m, _ = step_fn(p, o, pipe.batch_at(s))
        return float(m["loss"])

    mgr = CheckpointManager(tmp_path)
    crashed = {"done": False}

    def train_fn(resume):
        if resume is None:
            p, o, s0 = params0, adamw.init_opt_state(opt_cfg, params0), 0
        else:
            tree, s0 = mgr.restore(
                {"params": params0,
                 "opt": adamw.init_opt_state(opt_cfg, params0)})
            p, o = tree["params"], tree["opt"]
        for s in range(s0, total):
            if s == 3 and not crashed["done"]:
                mgr.save(s, {"params": p, "opt": o}, blocking=True)
                crashed["done"] = True
                raise RuntimeError("simulated preemption")
            p, o, m, _ = step_fn(p, o, pipe.batch_at(s))
        train_fn.final_loss = float(m["loss"])
        return total

    policy = RestartPolicy()
    assert policy.run_with_restarts(train_fn, mgr) == total
    assert policy.restarts == 1
    assert train_fn.final_loss == pytest.approx(run_clean(), abs=1e-6)
