"""Optimizer: AdamW math vs numpy reference, q8 moment error bounds,
schedule shape."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import adamw


def _np_adamw_step(p, g, m, v, step, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    bc1 = 1 - cfg.b1 ** step
    bc2 = 1 - cfg.b2 ** step
    u = (m / bc1) / (np.sqrt(v / bc2) + cfg.eps)
    return m, v, u


def test_adamw_matches_numpy_reference():
    cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9,
                            warmup_steps=0, total_steps=10**9)
    params = {"w": jnp.asarray(np.ones((4, 4), np.float32))}
    grads = {"w": jnp.asarray(np.full((4, 4), 0.5, np.float32))}
    opt = adamw.init_opt_state(cfg, params)
    p2, opt2, _ = adamw.apply_updates(cfg, params, grads, opt)
    m, v, u = _np_adamw_step(np.ones((4, 4)), np.full((4, 4), 0.5),
                             np.zeros((4, 4)), np.zeros((4, 4)), 1, cfg)
    # schedule at step 1 with warmup 0: cosine at t=1/total ~ lr
    lr = float(adamw.cosine_schedule(cfg.lr, 0, cfg.total_steps)(jnp.asarray(1)))
    want = np.ones((4, 4)) - lr * u
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)


def test_grad_clip_applies():
    cfg = adamw.AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.zeros((8,), jnp.float32)}
    grads = {"w": jnp.full((8,), 100.0)}
    opt = adamw.init_opt_state(cfg, params)
    _, _, metrics = adamw.apply_updates(cfg, params, grads, opt)
    assert float(metrics["grad_norm"]) > 100


@given(st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_8bit_tracks_fp32(seed):
    rng = np.random.RandomState(seed)
    p0 = {"w": jnp.asarray(rng.randn(16, 256).astype(np.float32))}
    cfg32 = adamw.AdamWConfig(use_8bit=False, weight_decay=0.0)
    cfg8 = adamw.AdamWConfig(use_8bit=True, weight_decay=0.0)
    o32 = adamw.init_opt_state(cfg32, p0)
    o8 = adamw.init_opt_state(cfg8, p0)
    p32, p8 = p0, p0
    for step in range(3):
        g = {"w": jnp.asarray(rng.randn(16, 256).astype(np.float32))}
        p32, o32, _ = adamw.apply_updates(cfg32, p32, g, o32)
        p8, o8, _ = adamw.apply_updates(cfg8, p8, g, o8)
    diff = np.abs(np.asarray(p32["w"]) - np.asarray(p8["w"]))
    scale = np.abs(np.asarray(p32["w"]) - np.asarray(p0["w"])).max()
    assert diff.max() <= 0.35 * scale + 1e-5  # 8-bit drift bounded vs update size


@given(st.sampled_from([(4, 8), (3, 256), (16, 128), (2, 1000)]))
@settings(max_examples=20, deadline=None)
def test_q8_roundtrip_bound(shape):
    rng = np.random.RandomState(shape[1])
    x = jnp.asarray(rng.randn(*shape).astype(np.float32) * 10)
    z = adamw.q8_encode(x)
    back = adamw.q8_decode(z)
    q = adamw.block_size(shape[-1])
    blocks = np.asarray(x).reshape(*shape[:-1], shape[-1] // q, q)
    bound = np.abs(blocks).max(-1, keepdims=True) / 127 * 0.51 + 1e-7
    err = np.abs(np.asarray(back).reshape(blocks.shape) - blocks)
    assert np.all(err <= bound)


def test_schedule_warmup_and_decay():
    lr = adamw.cosine_schedule(1e-3, warmup=100, total=1000)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(50))) < float(lr(jnp.asarray(100)))
    assert abs(float(lr(jnp.asarray(100))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(1000))) < float(lr(jnp.asarray(500)))
    assert float(lr(jnp.asarray(1000))) >= 1e-4 - 1e-9   # min_ratio floor
