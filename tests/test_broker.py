"""Broker: grouping invariants (property), codec roundtrip, backpressure,
endpoint failover — the paper's §3.1 semantics."""
import time

import numpy as np
import pytest

try:        # hypothesis gates only the property tests, not the whole module
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.api import broker_connect, broker_init, broker_write, broker_finalize
from repro.core.broker import Broker, BrokerConfig
from repro.core.grouping import GroupPlan, plan_groups
from repro.core.records import StreamRecord, encode, decode, quantize_int8, dequantize_int8
from repro.streaming.endpoint import make_endpoints


# ------------------------------------------------- grouping + codec (property)
if HAS_HYPOTHESIS:
    @given(n=st.integers(1, 512), groups=st.integers(1, 64))
    @settings(max_examples=60, deadline=None)
    def test_grouping_partitions(n, groups):
        plan = GroupPlan(n_producers=n, n_groups=min(groups, n),
                         executors_per_group=2)
        seen = {}
        for r in range(n):
            g = plan.group_of(r)
            assert 0 <= g < plan.n_groups
            seen.setdefault(g, []).append(r)
        # complete partition + balanced within 1
        assert sum(len(v) for v in seen.values()) == n
        sizes = [len(v) for v in seen.values()]
        assert max(sizes) - min(sizes) <= 1

    @given(n=st.integers(1, 2048),
           rate=st.floats(0.1, 100), rec=st.floats(1e3, 1e8))
    @settings(max_examples=60, deadline=None)
    def test_planner_respects_bandwidth(n, rate, rec):
        plan = plan_groups(n, record_rate_hz=rate, record_bytes=rec,
                           endpoint_in_bw=10e9)
        demand = min(rate * rec, 1e9)
        per_ep = (n + plan.n_groups - 1) // plan.n_groups
        assert per_ep * demand <= 10e9 * 1.01 or per_ep <= 1 or per_ep <= 16

    @given(shape=st.sampled_from([(4,), (64,), (3, 5), (128,), (2, 2, 2)]),
           compress=st.sampled_from(["none", "zstd", "int8", "int8+zstd"]))
    @settings(max_examples=40, deadline=None)
    def test_record_roundtrip(shape, compress):
        rng = np.random.RandomState(1)
        payload = rng.randn(*shape).astype(np.float32) * 5
        rec = StreamRecord(field_name="velocity_x", group_id=3, rank=7,
                           step=11, payload=payload)
        out = decode(encode(rec, compress=compress))
        assert out.field_name == "velocity_x" and out.rank == 7 and out.step == 11
        assert out.payload.shape == tuple(shape)
        tol = 0.0 if "int8" not in compress else np.abs(payload).max() / 100
        np.testing.assert_allclose(out.payload, payload, atol=tol + 1e-7)

    @given(n=st.integers(1, 2000))
    @settings(max_examples=30, deadline=None)
    def test_int8_codec_bound(n):
        rng = np.random.RandomState(n)
        x = (rng.randn(n) * rng.uniform(0.01, 100)).astype(np.float32)
        back = dequantize_int8(quantize_int8(x))
        # per-block error <= scale/2 = absmax/254
        assert np.abs(back - x).max() <= np.abs(x).max() / 100


# ---------------------------------------------------------------- transport
# The same broker suite runs over both Transport implementations: the
# in-process CloudEndpoint.handle delegation and the loopback TCP socket.
@pytest.fixture(params=["inprocess", "loopback"])
def mk(request):
    created = []

    def _mk(n_producers=8, n_eps=2, **cfg_kw):
        eps = make_endpoints(n_eps, transport=request.param)
        created.extend(eps)
        plan = GroupPlan(n_producers=n_producers, n_groups=n_eps,
                         executors_per_group=2)
        broker = Broker(plan, eps, BrokerConfig(**cfg_kw))
        return broker, eps

    yield _mk
    for e in created:
        e.close()


def test_write_reaches_designated_endpoint(mk):
    broker, eps = mk()
    for rank in range(8):
        broker.write("f", rank, step=0, payload=np.arange(4, dtype=np.float32))
    broker.finalize()
    per_ep = [e.handle.records_in for e in eps]
    assert sum(per_ep) == 8
    assert all(c == 4 for c in per_ep)  # round-robin groups, 2 endpoints
    # stream keys are per (field, group, rank)
    keys = set(eps[0].handle.stream_keys()) | set(eps[1].handle.stream_keys())
    assert len(keys) == 8


def test_backpressure_drop_oldest(mk):
    broker, eps = mk(n_producers=1, n_eps=1, queue_capacity=4,
                     backpressure="drop_oldest")
    eps[0].handle.fail()  # sender can't drain -> queue fills
    for step in range(50):
        broker.write("f", 0, step, np.zeros(8, np.float32))
    time.sleep(0.2)
    assert broker.stats.dropped > 0
    assert broker.stats.written == 50
    eps[0].handle.recover()
    broker.finalize()
    # newest records survived (drop-OLDEST)
    recs = eps[0].handle.drain(eps[0].handle.stream_keys()[0]) if eps[0].handle.stream_keys() else []
    if recs:
        assert max(r.step for r in recs) == 49


def test_endpoint_failover_reroutes(mk):
    broker, eps = mk(n_producers=4, n_eps=2, retry_limit=3)
    eps[0].handle.fail()   # group 0's designated endpoint dies
    for step in range(10):
        for rank in range(4):
            broker.write("f", rank, step, np.zeros(4, np.float32))
    broker.finalize()
    assert broker.stats.rerouted > 0
    assert eps[1].handle.records_in == 40  # everything landed on the survivor
    assert broker.stats.sent == 40


def test_reroute_picks_least_loaded_survivor():
    """Proactive reroute must NOT dogpile the ring-order neighbor: with the
    primary dead, the group goes to the survivor with the least
    pending+ingest load, not simply the next index."""
    eps = make_endpoints(3)
    plan = GroupPlan(n_producers=3, n_groups=3, executors_per_group=2)
    broker = Broker(plan, eps, BrokerConfig(retry_limit=3))
    try:
        # pile undrained records onto ep1 (group 1's designated endpoint)
        for step in range(20):
            broker.write("f", 1, step, np.zeros(4, np.float32))
        for _ in range(200):
            if eps[1].handle.records_in >= 20:
                break
            time.sleep(0.01)
        assert eps[1].handle.pending() >= 20
        eps[0].handle.fail()
        assert broker.reroute_from_endpoint(0) == 1   # one group moved
        # group 0 must land on the EMPTY ep2, not the loaded neighbor ep1
        assert broker.groups_on_endpoint(2) == 2      # its own group 2 + group 0
        assert broker.groups_on_endpoint(1) == 1
        assert broker.stats.rerouted == 1
    finally:
        eps[0].handle.recover()
        broker.finalize()
        for e in eps:
            e.close()


def test_reroute_tie_breaks_in_ring_order():
    eps = make_endpoints(3)
    plan = GroupPlan(n_producers=3, n_groups=3, executors_per_group=2)
    broker = Broker(plan, eps, BrokerConfig())
    try:
        eps[0].handle.fail()
        broker.reroute_from_endpoint(0)
        # all survivors idle -> legacy ring order: next index wins
        assert broker.groups_on_endpoint(1) == 2
    finally:
        eps[0].handle.recover()
        broker.finalize()
        for e in eps:
            e.close()


def test_paper_api_surface():
    eps = make_endpoints(2)
    broker = broker_connect(eps, n_producers=4)
    ctx = broker_init("pressure", rank=1, shape=(16,))
    assert ctx.group_id == broker.plan.group_of(1)
    ok = broker_write(ctx, step=0, data=np.zeros(16, np.float32))
    assert ok
    stats = broker_finalize(ctx)
    assert stats.sent == 1 and stats.dropped == 0
