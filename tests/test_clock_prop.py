"""Property tests (hypothesis; skipped where absent — CI installs the
``[test]`` extra): VirtualClock scheduling invariants under randomized
sleep plans, and an ``encode_batch``/``decode_batch`` round-trip property
across codec × delta × dtype.  Deterministic spot-check versions of the
clock invariants live in ``tests/test_clock.py`` and always run."""
import threading

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.records import StreamRecord, decode_batch, encode_batch
from repro.runtime.clock import VirtualClock

# ---------------------------------------------------------------------------
# VirtualClock scheduling invariants
# ---------------------------------------------------------------------------

durations = st.lists(
    st.floats(min_value=0.0, max_value=10.0,
              allow_nan=False, allow_infinity=False),
    min_size=1, max_size=20)


@settings(max_examples=30, deadline=None)
@given(plan=durations)
def test_now_monotonic_under_any_sleep_plan(plan):
    clk = VirtualClock()
    seen = []
    for d in plan:
        clk.sleep(d)
        seen.append(clk.now())
    assert all(b >= a for a, b in zip(seen, seen[1:]))
    assert seen[-1] == pytest.approx(sum(plan))


@settings(max_examples=20, deadline=None)
@given(plans=st.lists(durations, min_size=2, max_size=6),
       seed=st.one_of(st.none(), st.integers(0, 2**31)))
def test_no_lost_wakeups_under_concurrent_sleepers(plans, seed):
    """Every sleeper completes its full randomized plan regardless of how
    many peers are interleaved or how ties are broken."""
    clk = VirtualClock(seed=seed)
    clk.attach()
    done, lock = [], threading.Lock()

    def sleeper(i, plan):
        for d in plan:
            clk.sleep(d)
        with lock:
            done.append((i, clk.now()))   # finish instant, pre-join

    threads = [threading.Thread(target=sleeper, args=(i, p), daemon=True)
               for i, p in enumerate(plans)]
    for t in threads:
        clk.thread_started(t)
        t.start()
    clk.detach()
    for t in threads:
        assert clk.join(t, timeout=None)
    assert sorted(i for i, _ in done) == list(range(len(plans)))
    # each sleeper finishes exactly at its own cumulative deadline: the
    # schedule neither stalls a waiter nor overshoots it (join() itself
    # polls on virtual time, so clk.now() afterwards may sit a few poll
    # quanta past the last finish — measure inside the sleepers instead)
    finish = max(t for _, t in done)
    assert finish == pytest.approx(max(sum(p) for p in plans))
    assert clk.now() >= finish


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=2, max_value=8),
       target=st.floats(min_value=1.0, max_value=100.0,
                        allow_nan=False, allow_infinity=False))
def test_fifo_wakeup_among_equal_deadlines(n, target):
    """Park order (forced deterministic by serialized staggered sleeps) is
    wake order when deadlines tie exactly and no seed is set."""
    clk = VirtualClock()
    clk.attach()
    order, lock = [], threading.Lock()

    def sleeper(i):
        clk.sleep(0.001 * i)       # serialized: fixes park order = i order
        clk.sleep_until(target)    # identical absolute deadline for all
        with lock:
            order.append(i)

    threads = [threading.Thread(target=sleeper, args=(i,), daemon=True)
               for i in range(n)]
    for t in threads:
        clk.thread_started(t)
        t.start()
    clk.detach()
    for t in threads:
        assert clk.join(t, timeout=None)
    assert order == list(range(n))


@settings(max_examples=30, deadline=None)
@given(timeout=st.floats(min_value=0.01, max_value=50.0,
                         allow_nan=False, allow_infinity=False))
def test_wait_timeout_is_exact_in_virtual_time(timeout):
    clk = VirtualClock()
    t0 = clk.now()
    assert clk.wait(lambda: False, timeout=timeout) is False
    assert clk.now() - t0 == pytest.approx(timeout)


# ---------------------------------------------------------------------------
# Wire-codec round-trip property: codec × delta × dtype
# ---------------------------------------------------------------------------

_DTYPES = (np.float32, np.float64, np.float16, np.int32)


@st.composite
def record_batches(draw):
    n = draw(st.integers(min_value=1, max_value=12))
    dtype = draw(st.sampled_from(_DTYPES))
    size = draw(st.integers(min_value=1, max_value=64))
    n_streams = draw(st.integers(min_value=1, max_value=3))
    rng = np.random.RandomState(draw(st.integers(0, 2**31)))
    scale = draw(st.floats(min_value=1e-3, max_value=1e3))
    recs = []
    for i in range(n):
        rank = i % n_streams
        payload = (rng.randn(size) * scale).astype(dtype)
        recs.append(StreamRecord("f", 0, rank, i // n_streams, payload))
    return recs


@settings(max_examples=40, deadline=None)
@given(recs=record_batches(),
       compress=st.sampled_from(["none", "zstd", "int8", "int8+zstd"]),
       delta=st.booleans())
def test_encode_decode_batch_roundtrip(recs, compress, delta):
    out = decode_batch(encode_batch(recs, compress=compress, delta=delta))
    assert len(out) == len(recs)
    for a, b in zip(recs, out):
        assert (a.field_name, a.group_id, a.rank, a.step) == \
               (b.field_name, b.group_id, b.rank, b.step)
        assert b.payload.shape == np.asarray(a.payload).shape
        ref = np.asarray(a.payload, np.float32)   # wire format is f32
        if compress.startswith("int8"):
            # closed-loop per-stream quantization: error bounded by each
            # record's own quant step (ptp/254), never by chain position
            ptp = float(ref.max() - ref.min()) if ref.size else 0.0
            atol = max(ptp / 254.0 * 1.5, 1e-6)
            np.testing.assert_allclose(ref, b.payload, atol=atol)
        elif delta:
            # float delta chains reconstruct to roundoff, not bitwise
            atol = 1e-5 * max(1.0, float(np.abs(ref).max() or 1.0))
            np.testing.assert_allclose(ref, b.payload, atol=atol)
        else:
            np.testing.assert_array_equal(ref, b.payload)
